"""End-to-end FL training driver (the deliverable-(b) long run).

Trains the paper's full pipeline — uniqueness detection, sparsified GI with
warm start, switching monitor with gamma decay — for a few hundred rounds on
the synthetic disaster-image-like dataset, comparing all strategies, and
writes metrics + a checkpoint.

Run:  PYTHONPATH=src python examples/train_fl_end_to_end.py [--rounds 200]
"""

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.checkpoint.io import save_pytree
from repro.core.client import LocalProgram
from repro.core.gradient_inversion import GIConfig
from repro.core.server import FLConfig, Server
from repro.data.partition import (client_label_histograms, dirichlet_partition,
                                  pad_client_shards)
from repro.data.staleness import intertwined_schedule
from repro.data.synthetic import make_image_dataset
from repro.models.small import lenet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--strategies", nargs="+",
                    default=["unweighted", "weighted", "ours", "unstale"])
    ap.add_argument("--tau", type=int, default=20)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--gi-engine", choices=["batched", "sequential"],
                    default="batched",
                    help="batched = one vmapped while_loop jit over the "
                         "round's stale cohort; sequential = the per-client "
                         "seed engine (for A/B timing the same pipeline)")
    ap.add_argument("--out", default="examples/out_fl_end_to_end")
    args = ap.parse_args()

    N_CLASSES, HW, TARGET = 5, 16, 2
    x, y = make_image_dataset(120, n_classes=N_CLASSES, hw=HW)
    tx, ty = make_image_dataset(40, n_classes=N_CLASSES, hw=HW, seed=99)
    idx = dirichlet_partition(y, 16, alpha=args.alpha, seed=0)
    cx, cy, cm = pad_client_shards(x, y, idx, m=24)
    hist = client_label_histograms(y, idx, N_CLASSES)
    sched = intertwined_schedule(hist, TARGET, n_slow=4, tau=args.tau)
    prog = LocalProgram(steps=5, lr=0.08, momentum=0.5)

    os.makedirs(args.out, exist_ok=True)
    results = {}
    for strategy in args.strategies:
        cfg = FLConfig(
            strategy=strategy, rounds=args.rounds,
            gi=GIConfig(n_rec=12, iters=25, lr=0.1, keep_fraction=0.05,
                        warm_start=True),
            batched_gi=(args.gi_engine == "batched"),
            uniqueness_check=True, switching=True, switch_check_every=5,
            eval_every=10, seed=0)
        server = Server(lenet(n_classes=N_CLASSES, in_hw=HW), prog, cfg,
                        cx, cy, cm, sched, tx, ty)
        t0 = time.time()
        metrics = server.run()
        wall = time.time() - t0
        final = [m for m in metrics if "acc" in m][-1]
        results[strategy] = {
            "final_acc": final["acc"],
            "stale_class_acc": final.get(f"acc_class_{TARGET}"),
            "switched_at": server.monitor.switched_at,
            "gi_rounds": len(server.gi_log),
            "gi_engine": args.gi_engine,
            "wall_s": round(wall, 1),
            "curve": [(m["round"], m["acc"]) for m in metrics if "acc" in m],
        }
        print(f"{strategy:11s} acc={final['acc']:.3f} "
              f"stale-class={final.get(f'acc_class_{TARGET}', 0):.3f} "
              f"switched_at={server.monitor.switched_at} ({wall:.0f}s)")
        if strategy == "ours":
            save_pytree(os.path.join(args.out, "global_model.npz"),
                        server.global_params,
                        meta={"strategy": strategy, "rounds": args.rounds})
    with open(os.path.join(args.out, "results.json"), "w") as f:
        json.dump(results, f, indent=2, default=float)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
