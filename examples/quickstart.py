"""Quickstart: the paper's technique in ~60 lines.

Builds a tiny FL cohort with intertwined data/device heterogeneity, runs the
GI-based stale-update conversion against the unweighted baseline, and prints
the accuracy on the staleness-affected class.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.client import LocalProgram
from repro.core.gradient_inversion import GIConfig
from repro.core.server import FLConfig, Server
from repro.data.partition import (client_label_histograms, dirichlet_partition,
                                  pad_client_shards)
from repro.data.staleness import intertwined_schedule
from repro.data.synthetic import make_image_dataset
from repro.models.small import lenet

N_CLASSES, HW, TARGET, TAU = 5, 16, 2, 10

# 1. data: Dirichlet(0.1) non-iid shards over 12 clients
x, y = make_image_dataset(100, n_classes=N_CLASSES, hw=HW)
tx, ty = make_image_dataset(30, n_classes=N_CLASSES, hw=HW, seed=99)
idx = dirichlet_partition(y, 12, alpha=0.1, seed=0)
cx, cy, cm = pad_client_shards(x, y, idx, m=24)
hist = client_label_histograms(y, idx, N_CLASSES)

# 2. intertwined heterogeneity: the 3 biggest holders of class TARGET are
#    slow by TAU rounds — exactly the paper's hazard-rescue setting
sched = intertwined_schedule(hist, target_class=TARGET, n_slow=3, tau=TAU)

# 3. the paper's local program: 5 epochs of SGD(momentum=0.5)
prog = LocalProgram(steps=5, lr=0.1, momentum=0.5)

for strategy in ("unweighted", "ours"):
    cfg = FLConfig(strategy=strategy, rounds=30,
                   gi=GIConfig(n_rec=12, iters=30, lr=0.1),
                   eval_every=10)
    server = Server(lenet(n_classes=N_CLASSES, in_hw=HW), prog, cfg,
                    cx, cy, cm, sched, tx, ty)
    metrics = server.run()
    final = [m for m in metrics if "acc" in m][-1]
    print(f"{strategy:11s}  overall={final['acc']:.3f}  "
          f"stale-class={final[f'acc_class_{TARGET}']:.3f}")
