"""Gradient inversion for TOKEN models — the paper's Appendix A path.

For text, D_rec cannot be discrete tokens; the paper prescribes estimating
data in the *continuous embedding space*. This example runs the mechanism on
a REAL transformer (the qwen1_5_0_5b family at reduced dims) through the
batched server APIs — the same hot path ``benchmarks/run.py --only llm``
times and docs/real_models.md documents:

  1. ``repro.models.fl_bridge.lm_fl_model`` wraps the transformer as a
     ``SmallModel`` whose inputs are soft (seq_len, d_model) embeddings and
     whose labels are soft next-token distributions;
  2. slow clients fine-tune the LM on their private "dialect" token streams
     (one vmapped multi-version cohort LocalUpdate);
  3. the server recovers the whole stale cohort in ONE ``invert_batch``
     call (Eq. 6, L1 disparity, batched while_loop) and re-trains the
     estimates on the current weights in one ``estimate_unstale_batch``;
  4. the estimates are compared against the true unstale updates and the
     1st-order Taylor baseline, then the full ``Server.step`` round
     (strategy="ours") runs end to end.

Run:  PYTHONPATH=src python examples/fl_llm_embedding_gi.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import compensation
from repro.core.client import LocalProgram, make_cohort_update
from repro.core.disparity import l1_disparity, tree_sub
from repro.core.gradient_inversion import GIConfig, GradientInverter
from repro.core.server import FLConfig, Server
from repro.data.staleness import StalenessSchedule
from repro.models.fl_bridge import embed_dataset, lm_fl_model

S, n = 8, 2                       # seq len, dataset slots per client
B_STALE, N = 3, 6                 # stale cohort size, total clients
cfg = get_config("qwen1_5_0_5b", reduced=True).with_(remat=True)
V = cfg.vocab_size
model = lm_fl_model(cfg, seq_len=S)
# one plain SGD step per participation: the classic gradient-matching
# setting, where the stale update pins down the client's gradient exactly
program = LocalProgram(steps=1, lr=0.2, momentum=0.0)

rng = np.random.default_rng(0)
w0 = model.init(jax.random.PRNGKey(1))

# client data: slow clients speak a low-vocab "dialect" with peaked labels
# (one real example each — second slot masked out), fast clients the rest —
# the intertwined data/device heterogeneity the paper targets
slow_toks = rng.integers(0, V // 4, size=(B_STALE, n, S))
fast_toks = rng.integers(V // 4, V, size=(N - B_STALE, n, S))
toks = np.concatenate([fast_toks, slow_toks])  # clients 0.. fast, tail slow
cx = np.asarray(jax.vmap(lambda t: embed_dataset(w0, cfg, t))(
    jnp.asarray(toks)))
cy = rng.integers(0, V, size=(N, n)).astype(np.int32)
for b in range(B_STALE):
    cy[N - B_STALE + b] = rng.integers(b * 10, b * 10 + 5, size=(n,))
cm = np.ones((N, n), np.float32)
cm[N - B_STALE:, 1:] = 0.0        # slow clients hold a single example

# --- the batched mechanism, explicitly ------------------------------------- #
cohort_update = jax.jit(make_cohort_update(model.apply, program))
sx, sy, sm = (jnp.asarray(cx[N - B_STALE:]), jnp.asarray(cy[N - B_STALE:]),
              jnp.asarray(cm[N - B_STALE:]))

# stale updates: the cohort trained from w0 while the global model advances
# hard — fresh fast-client batches every round, aggressive local programs
w_stale = cohort_update(w0, sx, sy, sm)
drift_update = jax.jit(make_cohort_update(
    model.apply, LocalProgram(steps=4, lr=0.5, momentum=0.0)))
fm = jnp.asarray(cm[:N - B_STALE])
w_now = w0
for _ in range(10):
    ft = rng.integers(V // 4, V, size=(N - B_STALE, n, S))
    fxr = jax.vmap(lambda t: embed_dataset(w0, cfg, t))(jnp.asarray(ft))
    fyr = jnp.asarray(rng.integers(0, V, size=(N - B_STALE, n)), jnp.int32)
    trained = drift_update(w_now, fxr, fyr, fm)
    w_now = jax.tree_util.tree_map(
        lambda t, w: w + jnp.mean(t - w[None], axis=0), trained, w_now)
w_true = cohort_update(w_now, sx, sy, sm)
bcast = lambda w: jax.tree_util.tree_map(
    lambda l: jnp.broadcast_to(l, (B_STALE,) + l.shape), w)
true_delta = tree_sub(w_true, bcast(w_now))

# ONE batched inversion over the whole stale cohort (embedding-space D_rec:
# soft (n_rec, S, d_model) inputs + soft vocab labels per lane)
inv = GradientInverter(model.apply, model.input_shape, V, program,
                       GIConfig(n_rec=1, iters=600, lr=0.05,
                                init_scale=0.02, remat=True))
w0_stack = bcast(w0)
drec, info = inv.invert_batch(
    w0_stack, w_stale, jax.random.split(jax.random.PRNGKey(7), B_STALE))
w_hat = inv.estimate_unstale_batch(w_now, drec)

est_delta = tree_sub(w_hat, bcast(w_now))
stale_delta = tree_sub(w_stale, w0_stack)
fo_delta = compensation.first_order_batch(stale_delta, w_now, w0_stack)

per_lane = lambda a, b: [
    float(l1_disparity(jax.tree_util.tree_map(lambda x: x[i], a),
                       jax.tree_util.tree_map(lambda x: x[i], b)))
    for i in range(B_STALE)]
e_gi = per_lane(est_delta, true_delta)
e_stale = per_lane(stale_delta, true_delta)
e_fo = per_lane(fo_delta, true_delta)

losses = np.asarray(info["losses"])
print(f"batched GI over {B_STALE} stale clients "
      f"(engine={info['engine']}, iters={np.asarray(info['iters_used'])}):")
print(f"  loss lane0: {losses[0, 0]:.4f} -> "
      f"{losses[0, int(info['iters_used'][0]) - 1]:.4f}")
print("L1 error vs true unstale update (per stale client):")
print(f"  raw stale update : {[f'{e:.5f}' for e in e_stale]}")
print(f"  1st-order Taylor : {[f'{e:.5f}' for e in e_fo]}")
print(f"  GI (embeddings)  : {[f'{e:.5f}' for e in e_gi]}")
assert all(g < s for g, s in zip(e_gi, e_stale)), \
    "GI estimates should beat the raw stale updates"
print("OK: embedding-space GI (paper Appendix A) beats raw staleness"
      + (" and 1st-order" if sum(e_gi) < sum(e_fo) else ""))

# privacy check: recovered embeddings are not near any true token embedding
true_emb = jax.vmap(lambda t: embed_dataset(w0, cfg, t))(
    jnp.asarray(slow_toks))
d_cross = float(jnp.min(jnp.linalg.norm(
    drec[0][:, :, None, None] - true_emb[:, None, :, :], axis=-1)))
print(f"min distance recovered-embedding <-> true token embedding: "
      f"{d_cross:.3f} (distribution-level recovery only)")

# --- the same mechanism inside the full fused server round ----------------- #
tx = np.asarray(embed_dataset(
    w0, cfg, jnp.asarray(rng.integers(0, V, size=(8, S)))))
ty = rng.integers(0, V, size=(8,)).astype(np.int32)
sched = StalenessSchedule(
    staleness=np.array([0] * (N - B_STALE) + [2] * B_STALE))
srv = Server(model, program,
             FLConfig(strategy="ours", rounds=0,
                      gi=GIConfig(n_rec=1, iters=10, lr=0.05, remat=True),
                      uniqueness_check=False, switching=False,
                      eval_every=10_000),
             cx, cy, cm, sched, tx, ty)
fast, slow = sched.fast_clients, sched.slow_clients
for t in range(4):
    pairs = [(c, max(0, t - 2)) for c in slow] if t >= 2 else []
    srv.step(t, fast, pairs)
gi_iters = [m["gi_iters"] for m in srv.metrics]
print(f"Server.step x4 (strategy=ours, fused round + batched GI): "
      f"gi_iters per round = {gi_iters}")
assert sum(gi_iters) > 0, "the stale rounds should have run GI"
print("OK: full fused round on the transformer bridge")
