"""Gradient inversion for TOKEN models — the paper's Appendix A path.

For text, D_rec cannot be discrete tokens; the paper prescribes estimating
data in the *continuous embedding space*. This example runs the full
mechanism on a tiny causal LM:

  1. a "client" fine-tunes the LM on its private token stream (LocalUpdate);
  2. the server, holding only the stale weights, optimizes soft EMBEDDING
     sequences + soft next-token targets so that retraining reproduces the
     stale update (Eq. 6 with L1 disparity);
  3. the unstale estimate LocalUpdate(w_now; D_rec) is compared against the
     true unstale update and against 1st-order Taylor compensation.

Run:  PYTHONPATH=src python examples/fl_llm_embedding_gi.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import compensation
from repro.core.client import LocalProgram, make_local_update
from repro.core.disparity import cosine_distance, l1_disparity, tree_sub
from repro.core.gradient_inversion import GIConfig, GradientInverter

V, D, S, N = 64, 32, 12, 16      # vocab, embed dim, seq len, |D_rec|
KEY = jax.random.PRNGKey(0)


# --- a tiny causal LM operating on (soft) embeddings ----------------------- #
def init_lm(key):
    ks = jax.random.split(key, 4)
    s = lambda k, i, o: jax.random.normal(k, (i, o)) / jnp.sqrt(i)
    return {"embed": jax.random.normal(ks[0], (V, D)) * 0.1,
            "w1": s(ks[1], D, 64), "w2": s(ks[2], 64, D),
            "head": s(ks[3], D, V)}


def apply_embeds(params, x_embeds):
    """x_embeds (n, S, D) -> next-token logits (n, S, V); causal via a
    shifted cumulative-mean context mixer (cheap but order-sensitive)."""
    csum = jnp.cumsum(x_embeds, axis=1)
    denom = jnp.arange(1, x_embeds.shape[1] + 1)[None, :, None]
    ctx = csum / denom
    h = jax.nn.gelu(ctx @ params["w1"]) @ params["w2"] + x_embeds
    return h @ params["head"]


def embed(params, tokens):
    return params["embed"][tokens]


# --- client data: a skewed token distribution ------------------------------ #
k1, k2, k3 = jax.random.split(KEY, 3)
client_tokens = jax.random.randint(k1, (N, S + 1), 0, V // 4)      # "dialect"
other_tokens = jax.random.randint(k2, (N, S + 1), V // 4, V)

w0 = init_lm(k3)
program = LocalProgram(steps=5, lr=0.2, momentum=0.5)

# LocalUpdate over embedding inputs with soft targets (n, S, V):
lu = make_local_update(apply_embeds, program)


def client_update(params, tokens):
    x = embed(params, tokens[:, :-1])
    y = jax.nn.one_hot(tokens[:, 1:], V) * 50.0    # peaked soft targets
    return lu(params, x, y)[0]


w_stale = client_update(w0, client_tokens)

# staleness: global model advances tau rounds on other clients' data
w_now = w0
for _ in range(8):
    w_now = client_update(w_now, other_tokens)
w_true = client_update(w_now, client_tokens)
true_delta = tree_sub(w_true, w_now)

# --- GI in embedding space -------------------------------------------------- #
inv = GradientInverter(apply_embeds, input_shape=(S, D), n_classes=V,
                       program=program,
                       cfg=GIConfig(n_rec=N, iters=250, lr=0.05))
# D_rec: soft embeddings (N, S, D) + soft per-position targets (N, S, V)
kx, ky = jax.random.split(jax.random.PRNGKey(7))
init_drec = (jax.random.normal(kx, (N, S, D)) * 0.1,
             jax.random.normal(ky, (N, S, V)) * 0.1)
drec, info = inv.invert(w0, w_stale, jax.random.PRNGKey(1), init=init_drec)
w_hat = inv.estimate_unstale(w_now, drec)

e_gi = float(l1_disparity(tree_sub(w_hat, w_now), true_delta))
e_stale = float(l1_disparity(tree_sub(w_stale, w0), true_delta))
fo = compensation.first_order(tree_sub(w_stale, w0), w_now, w0)
e_fo = float(l1_disparity(fo, true_delta))

print(f"GI loss: {info['losses'][0]:.4f} -> {info['losses'][-1]:.4f} "
      f"({info['iters_used']} iters)")
print(f"L1 error vs true unstale update:")
print(f"  raw stale update : {e_stale:.5f}")
print(f"  1st-order Taylor : {e_fo:.5f}")
print(f"  GI (embeddings)  : {e_gi:.5f}")
assert info["losses"][-1] < info["losses"][0], "GI failed to optimize"
assert e_gi < e_stale, "GI estimate should beat the raw stale update"
print("OK: embedding-space GI (paper Appendix A) beats raw staleness"
      + (" and 1st-order" if e_gi < e_fo else ""))

# privacy check: recovered embeddings are not near any true token embedding
true_emb = embed(w0, client_tokens[:, :-1])
d_cross = float(jnp.min(jnp.linalg.norm(
    drec[0][:, :, None, :] - true_emb[:, None, :, :], axis=-1)))
print(f"min distance recovered-embedding <-> true token embedding: "
      f"{d_cross:.3f} (distribution-level recovery only)")
