"""Strategy comparison under event-driven asynchronous FL.

Runs a named simulation scenario (see ``python -m repro.sim --list``) once
per server strategy from the SAME seed — identical arrival process, dropout
pattern and realized staleness across strategies, so accuracy differences
are attributable to the aggregation strategy alone. This is the async
counterpart of examples/train_fl_end_to_end.py: instead of a fixed per-client
tau, staleness emerges from stochastic device latencies.

Run:  PYTHONPATH=src python examples/simulate_async_fl.py \
          [--scenario fedbuff_k4] [--horizon 12] [--seed 0] \
          [--strategies unweighted ours]
"""

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sim import scenarios


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="fedbuff_k4",
                    choices=scenarios.names())
    ap.add_argument("--horizon", type=float, default=12.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gi-iters", type=int, default=8)
    ap.add_argument("--strategies", nargs="+",
                    default=["unweighted", "weighted", "ours"])
    ap.add_argument("--out", default="examples/out_sim_async")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    results = {}
    digests = set()
    for strategy in args.strategies:
        run = scenarios.build(args.scenario, seed=args.seed,
                              horizon=args.horizon, strategy=strategy,
                              gi_iters=args.gi_iters)
        t0 = time.time()
        summary = run.run()
        wall = time.time() - t0
        digests.add(summary["trace_digest"])
        results[strategy] = {
            "final_acc": summary["final_acc"],
            "aggregations": summary["aggregations"],
            "mean_realized_tau": summary["mean_realized_tau"],
            "max_realized_tau": summary["max_realized_tau"],
            "dropouts": summary["dropouts"],
            "trace_digest": summary["trace_digest"],
            "evals": [{"time": t, "version": v, "acc": a}
                      for t, v, a in run.engine.evals],
            "wall_s": round(wall, 1),
        }
        print(f"{strategy:11s} acc={summary['final_acc']:.3f} "
              f"aggs={summary['aggregations']:4d} "
              f"mean_tau={summary['mean_realized_tau']:.2f} "
              f"max_tau={summary['max_realized_tau']} ({wall:.0f}s)")
    # the event process must be strategy-independent (same seed, same trace)
    assert len(digests) == 1, f"traces diverged across strategies: {digests}"
    out = os.path.join(args.out, f"{args.scenario}_seed{args.seed}.json")
    with open(out, "w") as f:
        json.dump({"scenario": args.scenario, "seed": args.seed,
                   "horizon": args.horizon, "results": results},
                  f, indent=2, default=float)
    print("wrote", out)


if __name__ == "__main__":
    main()
