"""GI estimation error vs upload bitwidth on an intertwined scenario.

The server's gradient inversion estimates each stale client's *unstale*
update from its (now quantized) upload. This driver runs the same
intertwined cohort — the biggest holders of one class are the slow
clients — at fp32, int8 and int4 wire formats and reports:

* E1: disparity between the GI estimate and the client's TRUE current
  update (the `SwitchMonitor`'s delayed oracle checks — GI estimation
  error, the quantity quantization noise could corrupt);
* E2: disparity between the raw stale update and the true one (what
  aggregating without conversion would eat) — the baseline E1 must beat;
* accuracy and the bytes each format put on the wire.

Expected shape (see docs/compression.md): int8 + error feedback is
indistinguishable from fp32 — quantization noise sits far below GI's own
estimation error — while int4 starts to blur the disparity targets.

Run:  PYTHONPATH=src python examples/quant_bits_gi_error.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.client import LocalProgram
from repro.core.gradient_inversion import GIConfig
from repro.core.quantize import QuantConfig
from repro.core.server import FLConfig, Server
from repro.data.partition import (client_label_histograms,
                                  dirichlet_partition, pad_client_shards)
from repro.data.staleness import intertwined_schedule
from repro.data.synthetic import make_feature_dataset
from repro.models.small import mlp3

N_CLASSES, N_FEATURES, TARGET, TAU = 5, 12, 2, 4

# intertwined heterogeneity: Dirichlet(0.1) shards; the 3 biggest holders
# of class TARGET are slow by TAU rounds
x, y = make_feature_dataset(60, n_classes=N_CLASSES,
                            n_features=N_FEATURES, seed=0)
tx, ty = make_feature_dataset(20, n_classes=N_CLASSES,
                              n_features=N_FEATURES, seed=99)
idx = dirichlet_partition(y, 10, alpha=0.1, seed=0)
cx, cy, cm = pad_client_shards(x, y, idx, m=24)
hist = client_label_histograms(y, idx, N_CLASSES)
sched = intertwined_schedule(hist, target_class=TARGET, n_slow=3, tau=TAU)
prog = LocalProgram(steps=5, lr=0.1, momentum=0.5)

print(f"{'bits':>4} {'mean E1 (GI)':>12} {'mean E2 (stale)':>15} "
      f"{'acc':>6} {'stale-class':>11} {'wire bytes':>10}")
for bits in (32, 8, 4):
    cfg = FLConfig(strategy="ours", rounds=24,
                   gi=GIConfig(n_rec=10, iters=25, lr=0.1),
                   eval_every=8, switch_check_every=1,
                   quant=QuantConfig(bits=bits))
    server = Server(mlp3(n_features=N_FEATURES, n_classes=N_CLASSES,
                         hidden=24),
                    prog, cfg, cx, cy, cm, sched, tx, ty)
    metrics = server.run()
    final = [m for m in metrics if "acc" in m][-1]
    obs = server.monitor.history
    e1 = float(np.mean([o["E1"] for o in obs])) if obs else float("nan")
    e2 = float(np.mean([o["E2"] for o in obs])) if obs else float("nan")
    print(f"{bits:>4} {e1:>12.4f} {e2:>15.4f} {final['acc']:>6.3f} "
          f"{final[f'acc_class_{TARGET}']:>11.3f} "
          f"{server.wire_bytes:>10d}")
