"""Serve a (reduced) assigned-architecture model with batched requests.

Exercises the production serving path — prefill into a KV cache, batched
greedy decode via serve_step — for any of the 10 assigned architectures.

Run:  PYTHONPATH=src python examples/serve_llm.py --arch rwkv6-1.6b
      PYTHONPATH=src python examples/serve_llm.py --arch qwen3-1.7b --batch 4
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.launch.specs import concrete_train_batch
from repro.models import transformer as T
from repro.models.model import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=sorted(ALIASES) + ARCH_IDS)
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, S = args.batch, args.prompt_len
    max_len = S + args.gen_len

    batch = concrete_train_batch(cfg, B, S, key)
    prompts = batch.get("tokens")
    if prompts is None:
        prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    caches = T.init_cache(cfg, B, max_len)
    cross_kv = (T.precompute_cross_kv(params, cfg, batch["frames"])
                if cfg.is_encdec else None)
    step = jax.jit(make_serve_step(cfg))

    t0 = time.time()
    logits = None
    for i in range(S):
        logits, caches = step(params, caches, prompts[:, i:i + 1],
                              jnp.array(i, jnp.int32), cross_kv)
    cur = jnp.argmax(logits[:, -1], -1)[:, None]
    generated = [cur]
    for i in range(S, max_len - 1):
        logits, caches = step(params, caches, cur, jnp.array(i, jnp.int32),
                              cross_kv)
        cur = jnp.argmax(logits[:, -1], -1)[:, None]
        generated.append(cur)
    out = jnp.concatenate(generated, axis=1)
    dt = time.time() - t0
    print(f"arch={cfg.name} family={cfg.family} "
          f"{B} requests x {out.shape[1]} tokens in {dt:.1f}s "
          f"({B * out.shape[1] / dt:.1f} tok/s incl. prefill)")
    for b in range(B):
        print(f"  req{b}: prompt={prompts[b, :6].tolist()}... "
              f"-> generated={out[b, :8].tolist()}...")


if __name__ == "__main__":
    main()
