"""Serve a (reduced) assigned-architecture model with batched requests.

Exercises the production serving path — prefill into a KV cache, batched
greedy decode via serve_step — for any of the 10 assigned architectures.
The drive loop lives in ``repro.launch.decode`` (previously
``repro.launch.serve``); this example is a thin front-end with
example-friendly defaults.

Run:  PYTHONPATH=src python examples/serve_llm.py --arch rwkv6-1.6b
      PYTHONPATH=src python examples/serve_llm.py --arch qwen3-1.7b --batch 4
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    decode.main(["--arch", args.arch, "--batch", str(args.batch),
                 "--prompt-len", str(args.prompt_len),
                 "--gen-len", str(args.gen_len)])


if __name__ == "__main__":
    main()
