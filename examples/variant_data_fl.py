"""The paper's variant-data scenario (§4.3): clients' data drifts from one
feature representation to another (MNIST->SVHN in the paper; synthetic style
A -> style B here) while slow clients stay stale.

Shows the headline §4.3 claim: under drift the baselines never stabilize,
while GI-based conversion tracks the moving distribution.

Run:  PYTHONPATH=src python examples/variant_data_fl.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.client import LocalProgram
from repro.core.gradient_inversion import GIConfig
from repro.core.server import FLConfig, Server
from repro.data.partition import (client_label_histograms, dirichlet_partition,
                                  pad_client_shards)
from repro.data.staleness import intertwined_schedule
from repro.data.synthetic import make_image_dataset
from repro.data.variant import VariantDataStream
from repro.models.small import lenet

N_CLASSES, HW, TARGET, TAU, RATE = 5, 16, 2, 8, 1.0

x, y = make_image_dataset(100, n_classes=N_CLASSES, hw=HW, style=0)
# test set drawn from the DRIFTED distribution (styles mixed) — the server
# must learn the new representation as it arrives
tx0, ty0 = make_image_dataset(15, n_classes=N_CLASSES, hw=HW, style=0, seed=9)
tx1, ty1 = make_image_dataset(15, n_classes=N_CLASSES, hw=HW, style=1, seed=9)
import numpy as np
tx = np.concatenate([tx0, tx1]); ty = np.concatenate([ty0, ty1])

pool_x, pool_y = make_image_dataset(100, n_classes=N_CLASSES, hw=HW, style=1,
                                    seed=1)
idx = dirichlet_partition(y, 12, alpha=0.1, seed=0)
cx, cy, cm = pad_client_shards(x, y, idx, m=24)
hist = client_label_histograms(y, idx, N_CLASSES)
sched = intertwined_schedule(hist, TARGET, n_slow=3, tau=TAU)
prog = LocalProgram(steps=5, lr=0.1, momentum=0.5)

for strategy in ("unweighted", "weighted", "ours"):
    stream = VariantDataStream(cx.copy(), cy, cm, pool_x, pool_y,
                               rate=RATE, seed=0)
    cfg = FLConfig(strategy=strategy, rounds=30,
                   gi=GIConfig(n_rec=12, iters=25, lr=0.1, warm_start=True),
                   eval_every=10)
    server = Server(lenet(n_classes=N_CLASSES, in_hw=HW), prog, cfg,
                    cx, cy, cm, sched, tx, ty, variant_stream=stream)
    metrics = server.run()
    curve = [(m["round"], round(m["acc"], 3)) for m in metrics if "acc" in m]
    print(f"{strategy:11s} drift={stream.drift_fraction:.2f} acc curve {curve}")
