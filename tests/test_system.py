"""End-to-end behaviour tests for the paper's system.

These are the integration-level claims: under intertwined data/device
heterogeneity, the GI-based conversion ("ours") recovers the stale class's
accuracy while weighted aggregation loses it; the oracle bounds everything;
switching and the variant-data scenario behave as §3.2 / §4.3 describe.
"""

import jax
import numpy as np
import pytest

from repro.core.client import LocalProgram
from repro.core.gradient_inversion import GIConfig
from repro.core.server import FLConfig, Server
from repro.data.partition import (client_label_histograms, dirichlet_partition,
                                  pad_client_shards)
from repro.data.staleness import intertwined_schedule
from repro.data.synthetic import make_image_dataset
from repro.data.variant import VariantDataStream
from repro.models.small import lenet

N_CLASSES, HW, TARGET = 5, 16, 2


@pytest.fixture(scope="module")
def fl_data():
    x, y = make_image_dataset(100, n_classes=N_CLASSES, hw=HW, seed=0)
    tx, ty = make_image_dataset(30, n_classes=N_CLASSES, hw=HW, seed=99)
    idx = dirichlet_partition(y, 12, alpha=0.1, seed=0)
    cx, cy, cm = pad_client_shards(x, y, idx, m=24)
    hist = client_label_histograms(y, idx, N_CLASSES)
    return cx, cy, cm, hist, tx, ty


def run_strategy(fl_data, strategy, rounds=30, tau=20, gi_iters=30):
    # tau=20 (paper: large staleness) makes the intertwined-heterogeneity
    # phenomenon robust: unweighted demonstrably loses the stale class
    # (acc_class ~0.0) instead of riding single-test-image sampling noise
    cx, cy, cm, hist, tx, ty = fl_data
    sched = intertwined_schedule(hist, target_class=TARGET, n_slow=3, tau=tau)
    prog = LocalProgram(steps=5, lr=0.1, momentum=0.5)
    cfg = FLConfig(strategy=strategy, rounds=rounds,
                   gi=GIConfig(n_rec=12, iters=gi_iters, lr=0.1),
                   eval_every=rounds, seed=0)
    srv = Server(lenet(n_classes=N_CLASSES, in_hw=HW), prog, cfg,
                 cx, cy, cm, sched, tx, ty)
    metrics = srv.run()
    final = [m for m in metrics if "acc" in m][-1]
    return final, srv


@pytest.mark.slow
def test_ours_beats_unweighted_on_stale_class(fl_data):
    f_ours, _ = run_strategy(fl_data, "ours")
    f_unw, _ = run_strategy(fl_data, "unweighted")
    assert f_ours[f"acc_class_{TARGET}"] >= f_unw[f"acc_class_{TARGET}"], \
        (f_ours, f_unw)
    assert f_ours["acc"] >= f_unw["acc"] - 0.05


@pytest.mark.slow
def test_unstale_oracle_upper_bounds_unweighted(fl_data):
    f_oracle, _ = run_strategy(fl_data, "unstale")
    f_unw, _ = run_strategy(fl_data, "unweighted")
    assert f_oracle["acc"] >= f_unw["acc"]


@pytest.mark.slow
def test_all_strategies_run_without_error(fl_data):
    for strat in ("weighted", "first_order", "w_pred", "asyn_tiers"):
        final, _ = run_strategy(fl_data, strat, rounds=6, gi_iters=5)
        assert 0.0 <= final["acc"] <= 1.0


@pytest.mark.slow
def test_gi_runs_and_logs(fl_data):
    final, srv = run_strategy(fl_data, "ours", rounds=14, tau=5, gi_iters=10)
    assert len(srv.gi_log) > 0
    assert all(rec["iters_used"] > 0 for rec in srv.gi_log)


@pytest.mark.slow
def test_variant_data_scenario(fl_data):
    cx, cy, cm, hist, tx, ty = fl_data
    px, py = make_image_dataset(100, n_classes=N_CLASSES, hw=HW,
                                style=1, seed=1)
    stream = VariantDataStream(cx, cy, cm, px, py, rate=1.0, seed=0)
    sched = intertwined_schedule(hist, target_class=TARGET, n_slow=3, tau=5)
    prog = LocalProgram(steps=5, lr=0.1, momentum=0.5)
    cfg = FLConfig(strategy="ours", rounds=10,
                   gi=GIConfig(n_rec=12, iters=10, lr=0.1),
                   eval_every=10, seed=0)
    srv = Server(lenet(n_classes=N_CLASSES, in_hw=HW), prog, cfg,
                 cx, cy, cm, sched, tx, ty, variant_stream=stream)
    metrics = srv.run()
    assert stream.drift_fraction > 0.0
    assert any("acc" in m for m in metrics)


def test_server_round_structure(fl_data):
    """One round produces sane metrics and advances history."""
    final, srv = run_strategy(fl_data, "unweighted", rounds=2, gi_iters=1)
    assert len(srv.history) == 3  # init + 2 rounds
    assert "acc" in final
