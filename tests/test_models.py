"""Per-architecture smoke tests (REDUCED variants: 2 layers, d_model<=256,
<=4 experts) — one forward + one train step on CPU, asserting output shapes
and no NaNs — plus decode-vs-prefill consistency for each family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import frontend as F
from repro.models import transformer as T
from repro.models.model import (init_train_state, loss_fn, make_serve_step,
                                make_train_step)
from repro.optim import sgd

KEY = jax.random.PRNGKey(0)

# archs whose reduced smoke/consistency tests are compile-heavy (>~4s each on
# CPU); they run under --runslow so the tier-1 pass keeps a representative
# per-family subset within the CI budget
SLOW_ARCHS = {"qwen3_1_7b", "whisper_tiny", "rwkv6_1_6b", "deepseek_moe_16b",
              "hymba_1_5b", "llama4_scout_17b_a16e", "starcoder2_15b"}


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in SLOW_ARCHS
            else a for a in archs]


def make_batch(cfg, B, S, key=KEY):
    ks = jax.random.split(key, 3)
    batch = {"labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["input_embeds"] = F.vlm_input_embeds(ks[0], cfg, B, S)
        batch["positions"] = F.mrope_positions(B, S, n_patches=min(8, S), grid=4)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    if cfg.family == "audio":
        batch["frames"] = F.audio_frame_embeddings(ks[2], cfg, B)
    return batch


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    B, S = 2, 16
    opt = sgd(0.01, momentum=0.5)
    state = init_train_state(KEY, cfg, opt)
    batch = make_batch(cfg, B, S)

    logits, aux = T.forward(state["params"], cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    step = jax.jit(make_train_step(cfg, opt))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum()) for a, b in zip(
            jax.tree_util.tree_leaves(new_state["params"]),
            jax.tree_util.tree_leaves(state["params"])))
    assert delta > 0.0


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_arch_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    B = 2
    params = T.init_params(KEY, cfg)
    caches = T.init_cache(cfg, B, 32)
    cross_kv = None
    if cfg.is_encdec:
        frames = F.audio_frame_embeddings(KEY, cfg, B)
        cross_kv = T.precompute_cross_kv(params, cfg, frames)
    step = jax.jit(make_serve_step(cfg))
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(3):
        logits, caches = step(params, caches, tok, jnp.array(i, jnp.int32),
                              cross_kv)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", _arch_params(
    ["qwen3_1_7b", "rwkv6_1_6b", "hymba_1_5b", "h2o_danube_1_8b",
     "whisper_tiny"]))
def test_decode_matches_prefill(arch):
    """Teacher-forced decode logits must match full-sequence forward."""
    cfg = get_config(arch, reduced=True)
    if cfg.sliding_window is not None:
        cfg = cfg.with_(sliding_window=64)  # window > S so paths agree
    B, S = 1, 8
    params = T.init_params(KEY, cfg)
    batch = make_batch(cfg, B, S)
    full_logits, _ = T.forward(params, cfg, batch)

    caches = T.init_cache(cfg, B, S)
    cross_kv = None
    if cfg.is_encdec:
        cross_kv = T.precompute_cross_kv(params, cfg, batch["frames"])
    outs = []
    for i in range(S):
        lg, caches = T.serve_step(params, cfg, caches,
                                  batch["tokens"][:, i:i + 1],
                                  jnp.array(i, jnp.int32), cross_kv)
        outs.append(lg)
    step_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        jax.nn.log_softmax(full_logits), jax.nn.log_softmax(step_logits),
        atol=2e-3, rtol=2e-3)


def test_param_counts_match_nameplates():
    """Full configs should be in the right parameter-count ballpark."""
    expect = {
        "rwkv6_1_6b": (1.4e9, 2.3e9),
        "starcoder2_15b": (13e9, 17e9),
        "qwen1_5_0_5b": (0.3e9, 0.8e9),
        "whisper_tiny": (25e6, 90e6),
        "deepseek_moe_16b": (14e9, 20e9),
        "qwen3_1_7b": (1.4e9, 2.4e9),
        "hymba_1_5b": (1.2e9, 2.2e9),
        "h2o_danube_1_8b": (1.5e9, 2.2e9),
        "qwen2_vl_7b": (6.5e9, 9e9),
        "llama4_scout_17b_a16e": (90e9, 120e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda c=cfg: T.init_params(KEY, c))
        n = sum(l.size for l in jax.tree_util.tree_leaves(shapes))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_microbatched_train_step_matches_single():
    """Gradient accumulation over microbatches == full-batch step (SGD)."""
    cfg = get_config("qwen3_1_7b", reduced=True)
    opt = sgd(0.05)
    state = init_train_state(KEY, cfg, opt)
    batch = make_batch(cfg, 4, 16)
    s1, m1 = jax.jit(make_train_step(cfg, opt, n_micro=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, opt, n_micro=2))(state, batch)
    v1 = jax.tree_util.tree_leaves(s1["params"])
    v2 = jax.tree_util.tree_leaves(s2["params"])
    for a, b in zip(v1, v2):
        np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


def test_training_reduces_loss():
    cfg = get_config("qwen1_5_0_5b", reduced=True)
    opt = sgd(0.1, momentum=0.9)
    state = init_train_state(KEY, cfg, opt)
    batch = make_batch(cfg, 4, 16)
    step = jax.jit(make_train_step(cfg, opt))
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses
