"""Mixed-precision contracts for the real-model hot path.

Two invariants the LLM-scale round relies on (docs/real_models.md):

* bf16-compute GI: running the batched inverter on a bf16 transformer
  evaluates the same Eq.-6 objective as fp32 within a pinned tolerance
  (bf16 keeps fp32's exponent range, so the disparity — a mean of small
  |diffs| — agrees to ~1%), and still optimizes it. The *trajectories*
  diverge quickly (the objective is nonconvex and bf16 rounds every
  gradient), so the pinned comparison is the deterministic iter-0
  objective at identical init, not the final iterate.
* compensation math is pinned to fp32: ``first_order_batch`` /
  ``w_pred_batch`` / ``predict_future_global_batch`` return exactly
  fp32 leaves even when the model (and hence the update trees) is bf16 —
  the g (.) g (.) dw surrogate squares already-small entries and would
  underflow in bf16's 8 mantissa bits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import compensation
from repro.core.client import LocalProgram, make_cohort_update
from repro.core.gradient_inversion import GIConfig, GradientInverter
from repro.models.fl_bridge import embed_dataset, lm_fl_model

S, B = 4, 2
PROGRAM = LocalProgram(steps=1, lr=0.2, momentum=0.0)


def _tiny_cfg(dtype: str):
    return get_config("qwen1_5_0_5b", reduced=True).with_(
        n_layers=1, d_model=64, n_heads=2, n_kv_heads=2, d_head=32,
        d_ff=128, vocab_size=128, dtype=dtype)


def _run_gi(dtype: str):
    cfg = _tiny_cfg(dtype)
    model = lm_fl_model(cfg, seq_len=S)
    w0 = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size // 4, size=(B, 1, S)))
    x = jax.vmap(lambda t: embed_dataset(w0, cfg, t))(toks)
    y = jnp.asarray(rng.integers(0, 20, size=(B, 1)), jnp.int32)
    m = jnp.ones((B, 1), jnp.float32)
    w_stale = jax.jit(make_cohort_update(model.apply, PROGRAM))(w0, x, y, m)
    inv = GradientInverter(model.apply, model.input_shape, cfg.vocab_size,
                           PROGRAM,
                           GIConfig(n_rec=1, iters=25, lr=0.1,
                                    init_scale=0.02))
    w0s = jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l, (B,) + l.shape), w0)
    drec, info = inv.invert_batch(
        w0s, w_stale, jax.random.split(jax.random.PRNGKey(7), B))
    return drec, np.asarray(info["losses"], np.float64)


@pytest.fixture(scope="module")
def gi_runs():
    return _run_gi("float32"), _run_gi("bfloat16")


def test_bf16_gi_objective_matches_fp32(gi_runs):
    """Identical init -> the iter-0 Eq.-6 objective agrees within 5%."""
    (_, l32), (_, l16) = gi_runs
    rel = np.abs(l16[:, 0] - l32[:, 0]) / l32[:, 0]
    assert np.all(rel < 0.05), rel


def test_bf16_gi_optimizes(gi_runs):
    """Both precisions reduce their own disparity loss lane-by-lane."""
    for _, losses in gi_runs:
        assert np.all(losses[:, -1] < losses[:, 0]), losses[:, [0, -1]]


def test_bf16_gi_recovers_finite_embeddings(gi_runs):
    (_, _), (drec16, _) = gi_runs
    for leaf in jax.tree_util.tree_leaves(drec16):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


# --------------------------------------------------------------------------- #
# compensation.*_batch fp32 pinning
# --------------------------------------------------------------------------- #


def _bf16_tree(key, n=None):
    ks = jax.random.split(key, 2)
    shape = lambda s: s if n is None else (n,) + s
    return {"a": (jax.random.normal(ks[0], shape((3, 4))) * 1e-3
                  ).astype(jnp.bfloat16),
            "b": (jax.random.normal(ks[1], shape((5,))) * 1e-3
                  ).astype(jnp.bfloat16)}


def _all_fp32(tree):
    return all(l.dtype == jnp.float32
               for l in jax.tree_util.tree_leaves(tree))


def test_first_order_batch_outputs_fp32():
    k = jax.random.PRNGKey(0)
    out = compensation.first_order_batch(
        _bf16_tree(k, n=3), _bf16_tree(jax.random.PRNGKey(1), n=3),
        _bf16_tree(jax.random.PRNGKey(2), n=3))
    assert _all_fp32(out)


def test_w_pred_batch_outputs_fp32():
    hist = [_bf16_tree(jax.random.PRNGKey(i)) for i in (3, 4)]
    out = compensation.w_pred_batch(
        _bf16_tree(jax.random.PRNGKey(5), n=2), hist,
        _bf16_tree(jax.random.PRNGKey(6), n=2), taus=[1, 3])
    assert _all_fp32(out)


def test_predict_future_global_batch_outputs_fp32():
    one = compensation.predict_future_global_batch(
        [_bf16_tree(jax.random.PRNGKey(7))], taus=[2])
    two = compensation.predict_future_global_batch(
        [_bf16_tree(jax.random.PRNGKey(8)),
         _bf16_tree(jax.random.PRNGKey(9))], taus=[2, 4])
    assert _all_fp32(one) and _all_fp32(two)


def test_first_order_batch_fp32_bitwise_vs_scalar():
    """For fp32 inputs the pinned casts are no-ops: each lane of the
    stacked form is bit-identical to the historic per-client path."""
    f32 = lambda t: jax.tree_util.tree_map(
        lambda l: l.astype(jnp.float32), t)
    ups = f32(_bf16_tree(jax.random.PRNGKey(10), n=3))
    now = f32(_bf16_tree(jax.random.PRNGKey(11)))
    base = f32(_bf16_tree(jax.random.PRNGKey(12), n=3))
    batch = compensation.first_order_batch(
        ups, jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (3,) + l.shape), now), base)
    for i in range(3):
        one = compensation.first_order(
            jax.tree_util.tree_map(lambda l: l[i], ups), now,
            jax.tree_util.tree_map(lambda l: l[i], base))
        got = jax.tree_util.tree_map(lambda l: l[i], batch)
        for a, b in zip(jax.tree_util.tree_leaves(one),
                        jax.tree_util.tree_leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
