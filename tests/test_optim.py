"""Optimizer unit tests (pure-JAX optim package)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adam, apply_updates, clip_by_global_norm,
                         fedprox_wrap, global_norm, sgd)


def test_sgd_matches_closed_form():
    opt = sgd(0.1)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -0.5])}
    s = opt.init(p)
    u, s = opt.update(g, s, p)
    p2 = apply_updates(p, u)
    np.testing.assert_allclose(p2["w"], [0.95, 2.05])


def test_sgd_momentum_accumulates():
    opt = sgd(1.0, momentum=0.5)
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    s = opt.init(p)
    u1, s = opt.update(g, s, p)        # m=1, u=-1
    u2, s = opt.update(g, s, p)        # m=1.5, u=-1.5
    np.testing.assert_allclose(u1["w"], -1.0)
    np.testing.assert_allclose(u2["w"], -1.5)


def test_adam_first_step_is_lr_sized():
    opt = adam(0.01)
    p = {"w": jnp.array([1.0])}
    g = {"w": jnp.array([10.0])}
    s = opt.init(p)
    u, s = opt.update(g, s, p)
    np.testing.assert_allclose(u["w"], -0.01, rtol=1e-3)


def test_adam_scale_invariance():
    """After bias correction, update magnitude ~ lr regardless of grad scale."""
    for scale in (1e-3, 1.0, 1e3):
        opt = adam(0.01)
        p = {"w": jnp.array([0.0])}
        s = opt.init(p)
        u, s = opt.update({"w": jnp.array([scale])}, s, p)
        np.testing.assert_allclose(abs(float(u["w"][0])), 0.01, rtol=1e-3)


def test_fedprox_zero_at_global():
    base = sgd(0.1)
    gp = {"w": jnp.array([1.0])}
    opt = fedprox_wrap(base, mu=5.0, global_params=gp)
    s = opt.init(gp)
    # at w == w_global the proximal term vanishes
    u, _ = opt.update({"w": jnp.array([0.0])}, s, gp)
    np.testing.assert_allclose(u["w"], 0.0, atol=1e-7)


def test_clip_by_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    np.testing.assert_allclose(float(global_norm(t)), 5.0)
    clipped, gn = clip_by_global_norm(t, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    same, _ = clip_by_global_norm(t, 10.0)
    np.testing.assert_allclose(same["a"], t["a"])
