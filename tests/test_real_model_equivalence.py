"""Fused-round equivalence on REAL transformer models (the LLM hot path).

tests/test_fused_step.py anchors the fused multi-version round on matmul
toy models; this suite re-anchors it on the ``repro.models.fl_bridge``
transformers the ``benchmarks/run.py --only llm`` section times:

* whisper_tiny-class (reduced encoder-decoder, cross-attention through the
  stubbed audio frontend): the fused round reproduces the per-client loop
  oracle at 1e-5 — transformer kernels under the vmapped cohort regroup
  into differently-fused XLA programs that differ by ~1 ULP per op, the
  same caveat that keeps the conv models out of the bitwise anchor in
  tests/test_fused_step.py (the bitwise fused==loop contract lives there,
  on matmul models) — and a 1-device mesh reproduces the mesh=None fused
  engine bit-for-bit (identical compiled program);
* 2/4-shard ``(pod, data)`` meshes agree with the unsharded trajectory at
  tolerance (the multi-shard contract — skipped unless the devices are
  visible; CI's sharded job fabricates 4);
* a ``(pod, data, model)`` mesh (model-parallel weights via the GSPMD
  cohort engines, ``FLConfig.mesh_mode``) agrees at the same tolerance on
  the qwen family — the configuration docs/real_models.md documents.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.client import LocalProgram
from repro.core.disparity import tree_to_vector
from repro.core.gradient_inversion import GIConfig
from repro.core.server import FLConfig, Server
from repro.data.staleness import StalenessSchedule
from repro.launch.mesh import make_server_mesh
from repro.models.config import EncoderConfig
from repro.models.fl_bridge import embed_dataset, lm_fl_model

S, n, N, B_STALE = 4, 2, 6, 2


def _bridge_server(arch, mesh=None, fused=True, seed=0):
    # shrink far below reduced() so jit compiles (the cost here — several
    # distinct cohort shapes x loop/fused/mesh variants) stay in seconds
    # while keeping the family's structure (GQA / cross-attention)
    cfg = get_config(arch, reduced=True).with_(
        n_layers=1, d_model=64, n_heads=2, n_kv_heads=2, d_head=32,
        d_ff=128, vocab_size=128)
    if cfg.is_encdec:
        cfg = cfg.with_(encoder=EncoderConfig(n_layers=1, n_ctx=16))
    model = lm_fl_model(cfg, seq_len=S)
    V = cfg.vocab_size
    rng = np.random.default_rng(seed)
    w0 = model.init(jax.random.PRNGKey(1))
    toks = jnp.asarray(rng.integers(0, V, size=(N, n, S)))
    cx = np.asarray(jax.vmap(lambda t: embed_dataset(w0, cfg, t))(toks))
    cy = rng.integers(0, V, size=(N, n)).astype(np.int32)
    cm = np.ones((N, n), np.float32)
    tx = np.asarray(embed_dataset(
        w0, cfg, jnp.asarray(rng.integers(0, V, size=(4, S)))))
    ty = rng.integers(0, V, size=(4,)).astype(np.int32)
    sched = StalenessSchedule(
        staleness=np.array([0] * (N - B_STALE) + [2] * B_STALE))
    prog = LocalProgram(steps=2, lr=0.1, momentum=0.5)
    fl = FLConfig(strategy="ours", rounds=0, fused_step=fused,
                  gi=GIConfig(n_rec=1, iters=4, lr=0.1),
                  uniqueness_check=False, switching=False, seed=seed,
                  eval_every=10_000)
    return Server(model, prog, fl, cx, cy, cm, sched, tx, ty, mesh=mesh)


def _drive(srv, rounds=4):
    """Scripted mixed-staleness cohorts: the two slow clients deliver
    updates based on different past rounds once the history allows it."""
    fast = srv.schedule.fast_clients
    slow = srv.schedule.slow_clients
    for t in range(rounds):
        pairs = []
        if t >= 2:
            pairs = [(slow[0], t - 2), (slow[1], t - 1)]
        srv.step(t, fast[:3], pairs)
    return srv


def _assert_same(a, b, bitwise=True, atol=0.0):
    va = np.asarray(tree_to_vector(a.global_params), np.float32)
    vb = np.asarray(tree_to_vector(b.global_params), np.float32)
    if bitwise:
        np.testing.assert_array_equal(va, vb)
    else:
        np.testing.assert_allclose(va, vb, atol=atol)
    assert [m["gi_iters"] for m in a.metrics] == \
        [m["gi_iters"] for m in b.metrics]


@pytest.fixture(scope="module")
def whisper_fused():
    return _drive(_bridge_server("whisper_tiny", fused=True))


def test_whisper_fused_matches_loop(whisper_fused):
    """The multi-version fused round reproduces the per-client loop oracle
    through the encoder-decoder bridge (cross-attention, last-position
    logits, GI in embedding space) at 1e-5 — the real-model ULP caveat
    (see module docstring) rules out the bitwise form."""
    srv_l = _drive(_bridge_server("whisper_tiny", fused=False))
    _assert_same(whisper_fused, srv_l, bitwise=False, atol=1e-5)


def test_whisper_one_shard_mesh_bitwise(whisper_fused):
    srv_one = _drive(_bridge_server("whisper_tiny",
                                    mesh=make_server_mesh(1)))
    _assert_same(whisper_fused, srv_one, bitwise=True)


@pytest.mark.parametrize("n_devices", [2, 4])
def test_whisper_sharded_matches_unsharded(whisper_fused, n_devices):
    if len(jax.devices()) < n_devices:
        pytest.skip(f"needs {n_devices} devices "
                    f"(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    srv_shd = _drive(_bridge_server("whisper_tiny",
                                    mesh=make_server_mesh(n_devices)))
    _assert_same(whisper_fused, srv_shd, bitwise=False, atol=5e-4)


def test_qwen_model_axis_mesh_matches_unsharded():
    """(pod, data, model) mesh: weights sharded on the model axis through
    the GSPMD cohort engines (server cohort update + batched GI + unstale
    re-train), cohort-only layouts at every jit boundary. Trajectory agrees
    with the single-device engines at the multi-shard tolerance."""
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    srv_ref = _drive(_bridge_server("qwen1_5_0_5b"))
    srv_tp = _drive(_bridge_server(
        "qwen1_5_0_5b", mesh=make_server_mesh(4, pods=1, model=2)))
    _assert_same(srv_ref, srv_tp, bitwise=False, atol=5e-4)
