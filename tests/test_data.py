"""Data pipeline tests: partitioning, staleness schedules, drift."""

import numpy as np
import pytest

from repro.data.partition import (client_label_histograms, dirichlet_partition,
                                  one_class_partition, pad_client_shards)
from repro.data.staleness import (intertwined_schedule, observed_schedule,
                                  uniform_random_schedule)
from repro.data.synthetic import (make_feature_dataset, make_image_dataset,
                                  make_timeseries_dataset)
from repro.data.variant import VariantDataStream


def test_image_dataset_shapes_and_determinism():
    x1, y1 = make_image_dataset(20, n_classes=4, hw=16, seed=3)
    x2, y2 = make_image_dataset(20, n_classes=4, hw=16, seed=3)
    assert x1.shape == (80, 16, 16, 1) and y1.shape == (80,)
    np.testing.assert_array_equal(x1, x2)
    assert set(np.unique(y1)) == {0, 1, 2, 3}


def test_styles_differ():
    xa, _ = make_image_dataset(10, n_classes=3, hw=16, style=0)
    xb, _ = make_image_dataset(10, n_classes=3, hw=16, style=1)
    assert float(np.abs(xa - xb).mean()) > 0.05


def test_dirichlet_partition_covers_all_samples():
    _, y = make_image_dataset(50, n_classes=5, hw=8)
    parts = dirichlet_partition(y, 10, alpha=0.5, seed=1)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(y)
    assert len(set(all_idx.tolist())) == len(y)  # exactly once


def test_dirichlet_alpha_controls_heterogeneity():
    _, y = make_image_dataset(100, n_classes=5, hw=8)
    h_low = client_label_histograms(y, dirichlet_partition(y, 10, 0.05, 1), 5)
    h_high = client_label_histograms(y, dirichlet_partition(y, 10, 100.0, 1), 5)

    def mean_entropy(h):
        p = h / np.maximum(h.sum(1, keepdims=True), 1)
        return float((-np.where(p > 0, p * np.log(p + 1e-12), 0).sum(1)).mean())

    assert mean_entropy(h_low) < mean_entropy(h_high) - 0.3


def test_one_class_partition():
    _, y = make_image_dataset(50, n_classes=5, hw=8)
    parts = one_class_partition(y, 8, seed=0)
    for idx in parts:
        assert len(set(y[idx].tolist())) <= 1


def test_pad_client_shards_masks():
    x, y = make_image_dataset(10, n_classes=2, hw=8)
    parts = [np.array([0, 1, 2]), np.array([3])]
    xs, ys, mask = pad_client_shards(x, y, parts, m=4)
    assert xs.shape == (2, 4, 8, 8, 1)
    np.testing.assert_array_equal(mask, [[1, 1, 1, 0], [1, 0, 0, 0]])


def test_intertwined_schedule_targets_class_holders():
    hist = np.array([[10, 0], [0, 10], [5, 5], [0, 8]])
    sched = intertwined_schedule(hist, target_class=1, n_slow=2, tau=7)
    assert set(sched.slow_clients) == {1, 3}
    assert sched.tau(1) == 7 and sched.tau(0) == 0


def test_uniform_schedule_count():
    s = uniform_random_schedule(20, 5, 10, seed=0)
    assert len(s.slow_clients) == 5


def test_intertwined_schedule_heterogeneous_tau_array():
    hist = np.array([[0, 10], [0, 8], [5, 5], [10, 0]])
    # taus assigned in rank order: heaviest holder of the class gets tau[0]
    sched = intertwined_schedule(hist, target_class=1, n_slow=2, tau=[3, 7])
    assert sched.tau(0) == 3 and sched.tau(1) == 7
    assert sched.tau(2) == 0 and sched.tau(3) == 0
    assert sched.max_tau == 7


def test_intertwined_schedule_tau_sampler():
    hist = np.array([[0, 9], [0, 7], [0, 5], [4, 1]])
    rng = np.random.RandomState(0)
    sched = intertwined_schedule(hist, 1, n_slow=3,
                                 tau=lambda n: rng.randint(1, 20, n))
    assert set(sched.slow_clients) == {0, 1, 2}
    assert all(1 <= sched.tau(i) < 20 for i in sched.slow_clients)
    # scalar backward-compat path unchanged
    s2 = intertwined_schedule(hist, 1, n_slow=3, tau=6)
    assert all(s2.tau(i) == 6 for i in s2.slow_clients)


def test_intertwined_schedule_bad_tau_specs():
    hist = np.array([[0, 9], [0, 7], [4, 1]])
    with pytest.raises(ValueError):
        intertwined_schedule(hist, 1, n_slow=2, tau=[1, 2, 3])  # wrong length
    with pytest.raises(ValueError):
        intertwined_schedule(hist, 1, n_slow=2, tau=[1, 0])     # tau < 1


def test_observed_schedule_view():
    sched = observed_schedule(4, {0: [2, 4], 2: [5]}, reducer="mean")
    assert sched.staleness.tolist() == [3, 0, 5, 0]
    assert observed_schedule(4, {0: [2, 4]}, "max").tau(0) == 4
    assert observed_schedule(4, {0: [2, 4]}, "last").tau(0) == 4
    with pytest.raises(ValueError):
        observed_schedule(4, {}, "median")


def test_variant_stream_drifts_with_rate():
    x, y = make_image_dataset(30, n_classes=3, hw=8, style=0)
    px, py = make_image_dataset(30, n_classes=3, hw=8, style=1)
    parts = dirichlet_partition(y, 5, 1.0, 0)
    xs, ys, mask = pad_client_shards(x, y, parts, m=12)
    stream = VariantDataStream(xs, ys, mask, px, py, rate=2.0, seed=0)
    before = stream.xs.copy()
    n = stream.step()
    assert n > 0
    assert float(np.abs(stream.xs - before).sum()) > 0
    for _ in range(5):
        stream.step()
    assert stream.drift_fraction > 0.1


def test_feature_and_timeseries_datasets():
    x, y = make_feature_dataset(20, n_classes=5, n_features=12)
    assert x.shape == (100, 12)
    x, y = make_timeseries_dataset(10, n_classes=3, seq=32, channels=4)
    assert x.shape == (30, 32, 4)
