"""GI in embedding space for token models (paper Appendix A).

The GradientInverter is input-shape agnostic: passing an init D_rec of soft
embedding sequences (n, S, D) with per-position soft targets (n, S, V) runs
the identical Eq.-6 optimization for causal-LM clients.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.core.client import LocalProgram, make_local_update
from repro.core.disparity import l1_disparity, tree_sub
from repro.core.gradient_inversion import GIConfig, GradientInverter

V, D, S, N = 32, 16, 8, 12
KEY = jax.random.PRNGKey(0)


def init_lm(key):
    ks = jax.random.split(key, 4)
    s = lambda k, i, o: jax.random.normal(k, (i, o)) / jnp.sqrt(i)
    return {"embed": jax.random.normal(ks[0], (V, D)) * 0.1,
            "w1": s(ks[1], D, 32), "w2": s(ks[2], 32, D),
            "head": s(ks[3], D, V)}


def apply_embeds(params, x):
    ctx = jnp.cumsum(x, axis=1) / jnp.arange(1, x.shape[1] + 1)[None, :, None]
    h = jax.nn.gelu(ctx @ params["w1"]) @ params["w2"] + x
    return h @ params["head"]


@pytest.fixture(scope="module")
def lm_setting():
    program = LocalProgram(steps=4, lr=0.2, momentum=0.5)
    lu = make_local_update(apply_embeds, program)
    w0 = init_lm(jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (N, S + 1), 0, V // 4)

    def client_update(params):
        x = params["embed"][toks[:, :-1]]
        y = jax.nn.one_hot(toks[:, 1:], V) * 50.0
        return lu(params, x, y)[0]

    w_stale = client_update(w0)
    return program, w0, w_stale, client_update


def test_embedding_gi_reduces_loss(lm_setting):
    program, w0, w_stale, _ = lm_setting
    inv = GradientInverter(apply_embeds, (S, D), V, program,
                           GIConfig(n_rec=N, iters=60, lr=0.05))
    kx, ky = jax.random.split(KEY)
    init = (jax.random.normal(kx, (N, S, D)) * 0.1,
            jax.random.normal(ky, (N, S, V)) * 0.1)
    _, info = inv.invert(w0, w_stale, KEY, init=init)
    assert info["losses"][-1] < info["losses"][0] * 0.9, info["losses"]


@pytest.mark.slow
def test_embedding_gi_estimate_beats_stale(lm_setting):
    program, w0, w_stale, client_update = lm_setting
    # strong drift: many stale rounds on disjoint data so the stale update
    # is genuinely misaligned with the current global model
    drift_prog = LocalProgram(steps=6, lr=0.4, momentum=0.5)
    lu = make_local_update(apply_embeds, drift_prog)
    other = jax.random.randint(jax.random.PRNGKey(9), (N, S + 1), V // 4, V)
    w_now = w0
    for i in range(15):
        ks = jax.random.split(jax.random.PRNGKey(100 + i))
        other_i = jax.random.randint(ks[0], (N, S + 1), V // 4, V)
        x = w_now["embed"][other_i[:, :-1]]
        y = jax.nn.one_hot(other_i[:, 1:], V) * 50.0
        w_now = lu(w_now, x, y)[0]
    w_true = client_update(w_now)
    true_delta = tree_sub(w_true, w_now)

    inv = GradientInverter(apply_embeds, (S, D), V, program,
                           GIConfig(n_rec=N, iters=200, lr=0.05))
    kx, ky = jax.random.split(KEY)
    init = (jax.random.normal(kx, (N, S, D)) * 0.1,
            jax.random.normal(ky, (N, S, V)) * 0.1)
    drec, _ = inv.invert(w0, w_stale, KEY, init=init)
    w_hat = inv.estimate_unstale(w_now, drec)
    e_gi = float(l1_disparity(tree_sub(w_hat, w_now), true_delta))
    e_stale = float(l1_disparity(tree_sub(w_stale, w0), true_delta))
    assert e_gi < e_stale, (e_gi, e_stale)
