"""Checkpoint roundtrip tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_meta, load_pytree, save_pytree
from repro.configs import get_config
from repro.models import transformer as T


def test_roundtrip_simple_tree(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree, meta={"round": 7})
    restored = load_pytree(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert load_meta(path)["round"] == 7


def test_roundtrip_model_params(tmp_path):
    cfg = get_config("qwen1_5_0_5b", reduced=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "model.npz")
    save_pytree(path, params)
    restored = load_pytree(path, params)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
