"""Mesh-sharded server hot path: equivalence, bucketing and reshard tests.

Tier-1 anchors (run on any device count):
* a 1-device (pod, data) mesh reproduces the unsharded batched trajectory
  BIT-FOR-BIT (the sharded dispatcher routes 1-shard meshes through the
  identical single-device engines);
* the shard_map engine itself — forced even on a 1-shard mesh — matches the
  plain vmapped engine within 1e-4 per client;
* shard-bucket arithmetic (pow2 per-shard buckets; empty/odd cohorts).

Multi-device tests (mesh sizes 2/4) skip unless enough devices are visible;
CI's sharded job fabricates them with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.client import LocalProgram, make_local_update
from repro.core.disparity import tree_stack, tree_to_vector
from repro.core.gradient_inversion import GIConfig, GradientInverter
from repro.core.server import FLConfig, Server
from repro.core.sparsify import WarmStartCache
from repro.data.partition import (client_label_histograms, dirichlet_partition,
                                  pad_client_shards)
from repro.data.staleness import intertwined_schedule
from repro.data.synthetic import make_image_dataset
from repro.launch.mesh import (make_server_mesh, mesh_shard_count,
                               shard_map_compat)
from repro.launch.sharding import shard_bucket
from repro.models.small import lenet, mlp3

KEY = jax.random.PRNGKey(0)


def _mesh_or_skip(n: int):
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} devices "
                    f"(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    return make_server_mesh(n)


# --------------------------------------------------------------------------- #
# Shard bucketing
# --------------------------------------------------------------------------- #


def test_shard_bucket_arithmetic():
    # unsharded reduces to the historic global pow2 bucket
    assert [shard_bucket(b, 1) for b in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    # per-shard pow2 buckets
    assert shard_bucket(3, 4) == 4          # local bucket 1
    assert shard_bucket(5, 4) == 8          # local bucket 2
    assert shard_bucket(9, 4) == 16         # local bucket 4
    assert shard_bucket(8, 2) == 8
    # empty cohorts never allocate
    assert shard_bucket(0, 1) == 0 and shard_bucket(0, 4) == 0


# --------------------------------------------------------------------------- #
# Batched GI engine equivalence
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def gi_setting():
    """B=3 stale clients, different data AND base rounds (odd batch on
    purpose: it exercises uneven shard bucketing on every mesh size)."""
    model = mlp3(n_features=8, n_classes=3, hidden=16)
    program = LocalProgram(steps=3, lr=0.1, momentum=0.5)
    lu = make_local_update(model.apply, program)
    w = model.init(KEY)
    bases, stales = [], []
    for b in range(3):
        kx, ky = jax.random.split(jax.random.PRNGKey(10 + b))
        x = jax.random.normal(kx, (12, 8))
        y = jax.random.randint(ky, (12,), 0, 3)
        w_stale, _ = lu(w, x, y)
        bases.append(w)
        stales.append(w_stale)
        w, _ = lu(w, jax.random.normal(ky, (12, 8)), y)
    keys = jax.random.split(jax.random.PRNGKey(42), 3)
    return model, program, bases, stales, keys


def _inverter(model, program, mesh=None, **kw):
    cfg = GIConfig(**{"n_rec": 6, "iters": 12, "lr": 0.1, **kw})
    return GradientInverter(model.apply, model.input_shape, model.n_classes,
                            program, cfg, mesh=mesh)


def test_one_shard_mesh_is_bitwise_identical(gi_setting):
    """Tier-1 anchor: mesh of 1 device == mesh=None, bit for bit."""
    model, program, bases, stales, keys = gi_setting
    ref = _inverter(model, program)
    one = _inverter(model, program, mesh=make_server_mesh(1))
    d0, i0 = ref.invert_batch(tree_stack(bases), tree_stack(stales), keys)
    d1, i1 = one.invert_batch(tree_stack(bases), tree_stack(stales), keys)
    assert i0["padded_to"] == i1["padded_to"] == 4
    np.testing.assert_array_equal(np.asarray(d0[0]), np.asarray(d1[0]))
    np.testing.assert_array_equal(np.asarray(d0[1]), np.asarray(d1[1]))
    w0 = ref.estimate_unstale_batch(bases[0], d0)
    w1 = one.estimate_unstale_batch(bases[0], d1)
    np.testing.assert_array_equal(np.asarray(tree_to_vector(w0)),
                                  np.asarray(tree_to_vector(w1)))


def test_forced_shard_map_engine_matches_plain(gi_setting):
    """The shard_map engine itself (not the 1-shard dispatch) agrees with
    the plain vmapped engine — runs in tier-1 on a 1-device mesh."""
    model, program, bases, stales, keys = gi_setting
    inv = _inverter(model, program, mesh=make_server_mesh(1))
    d_ref, _ = inv.invert_batch(tree_stack(bases), tree_stack(stales), keys)
    # call the sharded builder directly with the bucketed batch
    from repro.core.disparity import tree_pad_leading
    from repro.core.gradient_inversion import tree_sub
    B, Bp = 3, shard_bucket(3, 1)
    target = tree_sub(tree_stack(stales), tree_stack(bases))
    drec0 = inv._init_many(keys)
    fn = inv._get_invert_many_sharded(12, has_mask=False)
    pad = Bp - B
    d_sm, _, _, _ = fn(
        tree_pad_leading(tree_stack(bases), pad),
        tree_pad_leading(target, pad),
        tree_pad_leading(drec0, pad),
        jnp.concatenate([jnp.full((B,), 12, jnp.int32),
                         jnp.zeros((pad,), jnp.int32)]))
    np.testing.assert_allclose(np.asarray(d_sm[0][:B]), np.asarray(d_ref[0]),
                               atol=1e-4)


@pytest.mark.parametrize("n_devices", [2, 4])
def test_sharded_gi_matches_unsharded_per_client(gi_setting, n_devices):
    """Acceptance: 2- and 4-shard meshes agree with the single-device
    batched engine within 1e-4 per client (masked and unmasked)."""
    mesh = _mesh_or_skip(n_devices)
    model, program, bases, stales, keys = gi_setting
    ref = _inverter(model, program)
    shd = _inverter(model, program, mesh=mesh)
    d0, i0 = ref.invert_batch(tree_stack(bases), tree_stack(stales), keys)
    dm, im = shd.invert_batch(tree_stack(bases), tree_stack(stales), keys)
    assert im["n_shards"] == n_devices
    assert im["padded_to"] == shard_bucket(3, n_devices)
    np.testing.assert_array_equal(np.asarray(i0["iters_used"]),
                                  np.asarray(im["iters_used"]))
    for b in range(3):
        np.testing.assert_allclose(np.asarray(dm[0][b]), np.asarray(d0[0][b]),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(dm[1][b]), np.asarray(d0[1][b]),
                                   atol=1e-4)
    # downstream unstale estimates agree per client too
    w0 = ref.estimate_unstale_batch(bases[0], d0)
    wm = shd.estimate_unstale_batch(bases[0], dm)
    np.testing.assert_allclose(np.asarray(tree_to_vector(wm)),
                               np.asarray(tree_to_vector(w0)), atol=1e-4)


@pytest.mark.parametrize("n_devices", [2, 4])
def test_sharded_gi_masked_and_early_stop(gi_setting, n_devices):
    mesh = _mesh_or_skip(n_devices)
    model, program, bases, stales, keys = gi_setting
    from repro.core.disparity import tree_sub
    from repro.core.sparsify import topk_mask_batch
    deltas = [tree_sub(s, b) for s, b in zip(stales, bases)]
    masks_ref = topk_mask_batch(deltas, 0.1)
    masks_shd = topk_mask_batch(deltas, 0.1, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(masks_shd),
                                  np.asarray(masks_ref))
    ref = _inverter(model, program, keep_fraction=0.1)
    shd = _inverter(model, program, mesh=mesh, keep_fraction=0.1)
    d0, _ = ref.invert_batch(tree_stack(bases), tree_stack(stales), keys,
                             masks=masks_ref)
    dm, _ = shd.invert_batch(tree_stack(bases), tree_stack(stales), keys,
                             masks=masks_shd)
    np.testing.assert_allclose(np.asarray(dm[0]), np.asarray(d0[0]),
                               atol=1e-4)
    # early stop: per-lane tol predicates survive sharding (iteration
    # counts must match the unsharded engine exactly)
    ref_t = _inverter(model, program, iters=40, tol=5e-3)
    shd_t = _inverter(model, program, mesh=mesh, iters=40, tol=5e-3)
    _, it0 = ref_t.invert_batch(tree_stack(bases), tree_stack(stales), keys)
    _, itm = shd_t.invert_batch(tree_stack(bases), tree_stack(stales), keys)
    np.testing.assert_array_equal(np.asarray(it0["iters_used"]),
                                  np.asarray(itm["iters_used"]))


# --------------------------------------------------------------------------- #
# Warm-start cache across reshards
# --------------------------------------------------------------------------- #


def test_warm_cache_survives_resharding(gi_setting):
    """put from one mesh, gather onto another: values identical (the cache
    is host-resident and keyed by client id, so mesh geometry is free to
    change between rounds)."""
    model, program, bases, stales, keys = gi_setting
    inv = _inverter(model, program, iters=4)
    cache = WarmStartCache()
    drec, _ = inv.invert_batch(tree_stack(bases), tree_stack(stales), keys)
    cache.put_stacked([7, 3, 11], *drec)

    n_dev = len(jax.devices())
    meshes = [make_server_mesh(1)]
    if n_dev >= 2:
        meshes.append(make_server_mesh(2))
    if n_dev >= 4:
        meshes.append(make_server_mesh(4))
    ref_x, ref_y, ref_warm = cache.gather([7, 99, 11])
    for mesh in meshes:
        S = mesh_shard_count(mesh)
        pad_to = shard_bucket(3, S)
        xs, ys, warm = cache.gather_sharded([7, 99, 11], mesh, pad_to=pad_to)
        np.testing.assert_array_equal(warm[:3], [True, False, True])
        assert not warm[3:].any()            # padded rows are cold
        np.testing.assert_allclose(np.asarray(xs[:3]), np.asarray(ref_x))
        np.testing.assert_allclose(np.asarray(ys[:3]), np.asarray(ref_y))
        if S > 1:    # multi-shard gathers come back bucketed + mesh-placed
            assert xs.shape[0] == pad_to and xs.shape[0] % S == 0
        else:        # a 1-shard mesh is bit-for-bit the plain gather
            assert xs.shape[0] == 3
        # and a put from this mesh's layout round-trips
        cache.put_stacked([7, 99, 11], xs[:3], ys[:3])
        x7, _ = cache.get(7)
        np.testing.assert_allclose(np.asarray(x7), np.asarray(ref_x[0]))
        cache.drop(99)     # restore: 99 must stay cold for the next mesh
    assert np.asarray(ref_warm).tolist() == [True, False, True]


def test_gather_sharded_empty_cache(gi_setting):
    cache = WarmStartCache()
    xs, ys, warm = cache.gather_sharded([1, 2, 3], make_server_mesh(1),
                                        pad_to=4)
    assert xs is None and ys is None
    assert warm.shape == (4,) and not warm.any()


# --------------------------------------------------------------------------- #
# End-to-end Server trajectories
# --------------------------------------------------------------------------- #


def _tiny_server(mesh, rounds=4):
    x, y = make_image_dataset(60, n_classes=3, hw=8, seed=0)
    tx, ty = make_image_dataset(15, n_classes=3, hw=8, seed=9)
    idx = dirichlet_partition(y, 8, alpha=0.5, seed=0)
    cx, cy, cm = pad_client_shards(x, y, idx, m=12)
    hist = client_label_histograms(y, idx, 3)
    sched = intertwined_schedule(hist, target_class=1, n_slow=3, tau=2)
    prog = LocalProgram(steps=3, lr=0.1, momentum=0.5)
    cfg = FLConfig(strategy="ours", rounds=rounds,
                   gi=GIConfig(n_rec=6, iters=5, lr=0.1, keep_fraction=0.2),
                   uniqueness_check=False, eval_every=rounds,
                   switch_check_every=1, seed=0)
    return Server(lenet(n_classes=3, in_hw=8), prog, cfg,
                  cx, cy, cm, sched, tx, ty, mesh=mesh)


def test_server_one_device_mesh_trajectory_bitwise():
    """Tier-1 anchor: the full training trajectory on a 1-device mesh is
    bit-for-bit the unsharded batched trajectory — masks, warm starts, GI,
    pending E1/E2 checks, aggregation, everything."""
    s_ref = _tiny_server(None)
    s_one = _tiny_server(make_server_mesh(1))
    s_ref.run()
    s_one.run()
    np.testing.assert_array_equal(
        np.asarray(tree_to_vector(s_ref.global_params)),
        np.asarray(tree_to_vector(s_one.global_params)))
    assert [r["gi_iters"] for r in s_ref.metrics] == \
        [r["gi_iters"] for r in s_one.metrics]
    assert len(s_ref.gi_log) == len(s_one.gi_log)


@pytest.mark.parametrize("n_devices", [2, 4])
def test_server_sharded_trajectory_matches(n_devices):
    mesh = _mesh_or_skip(n_devices)
    s_ref = _tiny_server(None)
    s_shd = _tiny_server(mesh)
    s_ref.run()
    s_shd.run()
    np.testing.assert_allclose(
        np.asarray(tree_to_vector(s_shd.global_params)),
        np.asarray(tree_to_vector(s_ref.global_params)), atol=1e-4)
    assert len(s_ref.gi_log) == len(s_shd.gi_log) > 0


def test_server_sharded_empty_and_odd_cohorts():
    """Empty stale cohorts, single stale clients and cohorts smaller than
    the shard count must not crash the bucketing."""
    n = min(len(jax.devices()), 4)
    srv = _tiny_server(make_server_mesh(n))
    slow = srv.schedule.slow_clients
    fast = [i for i in range(srv.n_clients) if i not in slow]
    srv.step(0, fast[:2], [])                       # empty stale cohort
    srv.step(1, [], [(slow[0], 0)])                 # single (odd) stale
    srv.step(2, [fast[0]], [(c, 1) for c in slow])  # 3 stale over n shards
    srv.step(3, [], [])                             # fully empty cohort
    assert len(srv.history) == 5


# --------------------------------------------------------------------------- #
# Sweep runner + cohort specs
# --------------------------------------------------------------------------- #


def test_sweep_runner_merged_json(tmp_path):
    """repro.sweep fans (scenario, seed) pairs and merges bench-v1 rows the
    benchmark compare gate can read."""
    import json

    from repro import sweep
    rc = sweep.main(["--scenario", "degenerate_sync", "--seeds", "2",
                     "--horizon", "2", "--gi-iters", "2",
                     "--mesh", "none", "--out", str(tmp_path)])
    assert rc == 0
    merged = json.loads((tmp_path / "sweep.json").read_text())
    assert merged["schema"] == "bench-v1"
    names = [r["name"] for r in merged["rows"]]
    assert "sweep/degenerate_sync_seed0" in names
    assert "sweep/degenerate_sync_seed1" in names
    assert "sweep/merged_eval" in names
    merged_row = merged["rows"][-1]
    assert merged_row["metrics"]["max_drift"] <= 1e-6
    for seed in (0, 1):
        traj = json.loads(
            (tmp_path / f"trajectory_degenerate_sync_seed{seed}.json")
            .read_text())
        assert traj["summary"]["aggregations"] >= 1
        assert traj["metrics"], "bridge wall-time rows missing"
        assert "step_walls" not in traj   # one-release alias, now removed

    rc = sweep.main(["--scenario", "nope_not_real", "--seeds", "1",
                     "--out", str(tmp_path)])
    assert rc == 2


def test_gi_cohort_specs_lower_with_sharded_engine(gi_setting):
    """launch.specs.gi_cohort_specs matches what the sharded engine
    actually consumes — the stacks lower through the shard_map jit."""
    model, program, bases, stales, keys = gi_setting
    from repro.launch.specs import gi_cohort_specs
    params_shape = jax.eval_shape(lambda: model.init(KEY))
    specs = gi_cohort_specs(params_shape, model.input_shape, model.n_classes,
                            n_rec=6, batch=4, masked=True)
    assert specs["keys"].shape == (4, 2)
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params_shape))
    assert specs["masks"].shape == (4, n_params)
    inv = _inverter(model, program, mesh=make_server_mesh(1))
    fn = inv._get_invert_many_sharded(12, has_mask=False)
    lowered = fn.lower(specs["w_base"], specs["w_base"],
                       (specs["drec_x"], specs["drec_y"]),
                       jax.ShapeDtypeStruct((4,), jnp.int32))
    assert lowered is not None
