"""Fused aggregation round: multi-version delivery equivalence.

The fused round (``FLConfig.fused_step=True``, default) runs a round's whole
stale cohort as ONE multi-version LocalUpdate (per-lane base params gathered
from the ``VersionStore``) and a stacked delta/compensation/FedAvg stage.
The loop round (``fused_step=False``) is the per-client oracle.

Anchors (mirroring the PR 3/4 anchor structure):
* mixed-base-round stale cohorts — including simulator-realized schedules —
  produce BIT-FOR-BIT identical trajectories on matmul models, across every
  strategy, unsharded and on a 1-shard mesh;
* 2/4-shard meshes agree with the unsharded fused trajectory at 1e-4
  (skipped unless the devices are visible — CI's sharded job fabricates 4);
* the VersionStore-backed history is exact through capacity wrap + spill
  (a capacity-3 server replays a capacity-64 server bit for bit);
* the vectorized segment_sum eval equals the historic per-class loop.

Conv models regroup cohorts through CPU conv kernels that differ by ~1 ULP
(the PR 4 caveat), hence the matmul models here; the lenet-based server
suites in tests/test_batched_gi.py and tests/test_sharded_server.py cover
the conv path at their existing tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.client import LocalProgram
from repro.core.disparity import tree_to_vector
from repro.core.gradient_inversion import GIConfig
from repro.core.server import STRATEGIES, FLConfig, Server
from repro.data.partition import (client_label_histograms, dirichlet_partition,
                                  pad_client_shards)
from repro.data.staleness import intertwined_schedule
from repro.data.synthetic import make_feature_dataset
from repro.launch.mesh import make_server_mesh
from repro.models.small import mlp3

N_CLASSES, N_FEATURES = 5, 12


def _server(strategy="ours", fused=True, mesh=None, capacity=64, seed=0,
            **cfg_kw):
    x, y = make_feature_dataset(20, n_classes=N_CLASSES,
                                n_features=N_FEATURES, seed=seed)
    tx, ty = make_feature_dataset(8, n_classes=N_CLASSES,
                                  n_features=N_FEATURES, seed=seed + 99)
    idx = dirichlet_partition(y, 10, alpha=0.1, seed=seed)
    cx, cy, cm = pad_client_shards(x, y, idx, m=16)
    hist = client_label_histograms(y, idx, N_CLASSES)
    sched = intertwined_schedule(hist, 2, n_slow=3, tau=[2, 3, 2])
    prog = LocalProgram(steps=5, lr=0.1, momentum=0.5)
    cfg = FLConfig(strategy=strategy, rounds=0, fused_step=fused,
                   gi=GIConfig(n_rec=8, iters=6, lr=0.1, keep_fraction=0.3),
                   eval_every=4, seed=seed, switch_check_every=2,
                   version_capacity=capacity, **cfg_kw)
    return Server(mlp3(n_features=N_FEATURES, n_classes=N_CLASSES, hidden=24),
                  prog, cfg, cx, cy, cm, sched, tx, ty, mesh=mesh)


def _drive_scattered(srv, rounds=7):
    """Scripted cohorts whose stale deliveries span MULTIPLE distinct base
    rounds per aggregation (incl. repeats and varying fresh cohort sizes) —
    exactly the mixed-version regime the fused round exists for."""
    slow = srv.schedule.slow_clients
    fast = srv.schedule.fast_clients
    for t in range(rounds):
        pairs = []
        if t >= 2:
            pairs = [(slow[0], t - 2), (slow[1], max(0, t - 3)),
                     (slow[2], t - 1)]
        srv.step(t, fast[: 3 + (t % 2)], pairs)
    return srv


def _assert_same_trajectory(a, b, bitwise=True, atol=0.0):
    va = np.asarray(tree_to_vector(a.global_params))
    vb = np.asarray(tree_to_vector(b.global_params))
    if bitwise:
        np.testing.assert_array_equal(va, vb)
        assert len(a.history) == len(b.history)
        for v, (wa, wb) in enumerate(zip(a.history, b.history)):
            for la, lb in zip(jax.tree_util.tree_leaves(wa),
                              jax.tree_util.tree_leaves(wb)):
                assert bool(jnp.array_equal(la, lb)), f"version {v} diverged"
    else:
        np.testing.assert_allclose(va, vb, atol=atol)
    assert [m["gi_iters"] for m in a.metrics] == \
        [m["gi_iters"] for m in b.metrics]
    if bitwise:
        assert a.gi_log == b.gi_log
    else:
        assert [(r["round"], r["client"], r["iters_used"])
                for r in a.gi_log] == \
            [(r["round"], r["client"], r["iters_used"]) for r in b.gi_log]
        np.testing.assert_allclose([r["final_loss"] for r in a.gi_log],
                                   [r["final_loss"] for r in b.gi_log],
                                   atol=atol)


# --------------------------------------------------------------------------- #
# Fused == loop, every strategy, mixed base rounds
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_fused_matches_loop_bitwise_scattered_bases(strategy):
    """Acceptance anchor: the fused round reproduces the grouped
    per-base-round loop path bit-for-bit on mixed-base-round cohorts."""
    srv_f = _drive_scattered(_server(strategy, fused=True))
    srv_l = _drive_scattered(_server(strategy, fused=False))
    _assert_same_trajectory(srv_f, srv_l, bitwise=True)
    # eval rows (incl. per-class accuracies) agree exactly too
    for ra, rb in zip(srv_f.metrics, srv_l.metrics):
        assert ra == rb


def test_fused_matches_loop_round_synchronous():
    """The static-schedule ``round`` path (single shared base round per
    group) agrees too — the degenerate case of the multi-version cohort."""
    srv_f = _server("ours", fused=True)
    srv_l = _server("ours", fused=False)
    for t in range(6):
        srv_f.round(t)
        srv_l.round(t)
    _assert_same_trajectory(srv_f, srv_l, bitwise=True)


def test_fused_one_shard_mesh_bitwise():
    """A 1-device mesh dispatches to the identical single-device fused
    engines — bit-for-bit the mesh=None trajectory (the PR 3 anchor,
    extended to the fused round)."""
    srv_ref = _drive_scattered(_server("ours", fused=True))
    srv_one = _drive_scattered(_server("ours", fused=True,
                                       mesh=make_server_mesh(1)))
    _assert_same_trajectory(srv_ref, srv_one, bitwise=True)


@pytest.mark.parametrize("n_devices", [2, 4])
def test_fused_sharded_matches_unsharded(n_devices):
    """2/4-shard meshes agree with the unsharded fused trajectory at 1e-4
    per coordinate (mixed-base-round cohorts shard on the client axis)."""
    if len(jax.devices()) < n_devices:
        pytest.skip(f"needs {n_devices} devices "
                    f"(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    srv_ref = _drive_scattered(_server("ours", fused=True))
    srv_shd = _drive_scattered(_server("ours", fused=True,
                                       mesh=make_server_mesh(n_devices)))
    _assert_same_trajectory(srv_ref, srv_shd, bitwise=False, atol=1e-4)


# --------------------------------------------------------------------------- #
# Simulator-realized schedules
# --------------------------------------------------------------------------- #


def _sim_run(fused, policy_name="fedbuff"):
    from repro.sim import (FedBuffK, LatencyDist, SemiSyncDeadline, SimEngine,
                           intertwined_fleet)
    from repro.sim.bridge import ServerBridge

    srv = _server("ours", fused=fused)
    x, y = make_feature_dataset(20, n_classes=N_CLASSES,
                                n_features=N_FEATURES, seed=0)
    idx = dirichlet_partition(y, 10, alpha=0.1, seed=0)
    hist = client_label_histograms(y, idx, N_CLASSES)
    fleet = intertwined_fleet(
        hist, 2, n_slow=3,
        slow=LatencyDist("lognormal", 2.2, 0.5),
        fast=LatencyDist("lognormal", 0.4, 0.3),
        network=LatencyDist("fixed", 0.02))
    policy = FedBuffK(4) if policy_name == "fedbuff" else SemiSyncDeadline(1.0)
    eng = SimEngine(fleet, policy, ServerBridge(srv), seed=0, horizon=6.0)
    summary = eng.run()
    return srv, eng, summary


@pytest.mark.parametrize("policy_name", ["fedbuff", "semi_sync"])
def test_fused_matches_loop_under_simulator(policy_name):
    """Simulator-realized arrival schedules (stochastic latencies, cohorts
    mixing base versions arbitrarily) replay bit-for-bit across engines —
    and the event process itself is identical (same trace digest)."""
    srv_f, eng_f, sum_f = _sim_run(True, policy_name)
    srv_l, _, sum_l = _sim_run(False, policy_name)
    assert sum_f["trace_digest"] == sum_l["trace_digest"]
    assert sum_f["aggregations"] == sum_l["aggregations"] > 0
    _assert_same_trajectory(srv_f, srv_l, bitwise=True)
    # the cohorts genuinely scattered base rounds (else this test is vacuous)
    realized = [tau for taus in eng_f.realized.values() for tau in taus]
    assert len(set(realized)) > 1


# --------------------------------------------------------------------------- #
# VersionStore-backed history inside the server
# --------------------------------------------------------------------------- #


def test_small_capacity_spill_replays_large_capacity():
    """A capacity-3 VersionStore (deliveries reach through the spill) must
    replay the capacity-64 trajectory bit for bit — host spill is exact."""
    srv_small = _drive_scattered(_server("w_pred", capacity=3), rounds=10)
    srv_large = _drive_scattered(_server("w_pred", capacity=64), rounds=10)
    np.testing.assert_array_equal(
        np.asarray(tree_to_vector(srv_small.global_params)),
        np.asarray(tree_to_vector(srv_large.global_params)))
    assert srv_small.history.n_spilled > 0
    assert srv_small.history.device_bytes < srv_large.history.device_bytes


def test_history_device_memory_bounded_over_run():
    srv = _server("unweighted", capacity=4)
    baseline = srv.history.device_bytes
    _drive_scattered(srv, rounds=12)
    assert srv.history.device_bytes == baseline
    assert len(srv.history) == 13              # init + 12 aggregations


# --------------------------------------------------------------------------- #
# Vectorized eval
# --------------------------------------------------------------------------- #


def test_vectorized_eval_matches_per_class_loop():
    """The one-pass segment_sum eval equals the historic per-class Python
    loop exactly (sums of 1.0s are exact in float32)."""
    srv = _server("unweighted")

    def reference(params):
        logits = srv.model.apply(params, srv.test_x)
        pred = jnp.argmax(logits, -1)
        acc = jnp.mean((pred == srv.test_y).astype(jnp.float32))
        per_class = []
        for c in range(srv.model.n_classes):
            m = (srv.test_y == c).astype(jnp.float32)
            correct = ((pred == srv.test_y).astype(jnp.float32) * m).sum()
            per_class.append(correct / jnp.maximum(m.sum(), 1.0))
        return acc, jnp.stack(per_class)

    for seed in range(3):
        params = srv.model.init(jax.random.PRNGKey(seed))
        acc_v, pc_v = srv._eval_fn(params)
        acc_r, pc_r = reference(params)
        np.testing.assert_array_equal(np.asarray(acc_v), np.asarray(acc_r))
        np.testing.assert_array_equal(np.asarray(pc_v), np.asarray(pc_r))
    assert pc_v.shape == (N_CLASSES,)


# --------------------------------------------------------------------------- #
# Edge cases
# --------------------------------------------------------------------------- #


def test_fused_empty_and_degenerate_cohorts():
    """Empty cohorts, fresh-only, stale-only and duplicate-client pairs all
    keep version bookkeeping aligned (one history append per step)."""
    srv = _server("ours")
    fast = srv.schedule.fast_clients
    slow = srv.schedule.slow_clients
    srv.step(0, [], [])                          # fully empty
    srv.step(1, fast[:2], [])                    # fresh only
    srv.step(2, [], [(slow[0], 0), (slow[1], 1)])  # stale only, mixed bases
    # duplicate client in pairs: dict semantics (first position, last base)
    srv.step(3, fast[:1], [(slow[0], 1), (slow[0], 2)])
    assert len(srv.history) == 5
    srv_l = _server("ours", fused=False)
    srv_l.step(0, [], [])
    srv_l.step(1, fast[:2], [])
    srv_l.step(2, [], [(slow[0], 0), (slow[1], 1)])
    srv_l.step(3, fast[:1], [(slow[0], 1), (slow[0], 2)])
    _assert_same_trajectory(srv, srv_l, bitwise=True)


def test_delivery_order_mirrors_grouped_dict_semantics():
    order = Server._delivery_order([(7, 3), (2, 1), (5, 3), (2, 4)])
    # grouped emission order: base 3 -> [7, 5], base 1 -> [2], base 4 -> [2]
    # (the duplicate keeps client 2's first delivery position, last base)
    assert order == [(7, 3), (5, 3), (2, 4)]
    assert Server._delivery_order([]) == []
