import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="also run tests marked @pytest.mark.slow (long system/launch "
             "runs); the default tier-1 invocation skips them, i.e. it "
             "behaves like -m 'not slow'")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
