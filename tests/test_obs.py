"""Tests for the unified telemetry layer (repro.obs).

Pins the two contracts the subsystem ships on:

* **zero-cost when disabled** — ``tracer.span`` returns one shared no-op
  singleton and the span fast path allocates nothing, so instrumentation
  can live on the hot paths permanently;
* **neutrality when enabled** — tracing records but never perturbs:
  identical trace digests (heap and vec engines) and identical eval curves
  on a stock scenario with tracing on vs off.

Plus the recording/export layer (span nesting, interning, Chrome trace
structure, JSONL round trip, trajectory-JSON loading, report CLI) and the
server's per-client GI stop-reason telemetry.
"""

import gc
import itertools
import json
import sys

import numpy as np
import pytest

from repro import obs
from repro.obs import NOOP_SPAN, Tracer
from repro.obs import report as obs_report
from repro.sim.scenarios import engine_only


@pytest.fixture
def enabled_tracer():
    """Enable the process-wide tracer for one test, always restoring the
    disabled default (other tests pin the disabled fast path)."""
    obs.configure(enabled=True, reset=True)
    try:
        yield obs.tracer
    finally:
        obs.configure(enabled=False, reset=True)


# --------------------------------------------------------------------------- #
# Disabled fast path
# --------------------------------------------------------------------------- #


def test_disabled_span_is_shared_noop_singleton():
    t = obs.tracer
    assert not t.enabled
    sp = t.span("server.step")
    assert sp is NOOP_SPAN
    assert t.span("anything.else", args={"x": 1}) is NOOP_SPAN
    obj = object()
    assert sp.fence(obj) is obj
    assert sp.arg("bucket", 8) is None
    with sp:
        pass
    # counters/metrics record nothing while disabled
    t.counter("c")
    t.metric("gi_exec", batch=4)
    assert t.counters == {} and t.metrics == [] and len(t) == 0


def test_disabled_span_fast_path_allocates_nothing():
    t = obs.tracer
    assert not t.enabled
    span = t.span            # hot sites bind the method once
    counter = t.counter
    fence = t.fence
    payload = object()
    for _ in itertools.repeat(None, 256):        # warm caches/ints
        with span("warm"):
            counter("n")
            fence(payload)
    deltas = []
    for _ in range(3):
        it = itertools.repeat(None, 10_000)    # allocated before measuring
        gc.collect()
        before = sys.getallocatedblocks()
        for _ in it:
            with span("hot"):
                counter("n")
                fence(payload)
        deltas.append(sys.getallocatedblocks() - before)
    assert min(deltas) <= 0, deltas


# --------------------------------------------------------------------------- #
# Recording: nesting, interning, fences, compile counters
# --------------------------------------------------------------------------- #


def test_span_nesting_interning_and_totals():
    t = Tracer(enabled=True)
    with t.span("outer", args={"round": 0}):
        with t.span("inner") as sp:
            sp.arg("bucket", 8)
        with t.span("inner"):
            pass
    rows = t.spans()
    assert [r["name"] for r in rows] == ["outer", "inner", "inner"]
    assert rows[0]["parent"] == -1
    assert rows[1]["parent"] == 0 and rows[2]["parent"] == 0
    assert all(r["dur_ns"] >= 0 for r in rows)
    assert rows[0]["args"] == {"round": 0}
    assert rows[1]["args"] == {"bucket": 8}
    # both "inner" rows share one interned id
    assert t._name_id.view()[1] == t._name_id.view()[2]
    totals = t.span_totals()
    assert set(totals) == {"outer", "inner"}
    assert totals["outer"] >= totals["inner"] > 0
    # mark() scopes totals to a suffix
    mark = t.mark()
    with t.span("late"):
        pass
    assert set(t.span_totals(mark)) == {"late"}


def test_live_span_fence_returns_value_and_blocks():
    import jax.numpy as jnp
    t = Tracer(enabled=True)
    x = jnp.arange(4.0)
    with t.span("gi.invert") as sp:
        y = sp.fence(x * 2)
    assert np.allclose(np.asarray(y), [0, 2, 4, 6])
    assert t.spans()[0]["dur_ns"] >= 0


def test_metric_rows_and_counters():
    t = Tracer(enabled=True)
    t.metric("cohort", version=3, n_fresh=2, n_stale=5)
    t.counter("waves")
    t.counter("waves", 2)
    (row,) = t.metrics
    assert row["kind"] == "cohort" and row["n_stale"] == 5
    assert row["ts_s"] >= 0
    assert t.counters["waves"] == 3
    t.reset()
    assert t.metrics == [] and t.counters == {} and len(t) == 0


# --------------------------------------------------------------------------- #
# Exporters: Chrome trace + JSONL round trip (incl. legacy aliases)
# --------------------------------------------------------------------------- #


def test_chrome_trace_structure(tmp_path):
    t = Tracer(enabled=True)
    with t.span("sim.run"):
        with t.span("server.step", args={"version": 0}):
            pass
    t.metric("cohort", version=0, n_fresh=1, n_stale=0)
    doc = obs.chrome_trace(t, label="unit")
    assert doc["displayTimeUnit"] == "ms"
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    inst = [e for e in doc["traceEvents"] if e.get("ph") == "i"]
    assert {e["name"] for e in xs} == {"sim.run", "server.step"}
    assert all(e["dur"] > 0 for e in xs)
    (ev,) = inst
    assert ev["name"] == "cohort" and ev["args"]["n_fresh"] == 1
    assert doc["otherData"]["n_spans"] == 2
    path = tmp_path / "trace.json"
    n = obs.write_chrome_trace(t, str(path), label="unit")
    assert n == len(doc["traceEvents"])
    assert "traceEvents" in json.load(open(path))


def test_jsonl_roundtrip(tmp_path):
    rows = [{"kind": "server_step", "version": 0, "wall_s": 0.5},
            {"kind": "wave", "wave": "dispatch", "n": 12}]
    path = tmp_path / "metrics.jsonl"
    assert obs.write_jsonl(rows, str(path)) == 2
    back = obs.read_rows(str(path))
    assert back == rows
    assert obs.rows_of_kind(back, "wave") == [rows[1]]


def test_trajectory_json_loads_combined_rows(tmp_path):
    # a repro.sweep trajectory: kind-tagged "metrics" rows plus untagged
    # per-round "server_metrics" rows, combined by read_rows
    traj = {"scenario": "x", "metrics": [
        {"kind": "server_step", "version": 0, "n_fresh": 2, "n_stale": 1,
         "wall_s": 0.1}],
        "server_metrics": [{"round": 0, "n_fast": 2}]}
    path = tmp_path / "trajectory_x_seed0.json"
    path.write_text(json.dumps(traj))
    rows = obs.read_rows(str(path))
    steps = obs.rows_of_kind(rows, "server_step")
    assert len(steps) == 1 and steps[0]["version"] == 0
    assert obs.rows_of_kind(rows, "server_metric") == [
        {"round": 0, "n_fast": 2, "kind": "server_metric"}]

    # the one-release "step_walls" alias is gone: a step_walls-only doc no
    # longer resolves to rows
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({"step_walls": [{"version": 0}]}))
    with pytest.raises(ValueError):
        obs.read_rows(str(stale))


def test_report_cli_renders_all_formats(tmp_path, capsys):
    t = Tracer(enabled=True)
    with t.span("server.step"):
        pass
    t.metric("server_step", version=0, n_fresh=1, n_stale=2,
             n_base_rounds=2, wall_s=0.25, gi_iters=4, gi_occupancy=0.5)
    t.metric("aggregation", version=0, time=1.0, n_fresh=1, n_stale=2,
             n_base_rounds=2, mean_tau=1.5, tau_hist=[1, 1, 1])
    trace = tmp_path / "trace.json"
    jsonl = tmp_path / "metrics.jsonl"
    obs.write_chrome_trace(t, str(trace))
    obs.write_jsonl(t.metrics, str(jsonl))
    traj = tmp_path / "trajectory.json"
    traj.write_text(json.dumps({
        "metrics": [{"kind": "server_step", "version": 0, "n_fresh": 1,
                     "n_stale": 2, "wall_s": 0.25}],
        "server_metrics": [{"round": 0, "acc": 0.5}]}))
    for path in (trace, jsonl, traj):
        assert obs_report.main([str(path)]) == 0
        out = capsys.readouterr().out
        assert "round" in out and "wall_ms" in out
        assert "250.0" in out                  # wall_s rendered in ms
    assert obs_report.main([str(tmp_path / "missing.json")]) == 2


# --------------------------------------------------------------------------- #
# Neutrality: tracing on vs off changes nothing observable
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("engine", ["heap", "vec"])
def test_tracing_neutral_engine_digest(engine):
    base = engine_only("fedbuff_k4", seed=0, engine=engine)
    base.run()
    obs.configure(enabled=True, reset=True)
    try:
        traced = engine_only("fedbuff_k4", seed=0, engine=engine)
        traced.run()
        assert len(obs.tracer) > 0              # spans actually recorded
        aggs = obs.rows_of_kind(obs.tracer.metrics, "aggregation")
        assert len(aggs) == traced.counters["aggregations"]
        assert all("tau_hist" in r for r in aggs)
    finally:
        obs.configure(enabled=False, reset=True)
    assert traced.trace_digest() == base.trace_digest()
    assert traced.counters == base.counters


def test_tracing_neutral_server_trajectory_and_bridge_rows():
    """Full stack (vec engine + real Server): tracing on vs off yields the
    identical digest, eval curve, and final accuracy — and the traced run's
    bridge rows carry the obs-metrics-v1 schema with span breakdowns."""
    from repro.sim import scenarios

    def run_once():
        run = scenarios.build("fedbuff_k4", seed=0, horizon=3, gi_iters=2)
        summary = run.run()
        return run, summary

    run_off, off = run_once()
    obs.configure(enabled=True, reset=True)
    try:
        run_on, on = run_once()
        rows = run_on.engine.aggregator.rows
        assert rows and all(r["kind"] == "server_step" for r in rows)
        assert any(r.get("spans") for r in rows)
        assert any("server.step" in (r.get("spans") or {}) for r in rows)
        stream = obs.rows_of_kind(obs.tracer.metrics, "server_step")
        assert len(stream) == len(rows)
        assert obs.rows_of_kind(obs.tracer.metrics, "cohort")
        # nested sim -> step -> GI spans all present
        names = {s["name"] for s in obs.tracer.spans()}
        assert {"sim.run", "sim.aggregate", "server.step"} <= names
    finally:
        obs.configure(enabled=False, reset=True)
    assert on["trace_digest"] == off["trace_digest"]
    assert on["final_acc"] == off["final_acc"]
    assert run_on.engine.evals == run_off.engine.evals
    # the untraced run's bridge rows share the same schema, just no spans
    off_rows = run_off.engine.aggregator.rows
    assert off_rows and all(r["kind"] == "server_step" for r in off_rows)
    assert not any(r.get("spans") for r in off_rows)
    # server-side GI accounting is telemetry-independent
    assert on["server"]["gi"] == off["server"]["gi"]


# --------------------------------------------------------------------------- #
# Server GI telemetry: per-client iteration counts + early-stop reasons
# --------------------------------------------------------------------------- #


def _gi_server(tol):
    from repro.core.client import LocalProgram
    from repro.core.gradient_inversion import GIConfig
    from repro.core.server import FLConfig, Server
    from repro.data.partition import (client_label_histograms,
                                      dirichlet_partition, pad_client_shards)
    from repro.data.staleness import intertwined_schedule
    from repro.data.synthetic import make_feature_dataset
    from repro.models.small import mlp3

    x, y = make_feature_dataset(20, n_classes=3, n_features=8, seed=0)
    tx, ty = make_feature_dataset(8, n_classes=3, n_features=8, seed=99)
    idx = dirichlet_partition(y, 6, alpha=0.5, seed=0)
    cx, cy, cm = pad_client_shards(x, y, idx, m=12)
    hist = client_label_histograms(y, idx, 3)
    sched = intertwined_schedule(hist, 1, n_slow=2, tau=2)
    prog = LocalProgram(steps=2, lr=0.1, momentum=0.5)
    cfg = FLConfig(strategy="ours", rounds=0,
                   gi=GIConfig(n_rec=4, iters=5, lr=0.1, tol=tol),
                   uniqueness_check=False, eval_every=10_000, seed=0)
    return Server(mlp3(n_features=8, n_classes=3, hidden=16), prog, cfg,
                  cx, cy, cm, sched, tx, ty)


@pytest.mark.parametrize("tol,reason", [(0.0, "budget"), (1e9, "tol")])
def test_gi_stop_reason_telemetry(tol, reason):
    srv = _gi_server(tol)
    slow = srv.schedule.slow_clients
    srv.step(0, [c for c in range(6) if c not in slow][:2], [])
    srv.step(1, [], [(c, 0) for c in slow])
    gi_rows = [r for r in srv.gi_log]
    assert gi_rows and all(r["stop"] == reason for r in gi_rows)
    if reason == "budget":
        assert all(r["iters_used"] == 5 for r in gi_rows)
    else:
        assert all(r["iters_used"] < 5 for r in gi_rows)
    # cross-round accumulators + summary() surface the same accounting
    assert srv.gi_stop_counts[reason] == len(gi_rows)
    other = "tol" if reason == "budget" else "budget"
    assert srv.gi_stop_counts[other] == 0
    s = srv.summary()
    assert s["strategy"] == "ours"
    assert s["gi"]["stop_reasons"][reason] == len(gi_rows)
    assert s["gi"]["clients_inverted"] == len(slow)
    assert set(s["gi"]["per_client_iters"]) == set(int(c) for c in slow)
    assert s["gi"]["total_iters"] == sum(r["iters_used"] for r in gi_rows)
    assert all(v == 1 for v in s["gi"]["per_client_calls"].values())
    assert s["gi"]["last"]["stops"] == [reason] * len(slow)
