"""Tests for the GI engine — the paper's core mechanism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.client import LocalProgram, make_local_update
from repro.core.disparity import cosine_distance, l1_disparity, tree_sub
from repro.core.gradient_inversion import GIConfig, GradientInverter
from repro.core.sparsify import topk_mask
from repro.core import compensation
from repro.models.small import mlp3

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setting():
    """A small FL setting: model, program, a client's data + stale update."""
    model = mlp3(n_features=8, n_classes=3, hidden=16)
    program = LocalProgram(steps=5, lr=0.1, momentum=0.5)
    w0 = model.init(KEY)
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    # class-structured client data
    means = jax.random.normal(jax.random.PRNGKey(2), (3, 8)) * 2
    y = jax.random.randint(ky, (24,), 0, 3)
    x = means[y] + 0.3 * jax.random.normal(kx, (24, 8))
    lu = make_local_update(model.apply, program)
    w_stale, _ = lu(w0, x, y)
    return model, program, w0, x, y, w_stale


def test_gi_reduces_disparity(setting):
    model, program, w0, x, y, w_stale = setting
    inv = GradientInverter(model.apply, model.input_shape, model.n_classes,
                           program, GIConfig(n_rec=12, iters=60, lr=0.1))
    drec, info = inv.invert(w0, w_stale, KEY)
    assert info["losses"][-1] < info["losses"][0] * 0.8, info["losses"]


def test_gi_estimate_tracks_true_update(setting):
    """hat{w}^t from D_rec must be closer to the true unstale update than the
    raw stale update under staleness (the paper's Fig. 4 claim)."""
    model, program, w0, x, y, w_stale = setting
    lu = make_local_update(model.apply, program)
    # simulate staleness: global model advanced tau steps on other data
    kx2, ky2 = jax.random.split(jax.random.PRNGKey(3))
    other_x = jax.random.normal(kx2, (24, 8))
    other_y = jax.random.randint(ky2, (24,), 0, 3)
    w_now = w0
    for _ in range(8):
        w_now, _ = lu(w_now, other_x, other_y)

    inv = GradientInverter(model.apply, model.input_shape, model.n_classes,
                           program, GIConfig(n_rec=12, iters=150, lr=0.1))
    drec, _ = inv.invert(w0, w_stale, KEY)
    w_hat = inv.estimate_unstale(w_now, drec)
    w_true, _ = lu(w_now, x, y)

    e_hat = float(cosine_distance(tree_sub(w_hat, w_now), tree_sub(w_true, w_now)))
    e_stale = float(cosine_distance(tree_sub(w_stale, w0), tree_sub(w_true, w_now)))
    assert e_hat < e_stale, (e_hat, e_stale)


def test_gi_beats_first_order_at_high_staleness(setting):
    """Fig. 4: under large staleness GI compensation < 1st-order error."""
    model, program, w0, x, y, w_stale = setting
    lu = make_local_update(model.apply, program)
    kx2, ky2 = jax.random.split(jax.random.PRNGKey(3))
    other_x = jax.random.normal(kx2, (24, 8))
    other_y = jax.random.randint(ky2, (24,), 0, 3)
    w_now = w0
    for _ in range(12):   # large staleness
        w_now, _ = lu(w_now, other_x, other_y)
    w_true, _ = lu(w_now, x, y)
    true_delta = tree_sub(w_true, w_now)

    inv = GradientInverter(model.apply, model.input_shape, model.n_classes,
                           program, GIConfig(n_rec=12, iters=150, lr=0.1))
    drec, _ = inv.invert(w0, w_stale, KEY)
    w_hat = inv.estimate_unstale(w_now, drec)
    e_gi = float(l1_disparity(tree_sub(w_hat, w_now), true_delta))

    fo = compensation.first_order(tree_sub(w_stale, w0), w_now, w0)
    e_fo = float(l1_disparity(fo, true_delta))
    assert e_gi < e_fo, (e_gi, e_fo)


def test_gi_sparsified_still_converges(setting):
    model, program, w0, x, y, w_stale = setting
    mask = topk_mask(tree_sub(w_stale, w0), 0.05)
    inv = GradientInverter(model.apply, model.input_shape, model.n_classes,
                           program, GIConfig(n_rec=12, iters=60, lr=0.1,
                                             keep_fraction=0.05))
    drec, info = inv.invert(w0, w_stale, KEY, mask=mask)
    assert info["losses"][-1] < info["losses"][0], info["losses"]


def test_gi_warm_start_fewer_iterations(setting):
    """Table 5: warm-starting from the previous round's D_rec starts at a
    lower loss than a cold start."""
    model, program, w0, x, y, w_stale = setting
    inv = GradientInverter(model.apply, model.input_shape, model.n_classes,
                           program, GIConfig(n_rec=12, iters=80, lr=0.1))
    drec, info_cold = inv.invert(w0, w_stale, KEY)
    _, info_warm = inv.invert(w0, w_stale, KEY, init=drec, iters=10)
    assert info_warm["losses"][0] < info_cold["losses"][0]


def test_gi_labels_are_soft(setting):
    """Privacy: recovered labels are soft logits, never hard classes."""
    model, program, w0, x, y, w_stale = setting
    inv = GradientInverter(model.apply, model.input_shape, model.n_classes,
                           program, GIConfig(n_rec=8, iters=20, lr=0.1))
    (xr, yr), _ = inv.invert(w0, w_stale, KEY)
    assert yr.shape == (8, 3) and jnp.issubdtype(yr.dtype, jnp.floating)
    assert xr.shape == (8, 8)


def test_gi_no_individual_sample_recovery(setting):
    """Privacy claim (§3.4): recovered samples should not match any original
    sample closely (distribution-level recovery only)."""
    model, program, w0, x, y, w_stale = setting
    inv = GradientInverter(model.apply, model.input_shape, model.n_classes,
                           program, GIConfig(n_rec=12, iters=100, lr=0.1))
    (xr, _), _ = inv.invert(w0, w_stale, KEY)
    # min pairwise distance between any recovered and any true sample stays
    # far above the intra-data nearest-neighbour scale
    d_cross = jnp.min(jnp.linalg.norm(xr[:, None] - x[None], axis=-1))
    d_intra = jnp.partition(
        jnp.linalg.norm(x[:, None] - x[None], axis=-1) + jnp.eye(24) * 1e9,
        1, axis=-1)[:, 0].mean()
    assert float(d_cross) > 0.5 * float(d_intra)
