"""Unit tests for the FL core (the paper's contribution)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, compensation, tiers
from repro.core.client import LocalProgram, make_local_update, soft_ce_loss
from repro.core.disparity import (cosine_distance, l1_disparity, tree_sub,
                                  tree_to_vector, vector_to_tree)
from repro.core.sparsify import WarmStartCache, topk_mask
from repro.core.switching import SwitchMonitor
from repro.core.uniqueness import is_unique, uniqueness_threshold
from repro.models.small import lenet, mlp3

KEY = jax.random.PRNGKey(0)


def small_tree(seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    return {"a": jax.random.normal(k1, (4, 3)) * scale,
            "b": {"c": jax.random.normal(k2, (5,)) * scale}}


# --------------------------------------------------------------------------- #
# Disparity metrics
# --------------------------------------------------------------------------- #


def test_tree_vector_roundtrip():
    t = small_tree()
    v = tree_to_vector(t)
    t2 = vector_to_tree(v, t)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(t2)):
        np.testing.assert_allclose(a, b)


def test_cosine_distance_bounds_and_identity():
    t = small_tree()
    assert abs(float(cosine_distance(t, t))) < 1e-6
    neg = jax.tree_util.tree_map(lambda x: -x, t)
    np.testing.assert_allclose(float(cosine_distance(t, neg)), 2.0, atol=1e-5)
    other = small_tree(seed=1)
    d = float(cosine_distance(t, other))
    assert 0.0 <= d <= 2.0


def test_l1_disparity_masked():
    a = {"x": jnp.array([1.0, 2.0, 3.0, 4.0])}
    b = {"x": jnp.array([0.0, 0.0, 0.0, 0.0])}
    mask = jnp.array([True, False, False, True])
    np.testing.assert_allclose(float(l1_disparity(a, b, mask)), 2.5)
    np.testing.assert_allclose(float(l1_disparity(a, b)), 2.5)


# --------------------------------------------------------------------------- #
# LocalUpdate
# --------------------------------------------------------------------------- #


def test_local_update_runs_and_reduces_loss():
    model = mlp3(n_features=8, n_classes=3, hidden=16)
    params = model.init(KEY)
    x = jax.random.normal(KEY, (12, 8))
    y = jax.random.randint(KEY, (12,), 0, 3)
    lu = make_local_update(model.apply, LocalProgram(steps=20, lr=0.2))
    new_params, losses = lu(params, x, y)
    assert float(losses[-1]) < float(losses[0])
    assert float(l1_disparity(new_params, params)) > 0


def test_local_update_differentiable_in_data():
    """GI depends on d LocalUpdate / d data existing and being nonzero."""
    model = mlp3(n_features=4, n_classes=2, hidden=8)
    params = model.init(KEY)
    lu = make_local_update(model.apply, LocalProgram(steps=3, lr=0.1))

    def objective(x):
        y = jnp.zeros((x.shape[0], 2))
        w, _ = lu(params, x, y)
        return l1_disparity(w, params)

    g = jax.grad(objective)(jax.random.normal(KEY, (6, 4)))
    assert float(jnp.abs(g).sum()) > 0


def test_soft_ce_matches_hard_ce():
    model = mlp3(n_features=4, n_classes=3, hidden=8)
    params = model.init(KEY)
    x = jax.random.normal(KEY, (5, 4))
    y_hard = jnp.array([0, 1, 2, 1, 0])
    # soft logits strongly peaked at the hard labels
    y_soft = jax.nn.one_hot(y_hard, 3) * 100.0
    l_hard = soft_ce_loss(model.apply, params, x, y_hard)
    l_soft = soft_ce_loss(model.apply, params, x, y_soft)
    np.testing.assert_allclose(float(l_hard), float(l_soft), rtol=1e-4)


def test_fedprox_pulls_toward_global():
    model = mlp3(n_features=4, n_classes=2, hidden=8)
    params = model.init(KEY)
    x = jax.random.normal(KEY, (6, 4))
    y = jax.random.randint(KEY, (6,), 0, 2)
    plain = make_local_update(model.apply, LocalProgram(steps=10, lr=0.2,
                                                        optimizer="sgdm"))
    prox = make_local_update(model.apply, LocalProgram(steps=10, lr=0.2,
                                                       optimizer="fedprox",
                                                       fedprox_mu=10.0))
    w_plain, _ = plain(params, x, y)
    w_prox, _ = prox(params, x, y)
    # strong mu keeps the proximal update closer to the global model
    assert float(l1_disparity(w_prox, params)) < float(l1_disparity(w_plain, params))


# --------------------------------------------------------------------------- #
# Aggregation / compensation / tiers
# --------------------------------------------------------------------------- #


def test_fedavg_weighted_mean():
    u1 = {"w": jnp.ones((3,))}
    u2 = {"w": 3 * jnp.ones((3,))}
    agg = aggregation.fedavg([u1, u2], [1.0, 3.0])
    np.testing.assert_allclose(agg["w"], 2.5)
    agg_eq = aggregation.fedavg([u1, u2])
    np.testing.assert_allclose(agg_eq["w"], 2.0)


def test_staleness_weight_decay():
    w0 = compensation.staleness_weight(0)
    w10 = compensation.staleness_weight(10)
    w100 = compensation.staleness_weight(100)
    assert w0 > 0.9 and abs(w10 - 0.5) < 1e-6 and w100 < 1e-6


def test_first_order_identity_when_global_unchanged():
    u = small_tree()
    w = small_tree(seed=2)
    out = compensation.first_order(u, w, w)
    for a, b in zip(jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(u)):
        np.testing.assert_allclose(a, b)


def test_w_pred_linear_extrapolation():
    h0 = {"w": jnp.zeros(3)}
    h1 = {"w": jnp.ones(3)}
    pred = compensation.predict_future_global([h0, h1], tau=3)
    np.testing.assert_allclose(pred["w"], 4.0)


def test_tier_clustering_separates_staleness():
    staleness = [0, 0, 0, 0, 40, 50]
    t = tiers.cluster_tiers(staleness, n_tiers=2)
    assert sorted(map(len, t)) == [2, 4]
    fast = max(t, key=len)
    assert all(staleness[i] == 0 for i in fast)


def test_cluster_tiers_tied_gaps_deterministic():
    # all gaps equal: the stable sort must cut at the EARLIEST positions on
    # every platform (the old argsort[::-1] picked platform-dependent ones)
    t = tiers.cluster_tiers([0, 10, 20, 30], n_tiers=2)
    assert t == [[0], [1, 2, 3]]
    t3 = tiers.cluster_tiers([0, 10, 20, 30], n_tiers=3)
    assert t3 == [[0], [1], [2, 3]]


def test_cluster_tiers_all_equal_taus():
    assert tiers.cluster_tiers([5, 5, 5], n_tiers=3) == [[0, 1, 2]]


def test_cluster_tiers_more_tiers_than_levels():
    # only 2 distinct levels: never split equal-tau clients to fill tiers
    t = tiers.cluster_tiers([0, 0, 7, 7], n_tiers=3)
    assert t == [[0, 1], [2, 3]]
    t = tiers.cluster_tiers([0, 0, 0, 5, 5], n_tiers=4)
    assert t == [[0, 1, 2], [3, 4]]


def test_tiered_aggregate_shape():
    ups = [small_tree(i) for i in range(4)]
    agg = tiers.tiered_aggregate(ups, [0, 0, 10, 10], [1, 1, 1, 1], 2)
    assert agg["a"].shape == (4, 3)


# --------------------------------------------------------------------------- #
# Sparsify / uniqueness / switching
# --------------------------------------------------------------------------- #


def test_topk_mask_selects_largest():
    u = {"w": jnp.array([0.1, -5.0, 0.2, 3.0, -0.05])}
    m = topk_mask(u, 0.4)  # keep top-2
    np.testing.assert_array_equal(np.asarray(m), [False, True, False, True, False])
    m_all = topk_mask(u, 1.0)
    assert bool(m_all.all())


def test_warm_start_cache():
    c = WarmStartCache()
    assert 3 not in c
    c.put(3, jnp.ones((2,)), jnp.zeros((2, 4)))
    assert 3 in c
    x, y = c.get(3)
    assert x.shape == (2,)
    c.drop(3)
    assert 3 not in c


def test_uniqueness_detection():
    # unstale updates clustered; stale update orthogonal -> unique
    base = np.zeros(50, np.float32)
    base[0] = 1.0
    unstale = []
    rng = np.random.RandomState(0)
    for i in range(5):
        v = base + 0.05 * rng.randn(50).astype(np.float32)
        unstale.append({"w": jnp.asarray(v)})
    ortho = np.zeros(50, np.float32)
    ortho[10] = 1.0
    unique, info = is_unique({"w": jnp.asarray(ortho)}, unstale)
    assert unique and info["min_dist"] > info["threshold"]
    # a clone of the cluster is NOT unique
    dup, _ = is_unique(unstale[0], unstale[1:])
    assert not dup


def test_switch_monitor_switches_and_decays():
    mon = SwitchMonitor(metric="l1", decay_fraction=0.1, consecutive_needed=2)
    good = {"w": jnp.zeros(4)}
    bad = {"w": jnp.ones(4)}
    true_w = {"w": jnp.zeros(4)}
    # E1 (hat vs true) < E2: no switch
    mon.observe(10, good, bad, true_w)
    assert not mon.switched and mon.gamma(10) == 1.0
    # now hat is worse than stale twice -> switch at t=100
    mon.observe(90, bad, good, true_w)
    mon.observe(100, bad, good, true_w)
    assert mon.switched and mon.switched_at == 100
    assert mon.gamma(100) == 1.0
    assert 0.0 < mon.gamma(105) < 1.0
    assert mon.gamma(111) == 0.0
