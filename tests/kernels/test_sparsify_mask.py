"""Sweep: Pallas sparsify-mask kernel vs oracle + threshold semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.sparsify_mask import (sparsify_mask,
                                         sparsify_mask_reference,
                                         topk_threshold)

KEY = jax.random.PRNGKey(17)


@pytest.mark.parametrize("n", [128, 1000, 4096, 70001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mask_matches_reference(n, dtype):
    u = jax.random.normal(KEY, (n,), jnp.float32).astype(dtype)
    t = jnp.asarray(0.5, jnp.float32)
    out = sparsify_mask(u, t)
    ref = sparsify_mask_reference(u, t)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("frac", [0.01, 0.05, 0.5])
def test_topk_threshold_keeps_expected_fraction(frac):
    u = jax.random.normal(KEY, (20_000,))
    t = topk_threshold(u, frac)
    kept = int((jnp.abs(u) >= t).sum())
    expect = round(20_000 * frac)
    assert abs(kept - expect) <= max(2, int(0.01 * expect))


def test_masked_vector_sparsity_pattern():
    u = jax.random.normal(KEY, (5000,))
    t = topk_threshold(u, 0.05)
    out = np.asarray(sparsify_mask(u, t))
    nz = out != 0
    mags = np.abs(np.asarray(u))
    assert mags[nz].min() >= mags[~nz].max() - 1e-6


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_sharded_batch_mask_matches_unsharded(n_devices):
    """The per-shard grid (jnp fallback on CPU shards) produces booleans
    identical to the single-launch batched kernel — thresholds are row-local
    so sharding the cohort axis must not change a single bit."""
    from repro.kernels.sparsify_mask import (topk_binary_mask_batch,
                                             topk_binary_mask_batch_sharded)
    from repro.launch.mesh import make_server_mesh
    if len(jax.devices()) < n_devices:
        pytest.skip(f"needs {n_devices} devices "
                    f"(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    u2 = jax.random.normal(KEY, (4, 6000))
    ref = np.asarray(topk_binary_mask_batch(jnp.abs(u2), 0.05))
    got = np.asarray(topk_binary_mask_batch_sharded(
        u2, 0.05, make_server_mesh(n_devices)))
    np.testing.assert_array_equal(got, ref)


def test_sharded_batch_mask_rejects_indivisible_rows():
    from repro.kernels.sparsify_mask import topk_binary_mask_batch_sharded
    from repro.launch.mesh import make_server_mesh
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices")
    u2 = jax.random.normal(KEY, (3, 512))
    with pytest.raises(ValueError, match="not a multiple"):
        topk_binary_mask_batch_sharded(u2, 0.05, make_server_mesh(2))
