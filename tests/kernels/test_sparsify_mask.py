"""Sweep: Pallas sparsify-mask kernel vs oracle + threshold semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.sparsify_mask import (sparsify_mask,
                                         sparsify_mask_reference,
                                         topk_threshold)

KEY = jax.random.PRNGKey(17)


@pytest.mark.parametrize("n", [128, 1000, 4096, 70001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mask_matches_reference(n, dtype):
    u = jax.random.normal(KEY, (n,), jnp.float32).astype(dtype)
    t = jnp.asarray(0.5, jnp.float32)
    out = sparsify_mask(u, t)
    ref = sparsify_mask_reference(u, t)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("frac", [0.01, 0.05, 0.5])
def test_topk_threshold_keeps_expected_fraction(frac):
    u = jax.random.normal(KEY, (20_000,))
    t = topk_threshold(u, frac)
    kept = int((jnp.abs(u) >= t).sum())
    expect = round(20_000 * frac)
    assert abs(kept - expect) <= max(2, int(0.01 * expect))


def test_masked_vector_sparsity_pattern():
    u = jax.random.normal(KEY, (5000,))
    t = topk_threshold(u, 0.05)
    out = np.asarray(sparsify_mask(u, t))
    nz = out != 0
    mags = np.abs(np.asarray(u))
    assert mags[nz].min() >= mags[~nz].max() - 1e-6
