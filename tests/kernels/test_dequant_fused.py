"""Dequant-fused disparity terms: the kernels (interpret mode) and jnp
fallbacks consume an int8 payload + per-tile scales directly; forward AND
gradients must match dequantizing to fp32 first and running the concat
oracle — on sizes that do and don't divide the 128-lane tile grid."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.disparity import l1_disparity, masked_cosine_distance
from repro.core.quantize import QuantizedTree, quantize_flat
from repro.kernels.fused_disparity import (
    cosine_distance_dequant_reference, l1_disparity_dequant_reference,
    masked_cosine_terms_dq, masked_l1_terms_dq)

KEY = jax.random.PRNGKey(31)


def _tree(sizes, seed=0):
    k = jax.random.PRNGKey(seed)
    return {f"l{i}": jax.random.normal(jax.random.fold_in(k, i), (n,))
            for i, n in enumerate(sizes)}


def _quantize_tree(tree, bits=8, tile=128):
    """Host-quantize a pytree into an unbatched QuantizedTree payload."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    qs, ss, shapes = [], [], []
    for l in leaves:
        q, s = quantize_flat(np.asarray(l).reshape(-1), bits, tile)
        qs.append(jnp.asarray(q))
        ss.append(jnp.asarray(s))
        shapes.append(tuple(l.shape))
    return QuantizedTree(qs, ss, bits, tile, treedef, shapes)


# aligned, non-multiple-of-128, non-multiple-of-tile, tiny (always jnp)
SIZES = [(4096,), (1000, 4097), (130,), (256 * 128, 5000, 7)]


@pytest.mark.parametrize("sizes", SIZES)
@pytest.mark.parametrize("masked", [False, True])
def test_l1_dq_kernel_and_fallback_match_reference(sizes, masked):
    a = _tree(sizes)
    qt = _quantize_tree(_tree(sizes, seed=1))
    n = sum(sizes)
    mask = ((jax.random.uniform(KEY, (n,)) > 0.4) if masked else None)
    want = l1_disparity_dequant_reference(a, qt, mask)
    s, c = masked_l1_terms_dq(a, qt, mask, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(s / jnp.maximum(c, 1.0)),
                               np.asarray(want), rtol=1e-6)
    s2, c2 = masked_l1_terms_dq(a, qt, mask, use_kernel=False)
    np.testing.assert_allclose(np.asarray(s2 / jnp.maximum(c2, 1.0)),
                               np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("sizes", SIZES)
@pytest.mark.parametrize("masked", [False, True])
def test_cos_dq_kernel_and_fallback_match_reference(sizes, masked):
    a = _tree(sizes, seed=4)
    qt = _quantize_tree(_tree(sizes, seed=5))
    n = sum(sizes)
    mask = ((jax.random.uniform(KEY, (n,)) > 0.4) if masked else None)
    want = cosine_distance_dequant_reference(a, qt, mask)
    dot, na2, nb2 = masked_cosine_terms_dq(a, qt, mask, use_kernel=True,
                                           interpret=True)
    got = 1.0 - dot / jnp.maximum(jnp.sqrt(na2) * jnp.sqrt(nb2), 1e-12)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("masked", [False, True])
def test_l1_dq_grad_parity(masked, use_kernel):
    """custom_vjp backward (recompute a - q*s) == autodiff of the
    dequant-then-concat oracle; int8 payload leaves take float0
    cotangents, so grad(a) is the only one requested."""
    a = _tree((5000, 333), seed=7)
    qt = _quantize_tree(_tree((5000, 333), seed=8))
    mask = ((jax.random.uniform(KEY, (5333,)) > 0.5) if masked else None)

    def fused(t):
        s, c = masked_l1_terms_dq(t, qt, mask, use_kernel=use_kernel,
                                  interpret=use_kernel)
        return s / jnp.maximum(c, 1.0)

    g = jax.grad(fused)(a)
    g_ref = jax.grad(
        lambda t: l1_disparity_dequant_reference(t, qt, mask))(a)
    for k in g:
        np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_ref[k]),
                                   rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("masked", [False, True])
def test_cos_dq_grad_parity(masked, use_kernel):
    a = _tree((4097, 200), seed=9)
    qt = _quantize_tree(_tree((4097, 200), seed=10))
    mask = ((jax.random.uniform(KEY, (4297,)) > 0.5) if masked else None)

    def fused(t):
        dot, na2, nb2 = masked_cosine_terms_dq(
            t, qt, mask, use_kernel=use_kernel, interpret=use_kernel)
        return 1.0 - dot / jnp.maximum(jnp.sqrt(na2) * jnp.sqrt(nb2),
                                       1e-12)

    g = jax.grad(fused)(a)
    g_ref = jax.grad(
        lambda t: cosine_distance_dequant_reference(t, qt, mask))(a)
    for k in g:
        np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_ref[k]),
                                   rtol=1e-4, atol=1e-7)


def test_nondefault_tile_takes_fallback_and_matches():
    """tile != 128 can't map onto the kernel lanes — the dq terms must
    silently take the exact jnp fallback even with use_kernel=True."""
    a = _tree((5000,), seed=11)
    qt = _quantize_tree(_tree((5000,), seed=12), tile=64)
    want = l1_disparity_dequant_reference(a, qt)
    s, c = masked_l1_terms_dq(a, qt, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(s / jnp.maximum(c, 1.0)),
                               np.asarray(want), rtol=1e-6)


def test_disparity_dispatch_on_quantized_payload():
    """core.disparity's public metrics accept a QuantizedTree second
    argument and equal their fp32 forms on the dequantized tree."""
    a = _tree((1000, 300), seed=13)
    qt = _quantize_tree(_tree((1000, 300), seed=14))
    np.testing.assert_allclose(
        np.asarray(l1_disparity(a, qt)),
        np.asarray(l1_disparity(a, qt.to_tree())), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(masked_cosine_distance(a, qt)),
        np.asarray(masked_cosine_distance(a, qt.to_tree())), rtol=1e-5)


def test_vmap_over_payload_rows():
    """A stacked (B, n) payload vmaps row-wise: vmapped value_and_grad
    equals the per-row loop — the GI while_loop's consumption shape."""
    B, sizes = 3, (600, 137)
    rows_a = [_tree(sizes, seed=20 + b) for b in range(B)]
    rows_q = [_quantize_tree(_tree(sizes, seed=30 + b)) for b in range(B)]
    a = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *rows_a)
    qt = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *rows_q)

    def loss(a_row, qt_row):
        return l1_disparity(a_row, qt_row)

    vals, grads = jax.jit(jax.vmap(jax.value_and_grad(loss)))(a, qt)
    for b in range(B):
        want_v, want_g = jax.value_and_grad(loss)(rows_a[b], rows_q[b])
        np.testing.assert_allclose(np.asarray(vals[b]), np.asarray(want_v),
                                   rtol=1e-6)
        for k in want_g:
            np.testing.assert_allclose(np.asarray(grads[k][b]),
                                       np.asarray(want_g[k]), rtol=1e-5,
                                       atol=1e-8)
