"""Shape sweep: Pallas decode attention kernel vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gqa_decode import gqa_decode_attention, gqa_decode_reference

KEY = jax.random.PRNGKey(13)


def _mk(B, H, KV, D, S, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("B,H,KV,D,S,valid", [
    (1, 4, 4, 32, 128, 128),
    (2, 8, 2, 64, 256, 200),
    (1, 4, 1, 32, 100, 37),      # uneven cache, partial fill
    (4, 2, 2, 128, 64, 64),
])
def test_decode_matches_reference(B, H, KV, D, S, valid):
    q, k, v = _mk(B, H, KV, D, S)
    out = gqa_decode_attention(q, k, v, jnp.array(valid, jnp.int32), bk=32)
    ref = gqa_decode_reference(q, k, v, valid)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [8, 32])
def test_decode_sliding_window(window):
    q, k, v = _mk(2, 4, 2, 32, 128)
    out = gqa_decode_attention(q, k, v, jnp.array(100, jnp.int32),
                               window=window, bk=32)
    ref = gqa_decode_reference(q, k, v, 100, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_decode_valid_len_dynamic():
    """Same compiled kernel must honour different valid lengths."""
    q, k, v = _mk(1, 2, 2, 32, 64)
    o1 = gqa_decode_attention(q, k, v, jnp.array(10, jnp.int32), bk=32)
    o2 = gqa_decode_attention(q, k, v, jnp.array(60, jnp.int32), bk=32)
    assert float(jnp.abs(o1 - o2).max()) > 1e-4
    np.testing.assert_allclose(o1, gqa_decode_reference(q, k, v, 10),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(o2, gqa_decode_reference(q, k, v, 60),
                               atol=2e-5, rtol=2e-5)
