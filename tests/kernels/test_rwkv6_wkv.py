"""Shape/dtype sweep: Pallas WKV6 kernel vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rwkv6_wkv import wkv6, wkv6_reference

KEY = jax.random.PRNGKey(11)


def _mk(B, T, H, N, dtype=jnp.float32):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, T, H, N), jnp.float32).astype(dtype) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, N), jnp.float32).astype(dtype) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, N), jnp.float32).astype(dtype) * 0.5
    # decay in (0, 1) as the Finch parameterization guarantees
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N))) * 0.5 + 0.45
         ).astype(dtype)
    u = (jax.random.normal(ks[4], (H, N)) * 0.1).astype(dtype)
    return r, k, v, w, u


@pytest.mark.parametrize("B,T,H,N,chunk", [
    (1, 32, 2, 16, 16),
    (2, 64, 2, 32, 32),
    (1, 100, 4, 16, 32),    # uneven T vs chunk
    (2, 48, 1, 64, 16),     # production head size
    (1, 16, 2, 16, 64),     # chunk > T
])
def test_wkv6_matches_reference(B, T, H, N, chunk):
    r, k, v, w, u = _mk(B, T, H, N)
    out = wkv6(r, k, v, w, u, chunk=chunk)
    ref = wkv6_reference(r, k, v, w, u)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_wkv6_chunk_invariance():
    r, k, v, w, u = _mk(1, 64, 2, 16)
    o1 = wkv6(r, k, v, w, u, chunk=8)
    o2 = wkv6(r, k, v, w, u, chunk=64)
    np.testing.assert_allclose(o1, o2, atol=1e-4, rtol=1e-4)


def test_wkv6_decay_actually_forgets():
    """With strong decay (w ~ 0), output at t depends only on recent tokens."""
    B, T, H, N = 1, 16, 1, 8
    r, k, v, w, u = _mk(B, T, H, N)
    w_strong = jnp.full_like(w, 0.01)
    out1 = wkv6(r, k, v, w_strong, u, chunk=8)
    # perturb early tokens; late outputs should barely move
    k2 = k.at[:, :4].add(10.0)
    v2 = v.at[:, :4].add(10.0)
    out2 = wkv6(r, k2, v2, w_strong, u, chunk=8)
    late_diff = float(jnp.abs(out1[:, -4:] - out2[:, -4:]).max())
    early_diff = float(jnp.abs(out1[:, :4] - out2[:, :4]).max())
    assert late_diff < 1e-2 * max(early_diff, 1.0)


def test_wkv6_grads_match_reference():
    """The recompute custom_vjp replays the oracle recurrence, so grads
    match differentiating ``wkv6_reference`` directly to float tolerance."""
    r, k, v, w, u = _mk(1, 48, 2, 16)
    loss_k = lambda *a: jnp.sum(jnp.tanh(wkv6(*a, chunk=16)))
    loss_r = lambda *a: jnp.sum(jnp.tanh(wkv6_reference(*a)))
    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3, 4))(r, k, v, w, u)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3, 4))(r, k, v, w, u)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)
