"""Shape/dtype sweep: Pallas flash attention vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_reference, flash_attention

KEY = jax.random.PRNGKey(7)


def _mk(B, Sq, Skv, H, KV, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, D), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 64, 4, 2, 32),      # GQA rep 2
    (1, 96, 8, 2, 64),      # GQA rep 4
    (2, 60, 4, 1, 32),      # MQA, uneven seq
    (1, 256, 2, 2, 128),    # long-ish, wide head
])
def test_causal_matches_reference(B, S, H, KV, D):
    q, k, v = _mk(B, S, S, H, KV, D, jnp.float32)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32)
    ref = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [16, 32, 64])
def test_sliding_window(window):
    q, k, v = _mk(1, 128, 128, 4, 2, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, bq=32, bk=32)
    ref = attention_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_bidirectional():
    q, k, v = _mk(2, 64, 64, 4, 4, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=False, bq=32, bk=32)
    ref = attention_reference(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_bfloat16_tolerance():
    q, k, v = _mk(1, 64, 64, 4, 2, 64, jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32)
    ref = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=3e-2, rtol=3e-2)


def test_block_shape_invariance():
    q, k, v = _mk(1, 128, 128, 2, 2, 32, jnp.float32)
    o1 = flash_attention(q, k, v, causal=True, bq=16, bk=16)
    o2 = flash_attention(q, k, v, causal=True, bq=64, bk=128)
    np.testing.assert_allclose(o1, o2, atol=2e-5, rtol=2e-5)


def test_decode_shape_single_query():
    q, k, v = _mk(2, 1, 96, 4, 2, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, bq=8, bk=32)
    ref = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# Backward pass: the dq / dkv Pallas kernels vs differentiating the oracle
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 64, 2, 2, 32),      # MHA
    (1, 64, 4, 2, 32),      # GQA rep 2 (dk/dv fold heads onto kv groups)
    (2, 48, 4, 1, 32),      # MQA, uneven seq vs block
])
def test_grads_match_reference(B, S, H, KV, D):
    q, k, v = _mk(B, S, S, H, KV, D, jnp.float32)
    loss_k = lambda q, k, v: jnp.sum(
        jnp.sin(flash_attention(q, k, v, causal=True, bq=32, bk=32)))
    loss_r = lambda q, k, v: jnp.sum(
        jnp.sin(attention_reference(q, k, v, causal=True)))
    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)


def test_grads_sliding_window():
    q, k, v = _mk(1, 64, 64, 2, 2, 32, jnp.float32)
    loss_k = lambda q, k, v: jnp.sum(
        flash_attention(q, k, v, causal=True, window=16, bq=32, bk=32) ** 2)
    loss_r = lambda q, k, v: jnp.sum(
        attention_reference(q, k, v, causal=True, window=16) ** 2)
    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)
