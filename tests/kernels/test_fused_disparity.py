"""Fused disparity reduction: Pallas kernels + jnp fallback vs the
concat-based oracle, forward AND gradients, masked and unmasked, on sizes
that don't divide the tile grid."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.disparity import (cosine_distance, l1_disparity,
                                  masked_cosine_distance)
from repro.kernels.fused_disparity import (cosine_distance_reference,
                                           l1_disparity_reference,
                                           masked_cosine_terms,
                                           masked_l1_terms)

KEY = jax.random.PRNGKey(23)


def _tree_pair(sizes, seed=0):
    """Two same-structure pytrees with the given leaf sizes (flattened
    coordinate total = sum(sizes))."""
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = {f"l{i}": jax.random.normal(jax.random.fold_in(ka, i), (n,))
         for i, n in enumerate(sizes)}
    b = {f"l{i}": jax.random.normal(jax.random.fold_in(kb, i), (n,))
         for i, n in enumerate(sizes)}
    return a, b


# leaf layouts: aligned, non-multiple-of-128-lane, non-multiple-of-tile,
# tiny (stays on the jnp path even in kernel mode)
SIZES = [(4096,), (1000, 4097), (130,), (256 * 128, 5000, 7)]


@pytest.mark.parametrize("sizes", SIZES)
@pytest.mark.parametrize("masked", [False, True])
def test_l1_terms_kernel_matches_reference(sizes, masked):
    a, b = _tree_pair(sizes)
    n = sum(sizes)
    mask = ((jax.random.uniform(KEY, (n,)) > 0.4) if masked else None)
    want = l1_disparity_reference(a, b, mask)
    s, c = masked_l1_terms(a, b, mask, use_kernel=True, interpret=True)
    got = s / jnp.maximum(c, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    # and the jnp fallback agrees too
    s2, c2 = masked_l1_terms(a, b, mask, use_kernel=False)
    np.testing.assert_allclose(np.asarray(s2 / jnp.maximum(c2, 1.0)),
                               np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("sizes", SIZES)
@pytest.mark.parametrize("masked", [False, True])
def test_cosine_terms_kernel_matches_reference(sizes, masked):
    a, b = _tree_pair(sizes, seed=3)
    n = sum(sizes)
    mask = ((jax.random.uniform(KEY, (n,)) > 0.4) if masked else None)
    want = cosine_distance_reference(a, b, mask)
    dot, na2, nb2 = masked_cosine_terms(a, b, mask, use_kernel=True,
                                        interpret=True)
    got = 1.0 - dot / jnp.maximum(jnp.sqrt(na2) * jnp.sqrt(nb2), 1e-12)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@pytest.mark.parametrize("masked", [False, True])
def test_l1_grad_parity_kernel_vs_reference(masked):
    """custom_vjp backward (closed-form sign(a-b)*m) == autodiff of the
    concat oracle, through the interpret-mode Pallas forward."""
    a, b = _tree_pair((5000, 333), seed=7)
    mask = ((jax.random.uniform(KEY, (5333,)) > 0.5) if masked else None)

    def fused(t):
        s, c = masked_l1_terms(t, b, mask, use_kernel=True, interpret=True)
        return s / jnp.maximum(c, 1.0)

    g = jax.grad(fused)(a)
    g_ref = jax.grad(lambda t: l1_disparity_reference(t, b, mask))(a)
    for k in g:
        np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_ref[k]),
                                   rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("masked", [False, True])
def test_cosine_grad_parity_kernel_vs_reference(masked):
    a, b = _tree_pair((4100, 50), seed=11)
    mask = ((jax.random.uniform(KEY, (4150,)) > 0.5) if masked else None)

    def fused(t):
        dot, na2, nb2 = masked_cosine_terms(t, b, mask, use_kernel=True,
                                            interpret=True)
        return 1.0 - dot / jnp.maximum(jnp.sqrt(na2) * jnp.sqrt(nb2), 1e-12)

    g = jax.grad(fused)(a)
    g_ref = jax.grad(lambda t: cosine_distance_reference(t, b, mask))(a)
    for k in g:
        np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_ref[k]),
                                   rtol=1e-4, atol=1e-8)


def test_mask_grad_flows():
    """The mask cotangent is the real derivative, not a zero stub."""
    a, b = _tree_pair((300,), seed=5)
    mask = jnp.ones((300,), jnp.float32) * 0.5

    def f(m):
        s, c = masked_l1_terms(a, b, m)
        return s / jnp.maximum(c, 1.0)

    g = jax.grad(f)(mask)
    g_ref = jax.grad(lambda m: l1_disparity_reference(a, b, m))(mask)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-5)


def test_disparity_metrics_match_seed_semantics():
    """The public metrics (now fused-terms-backed) reproduce the seed
    concat implementations, masked and unmasked."""
    a, b = _tree_pair((2048, 999), seed=9)
    mask = jax.random.uniform(KEY, (3047,)) > 0.3
    np.testing.assert_allclose(np.asarray(l1_disparity(a, b)),
                               np.asarray(l1_disparity_reference(a, b)),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(l1_disparity(a, b, mask)),
                               np.asarray(l1_disparity_reference(a, b, mask)),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cosine_distance(a, b)),
                               np.asarray(cosine_distance_reference(a, b)),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(masked_cosine_distance(a, b, mask)),
        np.asarray(cosine_distance_reference(a, b, mask)),
        rtol=1e-5, atol=1e-7)


def test_vmap_over_lanes_kernel_path():
    """vmap lifting of the Pallas kernels themselves (the TPU GI-loop
    shape): per-tile partial outputs must stay lane-local when jax prepends
    the batch grid axis — a cross-grid-step accumulation pattern would pass
    the unbatched tests and corrupt every lane but the first here."""
    a, b = _tree_pair((4500,), seed=17)
    batch_a = jax.tree_util.tree_map(
        lambda x: jnp.stack([x, 2.0 * x, -0.5 * x]), a)
    masks = jnp.stack([jnp.ones((4500,), bool),
                       jax.random.uniform(KEY, (4500,)) > 0.5,
                       jax.random.uniform(KEY, (4500,)) > 0.9])

    def lane(t, m):
        s, c = masked_l1_terms(t, b, m, use_kernel=True, interpret=True)
        return s / jnp.maximum(c, 1.0)

    got = jax.vmap(lane)(batch_a, masks)
    want = jnp.stack([l1_disparity_reference(
        jax.tree_util.tree_map(lambda x: x[i], batch_a), b, masks[i])
        for i in range(3)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def lane_cos(t, m):
        d, na2, nb2 = masked_cosine_terms(t, b, m, use_kernel=True,
                                          interpret=True)
        return 1.0 - d / jnp.maximum(jnp.sqrt(na2) * jnp.sqrt(nb2), 1e-12)

    got_c = jax.vmap(lane_cos)(batch_a, masks)
    want_c = jnp.stack([cosine_distance_reference(
        jax.tree_util.tree_map(lambda x: x[i], batch_a), b, masks[i])
        for i in range(3)])
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c),
                               rtol=1e-5, atol=1e-7)


def test_vmap_over_lanes():
    """The terms batch under vmap (how the GI engine evaluates them) —
    each lane sees its own mask slice of the stacked mask tensor."""
    a, b = _tree_pair((1000,), seed=13)
    batch_a = jax.tree_util.tree_map(
        lambda x: jnp.stack([x, 2.0 * x, -x]), a)
    masks = jnp.stack([jnp.ones((1000,), bool),
                       jax.random.uniform(KEY, (1000,)) > 0.5,
                       jnp.zeros((1000,), bool)])
    got = jax.vmap(lambda t, m: l1_disparity(t, b, m))(batch_a, masks)
    want = [l1_disparity_reference(
        jax.tree_util.tree_map(lambda x: x[i], batch_a), b, masks[i])
        for i in range(3)]
    np.testing.assert_allclose(np.asarray(got), np.asarray(jnp.stack(want)),
                               rtol=1e-6)
