"""Unit tests for model building blocks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.config import ModelConfig, MoEConfig

KEY = jax.random.PRNGKey(0)


def tiny_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=97, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #


def test_rmsnorm_unit_scale():
    cfg = tiny_cfg()
    p = L.init_norm(cfg, 64)
    x = jax.random.normal(KEY, (2, 8, 64)) * 5.0
    y = L.norm_fwd(p, cfg, x)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_layernorm_zero_mean():
    cfg = tiny_cfg(norm="layernorm")
    p = L.init_norm(cfg, 64)
    x = jax.random.normal(KEY, (2, 8, 64)) + 3.0
    y = L.norm_fwd(p, cfg, x)
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-4)
    np.testing.assert_allclose(jnp.std(y, -1), 1.0, atol=1e-2)


# --------------------------------------------------------------------------- #
# RoPE / M-RoPE
# --------------------------------------------------------------------------- #


def test_rope_preserves_norm_and_relative_phase():
    pos = jnp.arange(16)[None]
    cos, sin = L.rope_cos_sin(pos, 32, 10_000.0)
    x = jax.random.normal(KEY, (1, 16, 2, 32))
    y = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_rope_relative_position_invariance():
    """q.k after rope depends only on relative distance."""
    d = 16
    q = jax.random.normal(KEY, (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))

    def dot_at(p_q, p_k):
        cq, sq_ = L.rope_cos_sin(jnp.array([[p_q]]), d, 10_000.0)
        ck, sk = L.rope_cos_sin(jnp.array([[p_k]]), d, 10_000.0)
        qr = L.apply_rope(q, cq, sq_)
        kr = L.apply_rope(k, ck, sk)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(3, 1) - dot_at(4, 1)) > 1e-6  # actually differs


def test_mrope_text_only_matches_rope():
    """With all three position components equal, M-RoPE == RoPE."""
    d = 32
    pos = jnp.arange(8)[None]
    pos3 = jnp.broadcast_to(pos[None], (3, 1, 8))
    c1, s1 = L.rope_cos_sin(pos, d, 10_000.0)
    # mrope with full-width single section should equal rope
    c3, s3 = L.mrope_cos_sin(pos3, d, 10_000.0, (16,))
    np.testing.assert_allclose(c1[0], c3[0], rtol=1e-6)
    np.testing.assert_allclose(s1[0], s3[0], rtol=1e-6)


def test_mrope_sections_select_components():
    pos3 = jnp.stack([jnp.zeros((1, 4)), jnp.ones((1, 4)),
                      2 * jnp.ones((1, 4))])
    c, s = L.mrope_cos_sin(pos3, 12, 10_000.0, (2, 2, 2))
    # first 2 rotary coords use t=0 -> angle 0 -> cos 1 sin 0
    np.testing.assert_allclose(c[0, :, :2], 1.0, atol=1e-6)
    np.testing.assert_allclose(s[0, :, :2], 0.0, atol=1e-6)
    assert float(jnp.abs(s[0, :, 2:]).sum()) > 0


# --------------------------------------------------------------------------- #
# Chunked attention vs naive reference
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("Sq,Skv,H,KV,window,causal", [
    (32, 32, 4, 2, None, True),
    (48, 48, 4, 4, 16, True),
    (32, 32, 2, 2, None, False),
    (1, 64, 4, 2, None, True),
])
def test_chunked_attention_matches_reference(Sq, Skv, H, KV, window, causal):
    from repro.kernels.flash_attention.ref import attention_reference
    D = 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, Sq, H, D))
    k = jax.random.normal(ks[1], (2, Skv, KV, D))
    v = jax.random.normal(ks[2], (2, Skv, KV, D))
    out = L.chunked_attention(q, k, v, causal=causal, window=window,
                              q_offset=Skv - Sq, chunk=16)
    ref = attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_chunked_attention_valid_len_masking():
    from repro.kernels.gqa_decode.ref import gqa_decode_reference
    D, S = 16, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 1, 4, D))
    k = jax.random.normal(ks[1], (2, S, 2, D))
    v = jax.random.normal(ks[2], (2, S, 2, D))
    valid = 40
    out = L.chunked_attention(q, k, v, causal=True, window=None,
                              q_offset=valid - 1, kv_valid_len=valid, chunk=16)
    ref = gqa_decode_reference(q[:, 0].transpose(0, 2, 1).reshape(2, 4, D)
                               if False else q[:, 0], k, v, valid)
    np.testing.assert_allclose(out[:, 0], ref, atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# MoE
# --------------------------------------------------------------------------- #


def _moe_dense_reference(p, cfg, x):
    """All-experts-on-all-tokens reference for the sort-based dispatch."""
    mc = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_ids = jax.lax.top_k(probs, mc.top_k)
    gate_vals = gate_vals / gate_vals.sum(-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for e in range(mc.n_experts):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        y = h @ p["w_down"][e]
        w = jnp.sum(jnp.where(expert_ids == e, gate_vals, 0.0), -1)
        out = out + y * w[:, None]
    if mc.n_shared:
        sp = p["shared"]
        out = out + (jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_up"])) @ sp["w_down"]
    return out.reshape(B, S, d)


def test_moe_matches_dense_reference():
    cfg = tiny_cfg(family="moe",
                   moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_expert=32))
    p = L.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, 64)) * 0.5
    # generous capacity so no tokens drop -> must match dense reference
    out, aux = L.moe_fwd(p, cfg, x, capacity_factor=4.0)
    ref = _moe_dense_reference(p, cfg, x)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)
    assert float(aux) >= 0.0


def test_moe_top1_routing():
    cfg = tiny_cfg(family="moe",
                   moe=MoEConfig(n_experts=4, top_k=1, n_shared=0, d_expert=32))
    p = L.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (1, 16, 64)) * 0.5
    out, _ = L.moe_fwd(p, cfg, x, capacity_factor=4.0)
    ref = _moe_dense_reference(p, cfg, x)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_dont_nan():
    cfg = tiny_cfg(family="moe",
                   moe=MoEConfig(n_experts=2, top_k=2, n_shared=0, d_expert=16))
    p = L.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (1, 32, 64))
    out, aux = L.moe_fwd(p, cfg, x, capacity_factor=0.25)  # heavy dropping
    assert not bool(jnp.isnan(out).any())


# --------------------------------------------------------------------------- #
# RWKV6 / Mamba decode-vs-scan consistency
# --------------------------------------------------------------------------- #


def test_rwkv6_decode_matches_full_scan():
    cfg = tiny_cfg(family="ssm", block_type="rwkv6", rwkv_head_size=16)
    p = L.init_rwkv6(KEY, cfg)
    x = jax.random.normal(KEY, (2, 6, 64)) * 0.5
    full, _ = L.rwkv6_time_mix(p, cfg, x)
    state = {"x_prev": jnp.zeros((2, 64)),
             "S": jnp.zeros((2, 4, 16, 16), jnp.float32)}
    outs = []
    for t in range(6):
        o, state = L.rwkv6_time_mix(p, cfg, x[:, t:t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(full, step, atol=2e-4, rtol=2e-4)


def test_mamba_decode_matches_full_scan():
    cfg = tiny_cfg(family="hybrid", block_type="hybrid", ssm_state=8)
    p = L.init_mamba(KEY, cfg)
    x = jax.random.normal(KEY, (2, 6, 64)) * 0.5
    full, _ = L.mamba_fwd(p, cfg, x)
    state = {"conv": jnp.zeros((2, 3, 128)),
             "h": jnp.zeros((2, 128, 8), jnp.float32)}
    outs = []
    for t in range(6):
        o, state = L.mamba_fwd(p, cfg, x[:, t:t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(full, step, atol=2e-4, rtol=2e-4)


def test_rwkv6_decay_bounds():
    """Data-dependent decay w must stay in (0, 1) — the Finch contract."""
    cfg = tiny_cfg(family="ssm", block_type="rwkv6", rwkv_head_size=16)
    p = L.init_rwkv6(KEY, cfg)
    x = jax.random.normal(KEY, (1, 16, 64)) * 3.0
    xs = L._token_shift(x)
    ww = x + (xs - x) * p["mu_w"]
    dd = jnp.tanh(ww @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp((p["w0"] + dd).astype(jnp.float32)))
    assert float(w.min()) > 0.0 and float(w.max()) < 1.0
