"""The quantized upload wire format (core.quantize) end to end.

Pins the contracts the compression axis ships on:

* **replay determinism** — quantization is a pure function of the upload's
  identity (seed, client, round): re-quantizing, re-batching, or replaying
  a whole EF chain yields bitwise-identical payloads;
* **error feedback** — the residual ``delta - deq(quant(delta))`` carried
  per client bounds the *running-sum* error at one quantization step, so
  constant deltas drain to the truth at O(1/T);
* **round-trip bounds** — per-coordinate error is at most one per-tile
  quantization step, at int8 and int4, for f32 and bf16 leaves;
* **bits=32 is the identity** — the default config equals the explicit
  fp32 config and the quantizer refuses to run on it;
* **integration** — fused and loop aggregation see the same wire bytes and
  (to float tolerance) the same trajectory at int8; the streaming
  service's replay digest is invariant to the wire format while bytes on
  the wire shrink >= 3.5x; the VersionStore's quantized ring shrinks the
  resident history ~4x with reads equal across in-window/spilled/gather.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import (ErrorFeedback, QuantConfig,
                                 dequantize_flat_np, leaf_payload_bytes,
                                 quantize_delta_stack, quantize_flat,
                                 tree_payload_bytes)
from repro.core.versions import VersionStore


def _stack(B, sizes, seed=0):
    k = jax.random.PRNGKey(seed)
    return {f"l{i}": jax.random.normal(jax.random.fold_in(k, i), (B, n))
            for i, n in enumerate(sizes)}


def _rows_equal(qt_a, qt_b, row_a, row_b):
    for qa, qb in zip(qt_a.q, qt_b.q):
        np.testing.assert_array_equal(np.asarray(qa[row_a]),
                                      np.asarray(qb[row_b]))
    for sa, sb in zip(qt_a.s, qt_b.s):
        np.testing.assert_array_equal(np.asarray(sa[row_a]),
                                      np.asarray(sb[row_b]))


# --------------------------------------------------------------------------- #
# Config + payload accounting
# --------------------------------------------------------------------------- #


def test_config_validation_and_identity():
    assert not QuantConfig().enabled
    assert QuantConfig(bits=8).qmax == 127
    assert QuantConfig(bits=4).qmax == 7
    assert QuantConfig(bits=32) == QuantConfig()
    with pytest.raises(ValueError):
        QuantConfig(bits=16)
    with pytest.raises(ValueError):
        QuantConfig(store_bits=2)
    with pytest.raises(ValueError):
        quantize_delta_stack(_stack(1, (64,)), [0], 0, QuantConfig())


def test_payload_bytes_accounting():
    int8 = QuantConfig(bits=8)
    # 437 coords: 437 payload bytes + 4 tiles of f32 scale
    assert leaf_payload_bytes(437, int8) == 437 + 4 * 4
    assert leaf_payload_bytes(437, QuantConfig()) == 4 * 437
    # int4 packs two coords per byte on the wire
    assert leaf_payload_bytes(256, QuantConfig(bits=4)) == 128 + 4 * 2
    tpl = {"w": jnp.zeros((256, 392)), "b": jnp.zeros((1568,))}
    ratio = (tree_payload_bytes(tpl, QuantConfig())
             / tree_payload_bytes(tpl, int8))
    assert ratio >= 3.5, ratio
    # and the stack quantizer reports exactly B x the per-row bytes
    B = 3
    qt, _, nbytes = quantize_delta_stack(_stack(B, (437, 90)), [5, 1, 2],
                                         0, int8)
    per_row = (leaf_payload_bytes(437, int8)
               + leaf_payload_bytes(90, int8))
    assert nbytes == B * per_row == B * qt.wire_bytes_per_row


# --------------------------------------------------------------------------- #
# Round-trip bounds
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits", [8, 4])
def test_round_trip_bounded_by_one_step(bits, dtype):
    """|x - deq(quant(x))| <= the tile's quantization step (max|x|/qmax),
    stochastic and nearest, including a ragged tail tile."""
    tile, n = 128, 1000
    rng = np.random.default_rng(3)
    x = np.asarray(jnp.asarray(rng.normal(size=n) * 5.0, dtype),
                   np.float32)
    qmax = (1 << (bits - 1)) - 1
    for u in (None, rng.random(n)):
        q, s = quantize_flat(x, bits, tile, u)
        assert q.dtype == np.int8 and np.abs(q).max() <= qmax
        err = np.abs(x - dequantize_flat_np(q, s, tile))
        t = s.shape[0]
        step = np.repeat(s, tile)[:n]
        assert np.all(err <= step * (1 + 1e-5) + 1e-12), err.max()
        assert t == -(-n // tile)


def test_zero_tiles_quantize_exactly():
    x = np.zeros(300, np.float32)
    q, s = quantize_flat(x, 8, 128, np.random.default_rng(0).random(300))
    assert not q.any() and not s.any()
    np.testing.assert_array_equal(dequantize_flat_np(q, s, 128), x)


# --------------------------------------------------------------------------- #
# Replay determinism + error feedback
# --------------------------------------------------------------------------- #


def test_replay_is_bitwise_identical_and_batching_invariant():
    cfg = QuantConfig(bits=8)
    stack = _stack(4, (437, 90), seed=1)
    clients = [3, 1, 2, 0]
    qt1, deq1, _ = quantize_delta_stack(stack, clients, 7, cfg)
    qt2, deq2, _ = quantize_delta_stack(stack, clients, 7, cfg)
    for a, b in zip(qt1.q + qt1.s, qt2.q + qt2.s):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        deq1, deq2)
    # batching invariance: quantizing clients [3,1] and [2,0] separately
    # yields the same per-row payloads (streams are per-upload, not
    # per-cohort)
    half_a = {k: v[:2] for k, v in stack.items()}
    half_b = {k: v[2:] for k, v in stack.items()}
    qa, _, _ = quantize_delta_stack(half_a, clients[:2], 7, cfg)
    qb, _, _ = quantize_delta_stack(half_b, clients[2:], 7, cfg)
    _rows_equal(qt1, qa, 0, 0)
    _rows_equal(qt1, qa, 1, 1)
    _rows_equal(qt1, qb, 2, 0)
    _rows_equal(qt1, qb, 3, 1)
    # a different round draws a different rounding stream
    qt3, _, _ = quantize_delta_stack(stack, clients, 8, cfg)
    assert any(np.asarray(a).tobytes() != np.asarray(b).tobytes()
               for a, b in zip(qt1.q, qt3.q))


def test_ef_chain_replay_is_bitwise_identical():
    """Two independent replays of a 3-round EF chain produce the same
    quantized stream byte for byte — the soak/replay contract."""
    cfg = QuantConfig(bits=8)
    streams = []
    for _ in range(2):
        ef = ErrorFeedback()
        out = []
        for t in range(3):
            qt, _, _ = quantize_delta_stack(_stack(2, (200,), seed=t),
                                            [0, 1], t, cfg, ef)
            out.append(b"".join(np.asarray(x).tobytes()
                                for x in qt.q + qt.s))
        assert len(ef) == 2
        streams.append(b"".join(out))
    assert streams[0] == streams[1]


def test_ef_drains_constant_deltas():
    """With EF the running sum of dequantized uploads tracks the true sum
    to within ONE quantization step, independent of T — so the mean
    converges at O(1/T). Without EF the bias accumulates freely."""
    for bits in (8, 4):
        cfg = QuantConfig(bits=bits, stochastic=False)
        d = np.asarray(
            jax.random.normal(jax.random.PRNGKey(5), (256,)), np.float32)
        stack = {"l": jnp.asarray(d)[None, :]}
        T = 8
        ef = ErrorFeedback()
        total = np.zeros_like(d)
        for t in range(T):
            _, deq, _ = quantize_delta_stack(stack, [0], t, cfg, ef)
            total += np.asarray(deq["l"][0])
        step = 2.0 * np.abs(d).max() / cfg.qmax
        err_sum = np.abs(total - T * d).max()
        assert err_sum <= step, (bits, err_sum, step)
        assert ef.residual_norm(0) <= step
        # the residual IS the sum error: e_T = T*d - sum(deq)
        np.testing.assert_allclose(ef.residual(0), T * d - total,
                                   atol=1e-4)


# --------------------------------------------------------------------------- #
# Integration: server paths, service digest, VersionStore ring
# --------------------------------------------------------------------------- #


def test_fused_and_loop_see_same_wire_at_int8():
    """The fused stacked round and the per-client loop oracle quantize the
    same uploads: equal bytes-on-wire, trajectories equal to float
    tolerance (the quantized streams are identical; only fp32 reduction
    order differs)."""
    from repro.sim import scenarios

    finals, wires = [], []
    for fused in (True, False):
        run = scenarios.build("degenerate_sync", seed=0, horizon=3.0,
                              gi_iters=2, mesh=None, fused_step=fused,
                              quant_bits=8)
        s = run.run()
        finals.append(jax.tree_util.tree_map(np.asarray,
                                             run.server.global_params))
        wires.append(s["server"]["wire_bytes"])
        assert s["server"]["quant_bits"] == 8
    assert wires[0] == wires[1] > 0
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(a, b, atol=1e-5), *finals)


def test_quant_bits_32_is_the_default_identity():
    from repro.sim.scenarios import fl_setup

    server, _, _ = fl_setup(0, gi_iters=2, quant_bits=32)
    assert server.cfg.quant == QuantConfig()
    assert not server.cfg.quant.enabled


def test_service_digest_invariant_to_wire_format():
    """fp32 and int8 services replay the same log to the SAME event
    digest (compression never changes which aggregations fire) while the
    int8 service puts >= 3.5x fewer bytes on the wire."""
    from repro.service import ServiceConfig, build_service, synthetic_log

    log = synthetic_log(n_clients=6, horizon=3.0, seed=0, slow_ids=(0, 1))
    cfg = ServiceConfig(trigger="fedbuff", k=3, queue_capacity=8,
                        admission="coalesce", max_cohort=4)
    out = {}
    for bits in (32, 8):
        svc = build_service(seed=0, strategy="ours", gi_iters=2,
                            segment_iters=0, max_lanes=0, cfg=cfg,
                            quant_bits=bits)
        svc.run_log(log)
        out[bits] = (svc.digest(), svc.counters["payload_bytes"],
                     svc.counters["arrivals"])
    assert out[32][0] == out[8][0]
    assert out[32][2] == out[8][2] > 0
    assert out[32][1] / out[8][1] >= 3.5


def test_versionstore_quantized_ring():
    """store_bits=8: ~4x smaller resident ring; reads are within one
    deterministic quantization step; spilled reads and gathers equal the
    in-window read path bit for bit."""
    tpl = {"w": jnp.zeros((40, 13), jnp.float32),
           "b": jnp.zeros((29,), jnp.float32)}
    cfg = QuantConfig(store_bits=8)
    vs = VersionStore(tpl, capacity=4, spill=True, quant=cfg)
    exact = VersionStore(tpl, capacity=4, spill=True)
    assert exact.device_bytes / vs.device_bytes >= 3.5
    versions = []
    for v in range(7):
        k = jax.random.PRNGKey(v)
        p = {"w": jax.random.normal(k, (40, 13)),
             "b": jax.random.normal(jax.random.fold_in(k, 1), (29,))}
        versions.append(p)
        assert vs.append(p) == v
    assert vs.n_spilled == 3
    for v, p in enumerate(versions):
        got = vs[v]
        for key in p:
            x = np.asarray(p[key])
            err = np.abs(np.asarray(got[key]) - x)
            bound = np.abs(x).max() / 127 * (1 + 1e-5)
            assert err.max() <= bound, (v, key, err.max(), bound)
    # gather (spilled + in-window rows) == itemized reads, bitwise
    rows = [0, 2, 5, 6]
    g = vs.gather(rows)
    for j, v in enumerate(rows):
        one = vs[v]
        for key in one:
            np.testing.assert_array_equal(
                np.asarray(g[key][j]), np.asarray(one[key]))


def test_unquantized_store_ignores_fp32_quant_config():
    tpl = {"w": jnp.zeros((8, 3))}
    vs = VersionStore(tpl, capacity=2, quant=QuantConfig(bits=8))
    assert vs.quant is None  # store_bits=32: the ring stays exact
