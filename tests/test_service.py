"""Streaming service tests: replay determinism, the persistent lane pool,
admission control / backpressure soak, timely dissemination.

The expensive contracts (oracle equality, pool persistence) run on the
"ours" strategy with the segmented GI executor; the queue-mechanics soaks
run strategy="unweighted" (no GI) because admission and triggers are
strategy-independent — that keeps the 2x-overload replays cheap enough to
run per admission policy, twice each for the digest check.
"""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro.core.gradient_inversion as gi_mod
from repro.service import (AdmissionQueue, ServiceConfig, StreamArrival,
                           StreamingService, build_service,
                           log_from_scenario, read_upload_log, synthetic_log)
from repro.sim.devices import LatencyDist

ROOT = os.path.join(os.path.dirname(__file__), "..")
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


def _params_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# --------------------------------------------------------------------------- #
# Upload logs
# --------------------------------------------------------------------------- #


def test_upload_log_roundtrip(tmp_path):
    log = synthetic_log(n_clients=6, horizon=4.0, seed=3, slow_ids=(0,))
    assert len(log) > 0
    path = str(tmp_path / "uploads.jsonl")
    log.write_jsonl(path)
    back = read_upload_log(path)
    assert back.digest() == log.digest()
    assert back.n_clients == log.n_clients
    assert len(back) == len(log)


def test_synthetic_log_deterministic_and_ordered():
    a = synthetic_log(n_clients=5, horizon=3.0, seed=7, slow_ids=(1, 2))
    b = synthetic_log(n_clients=5, horizon=3.0, seed=7, slow_ids=(1, 2))
    assert a.digest() == b.digest()
    ts = [j.dispatch_t for j in a]
    assert ts == sorted(ts)
    assert all(j.arrival_t <= 3.0 for j in a)
    # a different seed is a different stream
    assert synthetic_log(n_clients=5, horizon=3.0, seed=8).digest() \
        != a.digest()


def test_log_from_scenario_engine_agnostic():
    """heap and vec engine traces are pinned identical, so the recorded
    upload log must be too."""
    vec = log_from_scenario("fedbuff_k4", seed=0, horizon=4.0, engine="vec")
    heap = log_from_scenario("fedbuff_k4", seed=0, horizon=4.0, engine="heap")
    assert len(vec) > 0
    assert vec.digest() == heap.digest()


# --------------------------------------------------------------------------- #
# Admission queue
# --------------------------------------------------------------------------- #


def _arr(client, base=0, t=0.0, job=0):
    return StreamArrival(client, base, t, t, job)


def test_admission_reject_full_queue():
    q = AdmissionQueue(2, "reject")
    assert q.offer(_arr(0)) == "admitted"
    assert q.offer(_arr(1)) == "admitted"
    assert q.offer(_arr(2)) == "rejected"
    assert len(q) == 2 and q.counters["rejected"] == 1


def test_admission_drop_oldest_evicts():
    q = AdmissionQueue(2, "drop_oldest")
    q.offer(_arr(0))
    q.offer(_arr(1))
    assert q.offer(_arr(2)) == "admitted"
    assert [a.client for a in q.pop_cohort()] == [1, 2]
    assert q.counters["dropped_oldest"] == 1


def test_admission_coalesce_replaces_in_place():
    q = AdmissionQueue(3, "coalesce")
    q.offer(_arr(0, base=0))
    q.offer(_arr(1, base=0))
    assert q.offer(_arr(0, base=5)) == "coalesced"
    assert len(q) == 2
    cohort = q.pop_cohort()
    # client 0 kept its queue position but carries the fresher base
    assert [(a.client, a.base_version) for a in cohort] == [(0, 5), (1, 0)]
    # with no duplicate to replace, a full coalesce queue rejects
    q2 = AdmissionQueue(1, "coalesce")
    q2.offer(_arr(0))
    assert q2.offer(_arr(1)) == "rejected"


def test_admission_pop_cohort_limit():
    q = AdmissionQueue(8, "reject")
    for c in range(5):
        q.offer(_arr(c))
    assert [a.client for a in q.pop_cohort(2)] == [0, 1]
    assert len(q) == 3
    assert q.counters["popped"] == 2


# --------------------------------------------------------------------------- #
# Replay determinism: loop-mode Server as the bit-for-bit oracle
# --------------------------------------------------------------------------- #


def test_replay_matches_loop_oracle():
    """Replaying one upload log through the fused-step service and through
    the loop-mode oracle yields identical digests AND bitwise-identical
    global model trajectories."""
    log = synthetic_log(n_clients=8, horizon=3.0, seed=1, slow_ids=(0, 1))
    cfg = ServiceConfig(trigger="fedbuff", k=3, max_cohort=4)
    fused = build_service(seed=0, gi_iters=4, cfg=cfg)
    loop = build_service(seed=0, gi_iters=4, fused_step=False, cfg=cfg)
    sf = fused.run_log(log)
    sl = loop.run_log(log)
    assert sf["digest"] == sl["digest"]
    assert sf["version"] == sl["version"] > 0
    assert sf["aggregations"] == sl["aggregations"]
    assert _params_equal(fused.server.global_params,
                         loop.server.global_params)


def test_two_runs_digest_identical():
    log = synthetic_log(n_clients=8, horizon=3.0, seed=2, slow_ids=(0,))
    cfg = ServiceConfig(trigger="async", queue_capacity=16,
                        admission="coalesce", max_cohort=2)
    runs = []
    for _ in range(2):
        svc = build_service(seed=0, strategy="unweighted", cfg=cfg)
        runs.append(svc.run_log(log))
    assert runs[0]["digest"] == runs[1]["digest"]
    for k in ("version", "offered", "admitted", "rejected", "coalesced",
              "superseded", "aggregations", "queue_depth_max"):
        assert runs[0][k] == runs[1][k], k


# --------------------------------------------------------------------------- #
# Persistent lane pool
# --------------------------------------------------------------------------- #


def test_lane_pool_never_reconstructed(monkeypatch):
    """The segmented executor's lane pool is built exactly once per
    GradientInverter and survives every aggregation trigger — a service
    run constructs zero new pools."""
    created = []
    orig = gi_mod.LanePool.__init__

    def spy(self, inverter):
        created.append(self)
        orig(self, inverter)

    monkeypatch.setattr(gi_mod.LanePool, "__init__", spy)
    log = synthetic_log(n_clients=8, horizon=3.0, seed=1, slow_ids=(0, 1))
    svc = build_service(seed=0, gi_iters=4, segment_iters=2, max_lanes=4,
                        cfg=ServiceConfig(trigger="fedbuff", k=3,
                                          max_cohort=4))
    assert len(created) == 1          # built by GradientInverter.__init__
    pool = svc.server.inverter.pool
    assert pool is created[0]
    svc.run_log(log)
    assert len(created) == 1          # never reconstructed between triggers
    assert svc.server.inverter.pool is pool
    # it actually drained GI cohorts, accumulating lifetime stats
    assert pool.stats["cohorts"] >= 2
    assert pool.stats["segments"] >= pool.stats["cohorts"]
    assert pool.stats["useful_lane_iters"] > 0
    assert pool.idle()


def test_lane_pool_guards_concurrent_entry():
    svc = build_service(seed=0, strategy="unweighted")
    pool = svc.server.inverter.pool
    pool.pending.append(0)
    with pytest.raises(RuntimeError):
        pool.run_cohort(None, None, None, None, None, 1, 1, 0)
    pool.pending.clear()


# --------------------------------------------------------------------------- #
# Backpressure soak: 2x the service's drain capacity
# --------------------------------------------------------------------------- #


def _overload_log():
    """16 clients on a fixed 0.4s cadence against a deadline trigger that
    drains at most 8 uploads per 0.5s tick: offered rate ~= 2x capacity."""
    return synthetic_log(n_clients=16, horizon=6.0, seed=5,
                         fast=LatencyDist("fixed", 0.4))


@pytest.mark.parametrize("policy", ["reject", "drop_oldest", "coalesce"])
def test_backpressure_soak(policy):
    log = _overload_log()
    cfg = ServiceConfig(trigger="deadline", round_len=0.5, queue_capacity=6,
                        admission=policy, max_cohort=8)
    summaries = []
    for _ in range(2):
        svc = build_service(seed=0, strategy="unweighted",
                            n_clients=log.n_clients, cfg=cfg)
        s = svc.run_log(log)
        # bounded queue: depth never exceeded capacity
        assert s["queue_depth_max"] <= cfg.queue_capacity
        # exact admission accounting: every offer lands in exactly one bin
        assert s["offered"] == s["admitted"] + s["coalesced"] + s["rejected"]
        # queued-entry conservation
        assert s["admitted"] == (s["popped"] + s["dropped_oldest"]
                                 + s["queue_depth"])
        # every drained entry either aggregated or was superseded in-cohort
        assert s["popped"] == len(svc.realized_taus) + s["superseded"]
        assert s["offered"] == len(log)
        # overload actually engaged the policy
        if policy == "reject":
            assert s["rejected"] > 0 and s["coalesced"] == 0
        elif policy == "drop_oldest":
            assert s["dropped_oldest"] > 0 and s["rejected"] == 0
        else:
            assert s["coalesced"] > 0
            # coalesce dedups at admission: a cohort never holds duplicates
            assert s["superseded"] == 0
        summaries.append(s)
    # digest-identical replay across two fresh runs
    assert summaries[0]["digest"] == summaries[1]["digest"]
    for k in ("offered", "admitted", "rejected", "coalesced",
              "dropped_oldest", "superseded", "popped", "aggregations",
              "version", "queue_depth_max"):
        assert summaries[0][k] == summaries[1][k], k


def test_flush_drains_queue():
    log = _overload_log()
    cfg = ServiceConfig(trigger="deadline", round_len=0.5, queue_capacity=6,
                        admission="reject", max_cohort=8)
    svc = build_service(seed=0, strategy="unweighted",
                        n_clients=log.n_clients, cfg=cfg)
    svc.run_log(log)
    svc.flush()
    s = svc.summary()
    assert s["queue_depth"] == 0
    assert s["admitted"] == s["popped"]


# --------------------------------------------------------------------------- #
# Timely dissemination (arxiv 2507.06031)
# --------------------------------------------------------------------------- #


def test_dissemination_reduces_realized_staleness():
    """Pushing the fresh global to in-flight slow clients re-bases their
    eventual uploads, so mean realized staleness must drop."""
    log = synthetic_log(n_clients=10, horizon=10.0, seed=4,
                        slow_ids=(0, 1, 2),
                        slow=LatencyDist("fixed", 4.0),
                        fast=LatencyDist("fixed", 0.5))
    base_cfg = dict(trigger="fedbuff", k=3, max_cohort=4)
    off = build_service(seed=0, strategy="unweighted", n_clients=10,
                        cfg=ServiceConfig(**base_cfg, disseminate=False))
    on = build_service(seed=0, strategy="unweighted", n_clients=10,
                       cfg=ServiceConfig(**base_cfg, disseminate=True,
                                         disseminate_max_progress=0.5))
    s_off = off.run_log(log)
    s_on = on.run_log(log)
    assert s_on["disseminated"] > 0
    assert s_off["disseminated"] == 0
    assert s_on["realized_tau_mean"] < s_off["realized_tau_mean"]
    # dissemination only rebases in-flight jobs; the arrival process (and
    # therefore the offered count) is unchanged
    assert s_on["offered"] == s_off["offered"]


# --------------------------------------------------------------------------- #
# Service state persists across logs (the never-stops contract)
# --------------------------------------------------------------------------- #


def test_versions_continue_across_logs():
    log = synthetic_log(n_clients=6, horizon=2.0, seed=6)
    svc = build_service(seed=0, strategy="unweighted",
                        cfg=ServiceConfig(trigger="async"))
    s1 = svc.run_log(log)
    v1, clock1 = s1["version"], s1["vclock"]
    assert v1 > 0
    s2 = svc.run_log(log)
    assert s2["version"] > v1
    assert s2["vclock"] > clock1
    assert s2["offered"] == 2 * len(log)
    assert len(svc.server.history) == s2["version"] + 1


# --------------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------------- #


def test_service_cli_smoke(tmp_path):
    log_path = str(tmp_path / "uploads.jsonl")
    out = subprocess.run(
        [sys.executable, "-m", "repro.service", "--horizon", "2",
         "--n-clients", "6", "--strategy", "unweighted",
         "--admission", "coalesce", "--max-cohort", "4",
         "--log-out", log_path],
        env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.loads(out.stdout)
    for key in ("uploads_per_sec", "trigger_wall_p99_ms", "digest",
                "queue_depth_max", "offered", "pool_stats"):
        assert key in rec, key
    assert rec["offered"] > 0
    # the log written is replayable: same log + config => same digest
    out2 = subprocess.run(
        [sys.executable, "-m", "repro.service", "--log-in", log_path,
         "--strategy", "unweighted", "--admission", "coalesce",
         "--max-cohort", "4"],
        env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=560)
    assert out2.returncode == 0, out2.stdout + out2.stderr
    rec2 = json.loads(out2.stdout)
    assert rec2["digest"] == rec["digest"]
    assert rec2["log_digest"] == rec["log_digest"]
