"""Tests for the batched GI engine (vmap + while_loop single-compile path).

Covers the tentpole guarantees:
* per-client equivalence of ``invert_batch`` against the sequential seed
  path (``invert``) — including masked objectives, warm starts, mixed base
  rounds and per-client iteration budgets;
* the stacked ``WarmStartCache`` round trip feeding the batched call;
* the pending-check client-identity fix (E1/E2 signals are computed from the
  scheduled client's data, not the first slow client's);
* end-to-end: a Server round with the batched engine matches the sequential
  engine bit-for-bit-ish on the aggregated global model.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.client import LocalProgram, make_local_update
from repro.core.disparity import tree_stack, tree_sub, tree_to_vector
from repro.core.gradient_inversion import GIConfig, GradientInverter
from repro.core.server import FLConfig, Server
from repro.core.sparsify import WarmStartCache, topk_mask_batch
from repro.data.partition import (client_label_histograms, dirichlet_partition,
                                  pad_client_shards)
from repro.data.staleness import StalenessSchedule, intertwined_schedule
from repro.data.synthetic import make_image_dataset
from repro.models.small import mlp3

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def batch_setting():
    """B=3 stale clients with different data AND different base rounds."""
    model = mlp3(n_features=8, n_classes=3, hidden=16)
    program = LocalProgram(steps=3, lr=0.1, momentum=0.5)
    lu = make_local_update(model.apply, program)
    w = model.init(KEY)
    bases, stales = [], []
    for b in range(3):
        kx, ky = jax.random.split(jax.random.PRNGKey(10 + b))
        x = jax.random.normal(kx, (12, 8))
        y = jax.random.randint(ky, (12,), 0, 3)
        w_stale, _ = lu(w, x, y)
        bases.append(w)
        stales.append(w_stale)
        # advance the "global" model so client b+1 has a different base round
        w, _ = lu(w, jax.random.normal(ky, (12, 8)), y)
    keys = jax.random.split(jax.random.PRNGKey(42), 3)
    return model, program, bases, stales, keys


def _make_inverter(model, program, **cfg_kwargs):
    cfg = GIConfig(**{"n_rec": 6, "iters": 20, "lr": 0.1, **cfg_kwargs})
    return GradientInverter(model.apply, model.input_shape, model.n_classes,
                            program, cfg)


def test_batched_matches_sequential_per_client(batch_setting):
    """Acceptance: one jitted vmap+while_loop call reproduces the seed's
    sequential per-client D_rec within atol=1e-4."""
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program)
    drec_b, info = inv.invert_batch(tree_stack(bases), tree_stack(stales),
                                    keys)
    assert int(np.asarray(info["iters_used"]).min()) == 20
    for b in range(3):
        drec_s, _ = inv.invert(bases[b], stales[b], keys[b])
        np.testing.assert_allclose(np.asarray(drec_b[0][b]),
                                   np.asarray(drec_s[0]), atol=1e-4)
        np.testing.assert_allclose(np.asarray(drec_b[1][b]),
                                   np.asarray(drec_s[1]), atol=1e-4)
        # and the downstream unstale estimates agree too
        w_hat_b = inv.estimate_unstale_batch(
            bases[0], drec_b)
        w_hat_s = inv.estimate_unstale(
            bases[0], drec_s)
        np.testing.assert_allclose(
            np.asarray(tree_to_vector(
                jax.tree_util.tree_map(lambda a: a[b], w_hat_b))),
            np.asarray(tree_to_vector(w_hat_s)), atol=1e-4)


def test_batched_matches_sequential_masked(batch_setting):
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program, keep_fraction=0.1)
    deltas = [tree_sub(s, b) for s, b in zip(stales, bases)]
    masks = topk_mask_batch(deltas, 0.1)
    drec_b, _ = inv.invert_batch(tree_stack(bases), tree_stack(stales),
                                 keys, masks=masks)
    for b in range(3):
        drec_s, _ = inv.invert(bases[b], stales[b], keys[b], mask=masks[b])
        np.testing.assert_allclose(np.asarray(drec_b[0][b]),
                                   np.asarray(drec_s[0]), atol=1e-4)


def test_batched_per_client_iteration_budgets(batch_setting):
    """Dynamic per-client budgets share ONE compiled executable; lanes stop
    at their own n_iters and losses are NaN beyond the used prefix."""
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program)
    budgets = jnp.array([5, 20, 11], jnp.int32)
    drec_b, info = inv.invert_batch(tree_stack(bases), tree_stack(stales),
                                    keys, iters=budgets)
    np.testing.assert_array_equal(np.asarray(info["iters_used"]), [5, 20, 11])
    losses = np.asarray(info["losses"])
    assert np.isfinite(losses[0, :5]).all() and np.isnan(losses[0, 5:]).all()
    drec_s, _ = inv.invert(bases[2], stales[2], keys[2], iters=11)
    np.testing.assert_allclose(np.asarray(drec_b[0][2]),
                               np.asarray(drec_s[0]), atol=1e-4)


def test_batched_early_stop_via_loop_predicate(batch_setting):
    """tol > 0 turns into a while_loop predicate: lanes reaching the
    tolerance use fewer iterations."""
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program, iters=60, tol=1e8)
    # absurd tolerance: every lane should stop after the first iteration
    _, info = inv.invert_batch(tree_stack(bases), tree_stack(stales), keys)
    np.testing.assert_array_equal(np.asarray(info["iters_used"]), [1, 1, 1])


def test_batched_early_stop_matches_sequential_cadence(batch_setting):
    """The loop predicate checks tol on the seed's every-10th-iteration
    cadence, so tol-enabled configs keep batched == sequential (iteration
    counts AND recovered D_rec)."""
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program, iters=40, tol=5e-3)
    drec_b, info = inv.invert_batch(tree_stack(bases), tree_stack(stales),
                                    keys)
    used = np.asarray(info["iters_used"])
    for b in range(3):
        drec_s, info_s = inv.invert(bases[b], stales[b], keys[b])
        assert info_s["iters_used"] == int(used[b])
        np.testing.assert_allclose(np.asarray(drec_b[0][b]),
                                   np.asarray(drec_s[0]), atol=1e-4)


def test_batched_warm_start_round_trip(batch_setting):
    """Stacked WarmStartCache -> invert_batch -> put_stacked round trip:
    warm lanes start from the cached D_rec, cold lanes from the fresh init."""
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program, iters=8)
    cache = WarmStartCache()
    # seed the cache for clients 0 and 2 only
    drec0, _ = inv.invert(bases[0], stales[0], keys[0])
    drec2, _ = inv.invert(bases[2], stales[2], keys[2])
    cache.put(100, *drec0)
    cache.put(102, *drec2)
    xs, ys, warm = cache.gather([100, 101, 102])
    np.testing.assert_array_equal(warm, [True, False, True])
    np.testing.assert_allclose(np.asarray(xs[0]), np.asarray(drec0[0]))
    np.testing.assert_allclose(np.asarray(ys[2]), np.asarray(drec2[1]))

    drec_b, _ = inv.invert_batch(tree_stack(bases), tree_stack(stales),
                                 keys, inits=(xs, ys),
                                 init_flags=jnp.asarray(warm))
    # warm lane == sequential continuation from the cached init
    warm_s, _ = inv.invert(bases[0], stales[0], keys[0], init=drec0, iters=8)
    np.testing.assert_allclose(np.asarray(drec_b[0][0]),
                               np.asarray(warm_s[0]), atol=1e-4)
    # cold lane == sequential cold start from the same key
    cold_s, _ = inv.invert(bases[1], stales[1], keys[1], iters=8)
    np.testing.assert_allclose(np.asarray(drec_b[0][1]),
                               np.asarray(cold_s[0]), atol=1e-4)
    # store the batch back; every client is now warm
    cache.put_stacked([100, 101, 102], *drec_b)
    assert all(i in cache for i in (100, 101, 102))
    x1, _ = cache.get(101)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(drec_b[0][1]))


# --------------------------------------------------------------------------- #
# Segmented continuous-batching executor
# --------------------------------------------------------------------------- #


def _both_engines(inv, bases, stales, keys, seg, **kw):
    d1, i1 = inv.invert_batch(tree_stack(bases), tree_stack(stales), keys,
                              **kw)
    d2, i2 = inv.invert_batch(tree_stack(bases), tree_stack(stales), keys,
                              segment_iters=seg, **kw)
    return (d1, i1), (d2, i2)


def _assert_bitwise(d1, i1, d2, i2):
    np.testing.assert_array_equal(np.asarray(d1[0]), np.asarray(d2[0]))
    np.testing.assert_array_equal(np.asarray(d1[1]), np.asarray(d2[1]))
    np.testing.assert_array_equal(np.asarray(i1["iters_used"]),
                                  np.asarray(i2["iters_used"]))
    np.testing.assert_array_equal(np.asarray(i1["losses"]),
                                  np.asarray(i2["losses"]))
    np.testing.assert_array_equal(np.asarray(i1["final_loss"]),
                                  np.asarray(i2["final_loss"]))


def test_segmented_matches_oneshot_bitwise(batch_setting):
    """Acceptance: same per-lane math carried across K-iteration segments —
    D_rec, loss history, final loss and iteration counts are all bit-for-bit
    the one-shot engine's (K need not divide the budget)."""
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program)
    (d1, i1), (d2, i2) = _both_engines(inv, bases, stales, keys, seg=7)
    _assert_bitwise(d1, i1, d2, i2)
    assert i2["engine"] == "segmented" and i1["engine"] == "oneshot"


def test_segmented_tol_early_stop_bitwise(batch_setting):
    """tol early-stops happen inside segments on the seed's every-10th
    cadence — lanes stop at exactly the one-shot iteration counts even when
    K is not aligned to the cadence."""
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program, iters=40, tol=5e-3)
    for seg in (7, 10):
        (d1, i1), (d2, i2) = _both_engines(inv, bases, stales, keys, seg=seg)
        _assert_bitwise(d1, i1, d2, i2)


def test_segmented_skewed_budgets_shrink_and_occupancy(batch_setting):
    """Skewed per-client budgets: finished lanes are compacted out, the
    resident bucket shrinks down the pow2 ladder, and the telemetry accounts
    every paid lane-iteration."""
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program)
    budgets = jnp.array([4, 20, 9], jnp.int32)
    (d1, i1), (d2, i2) = _both_engines(inv, bases, stales, keys, seg=4,
                                       iters=budgets)
    _assert_bitwise(d1, i1, d2, i2)
    assert i2["segments"] > 1
    assert i2["buckets"][0] == 4 and i2["buckets"][-1] < i2["buckets"][0]
    assert i2["useful_lane_iters"] == 4 + 20 + 9
    assert (i2["useful_lane_iters"] + i2["wasted_lane_iters"]
            == i2["lane_iter_cost"])
    assert 0.0 < i2["occupancy"] <= 1.0
    # the one-shot engine pays bucket * slowest-lane; segmented must waste
    # strictly less on this skew
    oneshot_cost = i1["padded_to"] * int(np.asarray(i1["iters_used"]).max())
    assert i2["lane_iter_cost"] < oneshot_cost


def test_segmented_warm_starts_bitwise(batch_setting):
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program, iters=12)
    inits, _ = inv.invert_batch(tree_stack(bases), tree_stack(stales), keys)
    flags = jnp.array([True, False, True])
    (d1, i1), (d2, i2) = _both_engines(inv, bases, stales, keys, seg=5,
                                       inits=inits, init_flags=flags)
    _assert_bitwise(d1, i1, d2, i2)


def test_segmented_masked_bitwise(batch_setting):
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program, keep_fraction=0.1)
    deltas = [tree_sub(s, b) for s, b in zip(stales, bases)]
    masks = topk_mask_batch(deltas, 0.1)
    (d1, i1), (d2, i2) = _both_engines(inv, bases, stales, keys, seg=6,
                                       masks=masks)
    _assert_bitwise(d1, i1, d2, i2)


def test_segmented_queue_refill_across_segments(batch_setting):
    """max_lanes < cohort: the executor holds the rest in its pending queue
    and streams clients into lanes freed by compaction — results identical,
    and the lane cap is respected in every segment's bucket."""
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program)
    budgets = jnp.array([5, 20, 9], jnp.int32)
    (d1, i1), (d2, i2) = _both_engines(inv, bases, stales, keys, seg=5,
                                       iters=budgets, max_lanes=2)
    _assert_bitwise(d1, i1, d2, i2)
    assert max(i2["buckets"]) <= 2
    # 3 clients through <= 2 lanes forces at least one refill round
    assert i2["segments"] >= 3


def test_segmented_zero_budget_lane(batch_setting):
    """A zero-budget client flows through a lane untouched (D_rec = init,
    inf final loss, NaN loss history) exactly like the one-shot engine."""
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program)
    budgets = jnp.array([0, 20, 7], jnp.int32)
    (d1, i1), (d2, i2) = _both_engines(inv, bases, stales, keys, seg=6,
                                       iters=budgets)
    _assert_bitwise(d1, i1, d2, i2)
    assert np.isinf(np.asarray(i2["final_loss"])[0])


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_segmented_mesh_matches_unsharded(batch_setting, n_devices):
    """Sharded segmented executor: per-shard segments + per-shard compaction
    buckets. A 1-device mesh must be bit-for-bit the unsharded segmented
    engine; 2/4 shards agree to 1e-4/client (bitwise on this container)."""
    from repro.launch.mesh import make_server_mesh
    if len(jax.devices()) < n_devices:
        pytest.skip(f"needs {n_devices} devices "
                    f"(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    model, program, bases, stales, keys = batch_setting
    inv = _make_inverter(model, program)
    budgets = jnp.array([4, 20, 9], jnp.int32)
    d_ref, i_ref = inv.invert_batch(tree_stack(bases), tree_stack(stales),
                                    keys, iters=budgets, segment_iters=5)
    cfg = GIConfig(n_rec=6, iters=20, lr=0.1)
    inv_m = GradientInverter(model.apply, model.input_shape, model.n_classes,
                             program, cfg, mesh=make_server_mesh(n_devices))
    d_m, i_m = inv_m.invert_batch(tree_stack(bases), tree_stack(stales),
                                  keys, iters=budgets, segment_iters=5)
    np.testing.assert_array_equal(np.asarray(i_ref["iters_used"]),
                                  np.asarray(i_m["iters_used"]))
    if n_devices == 1:
        np.testing.assert_array_equal(np.asarray(d_ref[0]),
                                      np.asarray(d_m[0]))
        np.testing.assert_array_equal(np.asarray(d_ref[1]),
                                      np.asarray(d_m[1]))
    else:
        np.testing.assert_allclose(np.asarray(d_ref[0]), np.asarray(d_m[0]),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(d_ref[1]), np.asarray(d_m[1]),
                                   atol=1e-4)


# --------------------------------------------------------------------------- #
# Server integration
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def tiny_fl():
    n_classes, hw = 3, 8
    x, y = make_image_dataset(60, n_classes=n_classes, hw=hw, seed=0)
    tx, ty = make_image_dataset(15, n_classes=n_classes, hw=hw, seed=9)
    idx = dirichlet_partition(y, 8, alpha=0.5, seed=0)
    cx, cy, cm = pad_client_shards(x, y, idx, m=12)
    hist = client_label_histograms(y, idx, n_classes)
    return n_classes, hw, cx, cy, cm, hist, tx, ty


def _tiny_server(tiny_fl, tau=2, rounds=6, batched=True, seed=0,
                 switch_every=1, **gi_kwargs):
    from repro.models.small import lenet
    n_classes, hw, cx, cy, cm, hist, tx, ty = tiny_fl
    sched = intertwined_schedule(hist, target_class=1, n_slow=2, tau=tau)
    prog = LocalProgram(steps=3, lr=0.1, momentum=0.5)
    cfg = FLConfig(strategy="ours", rounds=rounds,
                   gi=GIConfig(n_rec=6, iters=6, lr=0.1, keep_fraction=0.2,
                               **gi_kwargs),
                   batched_gi=batched, eval_every=rounds,
                   uniqueness_check=False,  # force GI on every delivery
                   switch_check_every=switch_every, seed=seed)
    return Server(lenet(n_classes=n_classes, in_hw=hw), prog, cfg,
                  cx, cy, cm, sched, tx, ty)


@pytest.mark.slow
def test_server_batched_equals_sequential_engine(tiny_fl):
    """Same seed, same rounds: the batched server path and the sequential
    fallback aggregate to the same global model."""
    srv_b = _tiny_server(tiny_fl, batched=True)
    srv_s = _tiny_server(tiny_fl, batched=False)
    srv_b.run()
    srv_s.run()
    vb = np.asarray(tree_to_vector(srv_b.global_params))
    vs = np.asarray(tree_to_vector(srv_s.global_params))
    np.testing.assert_allclose(vb, vs, atol=1e-4)
    assert len(srv_b.gi_log) == len(srv_s.gi_log) > 0


def test_pending_checks_use_scheduled_clients_data(tiny_fl):
    """Regression for the seed bug: pending E1/E2 checks always recomputed
    w_true from the FIRST slow client. Two checks scheduled for different
    clients must observe different true updates."""
    srv = _tiny_server(tiny_fl, tau=2, rounds=3)
    srv.run()  # builds history; also exercises the real scheduling path

    # the live scheduling path stores (t, client, w_hat, w_stale) tuples
    live = ([c for lst in srv._pending_checks.values() for c in lst]
            + [(h["t"], None, None, None) for h in srv.monitor.history])
    assert live, "no E1/E2 checks were scheduled or observed"
    for (t0, i, _, _) in live:
        assert isinstance(t0, int)
        if i is not None:
            assert i in srv.schedule.slow_clients

    slow = srv.schedule.slow_clients
    assert len(slow) >= 2
    i1, i2 = slow[0], slow[1]
    w_hat = srv.global_params
    w_stale = srv.history[0]
    srv.monitor.history.clear()
    srv._pending_checks = {0: [(0, i1, w_hat, w_stale),
                               (0, i2, w_hat, w_stale)]}
    srv._run_pending_checks(t=5)
    assert len(srv.monitor.history) == 2
    e1_a, e1_b = (h["E1"] for h in srv.monitor.history)
    # identical (w_hat, w_stale) pairs but different clients: the observed
    # disparities must differ because w_true differs per client. Under the
    # old bug both checks used slow_clients[0]'s data and were equal.
    assert abs(e1_a - e1_b) > 1e-9

    # and the fix recomputes exactly client i's true update
    x, y, m = srv._client_shard(i2)
    w_true = srv._local_update(srv.history[0], x, y, m)[0]
    from repro.core.disparity import cosine_distance
    expect = float(cosine_distance(w_hat, w_true))
    np.testing.assert_allclose(srv.monitor.history[1]["E1"], expect,
                               rtol=1e-6)


def test_server_segmented_engine_matches_oneshot(tiny_fl):
    """FLConfig(gi=GIConfig(segment_iters=K)) routes _ours_update_batch
    through the segmented executor; the aggregated global model matches the
    one-shot engine bit-for-bit (same per-lane math)."""
    srv_1 = _tiny_server(tiny_fl, rounds=4)
    srv_s = _tiny_server(tiny_fl, rounds=4, segment_iters=2)
    srv_1.run()
    srv_s.run()
    v1 = np.asarray(tree_to_vector(srv_1.global_params))
    vs = np.asarray(tree_to_vector(srv_s.global_params))
    np.testing.assert_array_equal(v1, vs)
    assert len(srv_s.gi_log) == len(srv_1.gi_log) > 0


def test_server_reports_gi_occupancy(tiny_fl):
    """Rounds that ran GI carry executor occupancy telemetry in their
    metrics row (both engines); rounds without GI don't."""
    for kw in ({}, {"segment_iters": 3}):
        srv = _tiny_server(tiny_fl, rounds=4, **kw)
        srv.run()
        gi_rows = [r for r in srv.metrics if "gi_occupancy" in r]
        assert gi_rows, "no GI round reported occupancy"
        for r in gi_rows:
            assert 0.0 < r["gi_occupancy"] <= 1.0
            assert r["gi_wasted_lane_iters"] >= 0.0
        # the schedule's first tau rounds deliver no stale updates => no GI
        assert "gi_occupancy" not in srv.metrics[0]


def test_server_segmented_with_lane_cap(tiny_fl):
    """A lane cap below the cohort size streams clients through the pending
    queue; the trajectory stays within ULP-level tolerance of the uncapped
    engine (conv kernels may regroup batches, so not bitwise)."""
    srv_1 = _tiny_server(tiny_fl, rounds=4)
    srv_c = _tiny_server(tiny_fl, rounds=4, segment_iters=2, max_lanes=1)
    srv_1.run()
    srv_c.run()
    v1 = np.asarray(tree_to_vector(srv_1.global_params))
    vc = np.asarray(tree_to_vector(srv_c.global_params))
    np.testing.assert_allclose(v1, vc, atol=1e-5)
