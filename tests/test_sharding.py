"""Sharding-rule tests (no 512-device env needed: specs are mesh-shape
functions; we build a small host mesh with the same axis names)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_abstract_mesh, make_mesh_compat
from repro.models import transformer as T
from repro.optim import sgd


def host_mesh():
    # 1x1 mesh with production axis names: divisibility guards all pass
    # trivially, structure checks still exercise every rule
    return make_mesh_compat((1, 1), ("data", "model"))


def abstract_mesh(shape, names):
    # spec rules only read mesh.shape/axis_names; AbstractMesh lets tests use
    # production-sized meshes without 512 fabricated devices
    return make_abstract_mesh(shape, names)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mode", ["tp", "fsdp_tp", "tp2"])
def test_param_specs_match_tree_structure(arch, mode):
    cfg = get_config(arch)
    mesh = host_mesh()
    shapes = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    specs = shd.param_specs(cfg, mesh, mode, shapes)
    # same treedef
    assert (jax.tree_util.tree_structure(shapes)
            == jax.tree_util.tree_structure(
                specs, is_leaf=lambda x: isinstance(x, P)))
    # every spec rank matches its leaf rank
    flat_s = jax.tree_util.tree_leaves_with_path(shapes)
    flat_p = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    for (kp, leaf), (_, spec) in zip(flat_s, flat_p):
        assert len(spec) <= len(leaf.shape), (kp, spec, leaf.shape)


def test_divisibility_guard():
    """whisper vocab 51865 is odd -> must not be sharded on model(16)."""
    cfg = get_config("whisper_tiny")
    mesh = abstract_mesh((1, 2), ("data", "model"))
    shapes = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    specs = shd.param_specs(cfg, mesh, "tp", shapes)
    emb = specs["embed"]
    assert emb[0] is None  # vocab not divisible by 2? 51865 odd -> unsharded
    assert emb[1] == "model"  # falls back to d_model sharding


def test_state_specs_cover_opt_state():
    cfg = get_config("qwen3_1_7b")
    mesh = host_mesh()
    opt = sgd(0.01, momentum=0.9)
    specs = shd.state_specs(cfg, mesh, "tp", opt)
    assert set(specs) == {"params", "opt", "step"}
    # momentum mirrors params structure
    assert (jax.tree_util.tree_structure(
        specs["opt"], is_leaf=lambda x: isinstance(x, P))
        == jax.tree_util.tree_structure(
            specs["params"], is_leaf=lambda x: isinstance(x, P)))


def test_batch_specs_shard_batch_dim():
    cfg = get_config("qwen3_1_7b")
    mesh = abstract_mesh((2, 1), ("data", "model"))
    batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    specs = shd.batch_specs(cfg, batch, mesh)
    assert specs["tokens"] == P(("data",), None)


def test_batch_specs_mrope_positions():
    cfg = get_config("qwen2_vl_7b")
    mesh = abstract_mesh((2, 1), ("data", "model"))
    batch = {"positions": jax.ShapeDtypeStruct((3, 8, 64), jnp.int32)}
    specs = shd.batch_specs(cfg, batch, mesh)
    assert specs["positions"] == P(None, ("data",), None)


def test_cache_specs_decode_vs_long():
    cfg = get_config("qwen3_1_7b")
    mesh = abstract_mesh((2, 2), ("data", "model"))
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 8, 1024))
    specs = shd.cache_specs(cfg, cache, mesh, batch=8)
    assert specs["k"][1] in ("data", ("data",))   # batch shardable
    assert specs["k"][2] == "model"            # seq on model
    cache1 = jax.eval_shape(lambda: T.init_cache(cfg, 1, 1024))
    specs1 = shd.cache_specs(cfg, cache1, mesh, batch=1)
    assert specs1["k"][1] is None              # batch=1 replicated
    assert specs1["k"][2] is not None          # seq sharded over all axes
