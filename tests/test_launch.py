"""Launcher tests: dry-run subprocess (512 fabricated devices) + FL driver."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


@pytest.mark.slow
def test_dryrun_subprocess_single_combo(tmp_path):
    """The dry-run must lower+compile a full production config on the 16x16
    mesh inside a fresh process (XLA_FLAGS is set by the module itself)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen1.5-0.5b", "--shape", "decode_32k",
         "--out-dir", str(tmp_path)],
        env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout + out.stderr
    arts = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
    assert len(arts) == 1
    rec = json.load(open(tmp_path / arts[0]))
    assert rec["memory"]["peak_per_device"] < 16 * 2**30   # fits v5e HBM
    assert rec["cost"]["flops"] > 0


@pytest.mark.slow
def test_fl_train_launcher():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--strategy", "ours",
         "--rounds", "4", "--clients", "6", "--n-per-class", "40",
         "--gi-iters", "5", "--eval-every", "4"],
        env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout + out.stderr
    line = [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    rec = json.loads(line)
    assert 0.0 <= rec["final_acc"] <= 1.0


@pytest.mark.slow
def test_decode_launcher():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.decode", "--arch", "qwen3-1.7b",
         "--batch", "2", "--prompt-len", "8", "--gen-len", "4"],
        env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "tok/s" in out.stdout
