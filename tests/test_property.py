"""Property-based tests (hypothesis) for system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dependency: property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import aggregation, compensation
from repro.core.disparity import cosine_distance, l1_disparity, tree_to_vector
from repro.core.sparsify import topk_mask
from repro.core.tiers import cluster_tiers
from repro.data.partition import dirichlet_partition

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

vec = st.lists(st.floats(-10, 10, allow_nan=False, width=32),
               min_size=4, max_size=32)


@given(vec)
def test_fedavg_idempotent_on_identical_updates(v):
    u = {"w": jnp.asarray(v, jnp.float32)}
    agg = aggregation.fedavg([u, u, u])
    np.testing.assert_allclose(agg["w"], u["w"], atol=1e-6)


@given(vec, st.lists(st.floats(0.1, 10), min_size=3, max_size=3))
def test_fedavg_convex_combination_bounds(v, ws):
    """FedAvg output is coordinate-wise within [min, max] of the updates."""
    us = [{"w": jnp.asarray(v, jnp.float32) * s} for s in (0.5, 1.0, 2.0)]
    agg = aggregation.fedavg(us, ws)
    stack = np.stack([np.asarray(u["w"]) for u in us])
    assert np.all(np.asarray(agg["w"]) <= stack.max(0) + 1e-5)
    assert np.all(np.asarray(agg["w"]) >= stack.min(0) - 1e-5)


@given(vec, st.floats(1.1, 100))
def test_cosine_distance_scale_invariant(v, scale):
    a = {"w": jnp.asarray(v, jnp.float32) + 0.01}
    b = {"w": (jnp.asarray(v, jnp.float32) + 0.01) * scale}
    assert abs(float(cosine_distance(a, b))) < 1e-4


@given(st.lists(st.floats(-5, 5, allow_nan=False, width=32),
                min_size=10, max_size=60),
       st.floats(0.05, 0.9))
def test_topk_mask_count_and_dominance(v, frac):
    u = {"w": jnp.asarray(v, jnp.float32)}
    m = np.asarray(topk_mask(u, frac))
    k = max(1, int(round(len(v) * frac)))
    # ties can push the count above k, never below
    assert m.sum() >= k
    # every kept magnitude >= every dropped magnitude
    mags = np.abs(np.asarray(v, np.float32))
    if m.sum() < len(v):
        assert mags[m].min() >= mags[~m].max() - 1e-6


@given(st.integers(2, 30), st.floats(0.05, 5.0), st.integers(0, 5))
def test_dirichlet_partition_is_exact_cover(n_clients, alpha, seed):
    y = np.repeat(np.arange(4), 25)
    parts = dirichlet_partition(y, n_clients, alpha, seed)
    allidx = np.concatenate([p for p in parts if len(p)]) if parts else []
    assert len(allidx) == len(y)
    assert len(np.unique(allidx)) == len(y)


@given(st.lists(st.integers(0, 100), min_size=2, max_size=20),
       st.integers(1, 4))
def test_cluster_tiers_partition_property(staleness, n_tiers):
    tiers = cluster_tiers(staleness, n_tiers)
    flat = sorted(i for t in tiers for i in t)
    assert flat == list(range(len(staleness)))


@given(vec)
def test_first_order_zero_delta_is_identity(v):
    u = {"w": jnp.asarray(v, jnp.float32)}
    w = {"w": jnp.asarray(v, jnp.float32) * 0.3}
    out = compensation.first_order(u, w, w, lam=3.0)
    np.testing.assert_allclose(out["w"], u["w"], atol=1e-6)


@given(st.floats(0, 200))
def test_staleness_weight_monotone_decreasing(tau):
    w1 = compensation.staleness_weight(tau)
    w2 = compensation.staleness_weight(tau + 1)
    assert 0.0 <= w2 <= w1 <= 1.0


@given(vec)
def test_l1_disparity_triangle_inequality(v):
    a = {"w": jnp.asarray(v, jnp.float32)}
    b = {"w": jnp.asarray(v, jnp.float32) * 0.5}
    c = {"w": jnp.asarray(v, jnp.float32) * -0.25}
    ab = float(l1_disparity(a, b))
    bc = float(l1_disparity(b, c))
    ac = float(l1_disparity(a, c))
    assert ac <= ab + bc + 1e-5
