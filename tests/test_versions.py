"""VersionStore: ring-buffer exactness, host spill, bounded device memory.

The fused aggregation round's equivalence oracle rests on one contract:
every version read back from the store — ring row, spilled row, or a mixed
``gather`` — is bit-for-bit the params that were appended. These tests pin
that contract plus the boundedness claim (device bytes constant while the
version count grows without limit).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.versions import VersionStore


def _tree(seed, scale=1.0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"w": jax.random.normal(k1, (4, 3)) * scale,
            "b": {"c": jax.random.normal(k2, (5,)) * scale},
            "s": jnp.asarray(float(seed))}      # scalar leaf


def _assert_tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_append_get_roundtrip_within_capacity():
    store = VersionStore(_tree(0), capacity=8)
    refs = [_tree(i) for i in range(5)]
    for i, t in enumerate(refs):
        assert store.append(t) == i
    assert len(store) == 5
    for i, t in enumerate(refs):
        _assert_tree_equal(store[i], t)
    # negative indexing mirrors the historic list API
    _assert_tree_equal(store[-1], refs[-1])
    _assert_tree_equal(store[-5], refs[0])
    with pytest.raises(IndexError):
        store[5]
    with pytest.raises(IndexError):
        store[-6]


def test_iteration_matches_list():
    store = VersionStore(_tree(0), capacity=4)
    refs = [_tree(10 + i) for i in range(7)]       # wraps + spills
    for t in refs:
        store.append(t)
    seen = list(store)
    assert len(seen) == 7
    for got, ref in zip(seen, refs):
        _assert_tree_equal(got, ref)


def test_spill_keeps_old_versions_exact():
    store = VersionStore(_tree(0), capacity=3)
    refs = [_tree(i, scale=1.0 + 0.1 * i) for i in range(10)]
    for t in refs:
        store.append(t)
    assert store.window_start == 7
    assert store.n_spilled == 7
    for i, t in enumerate(refs):               # spilled AND resident rows
        _assert_tree_equal(store[i], t)


def test_device_memory_bounded_at_capacity():
    store = VersionStore(_tree(0), capacity=4)
    baseline = store.device_bytes
    for i in range(50):
        store.append(_tree(i))
        assert store.device_bytes == baseline   # ring never grows
    ring_shapes = [l.shape for l in jax.tree_util.tree_leaves(store._ring)]
    assert all(s[0] == 4 for s in ring_shapes)
    assert len(store) == 50 and store.n_spilled == 46


def test_gather_mixed_window_and_spill():
    store = VersionStore(_tree(0), capacity=3)
    refs = [_tree(i) for i in range(8)]
    for t in refs:
        store.append(t)
    versions = [0, 6, 3, 7, 0, 5]              # spilled, resident, repeats
    stacked = store.gather(versions)
    for row, v in enumerate(versions):
        _assert_tree_equal(
            jax.tree_util.tree_map(lambda a: a[row], stacked), refs[v])
    with pytest.raises(IndexError):
        store.gather([0, 8])
    with pytest.raises(IndexError):
        store.gather([-1])


def test_gather_matches_getitem_stack():
    store = VersionStore(_tree(0), capacity=4)
    for i in range(6):
        store.append(_tree(i))
    versions = [1, 5, 4, 0]
    stacked = store.gather(versions)
    manual = jax.tree_util.tree_map(
        lambda *a: jnp.stack(a), *[store[v] for v in versions])
    _assert_tree_equal(stacked, manual)


def test_spill_disabled_evicts():
    store = VersionStore(_tree(0), capacity=2, spill=False)
    for i in range(5):
        store.append(_tree(i))
    _assert_tree_equal(store[4], _tree(4))
    _assert_tree_equal(store[3], _tree(3))
    with pytest.raises(KeyError):
        store[1]                               # evicted, no host copy
    with pytest.raises(KeyError):
        store.gather([1, 4])
    assert store.n_spilled == 0


def test_capacity_validation():
    with pytest.raises(ValueError):
        VersionStore(_tree(0), capacity=0)


def test_dtype_preserved():
    t = {"w": jnp.ones((3,), jnp.float32), "n": jnp.asarray(2, jnp.int32)}
    store = VersionStore(t, capacity=2)
    store.append(t)
    got = store[0]
    assert got["w"].dtype == jnp.float32
    assert got["n"].dtype == jnp.int32
