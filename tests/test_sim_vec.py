"""Heap-vs-vectorized engine equivalence suite (repro.sim.engine_vec).

The vectorized engine is only allowed to exist because it replays the heap
oracle bit-for-bit: every test here pins some axis of that contract —
trace digests across the stock scenarios, wheel resolution and edge fan-in
invariance, counter-based RNG block slicing, fast-mode summaries, staged
``run(until=...)`` resume, and the dropout/cancellation bookkeeping the
accounting fixes in this layer exist to protect.
"""

import numpy as np
import pytest

from repro.sim import (FedBuffK, FleetArrays, LatencyDist, NullAggregator,
                       PureAsync, RecordingAggregator, SemiSyncDeadline,
                       SimEngine, VecEngine, homogeneous_fleet, trace_fleet)
from repro.sim.rand import (JobRandoms, job_uniforms, lognormal_from_uniforms,
                            pareto_from_uniforms, trace_from_uniforms)
from repro.sim.scenarios import _ENGINE_PARTS, engine_only
from repro.sim.wheel import TimeWheel, merge_chunks, sort_chunk

# regenerated deliberately in this PR: the per-job counter-based RNG and
# the distinct-client FedBuff trigger both change the event stream vs the
# sequential-stream engine these scenarios shipped with
STOCK_DIGESTS = {
    "degenerate_sync": "d3c9bef802dcc8f4",
    "semi_sync_deadline": "7badebe186d4c157",
    "pure_async": "070c41fe59505b69",
    "fedbuff_k4": "915e97d00a7bf144",
    "heavy_churn": "61e2f2ecc64fe54b",
}


def _summaries_equal(a, b):
    ka = {k: v for k, v in a.items() if k != "trace_digest"}
    kb = {k: v for k, v in b.items() if k != "trace_digest"}
    assert ka == kb


# --------------------------------------------------------------------------- #
# Scenario-level equivalence
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("name", sorted(_ENGINE_PARTS))
def test_stock_scenario_digest_equivalence(name):
    heap = engine_only(name, seed=0, engine="heap")
    heap.run()
    assert heap.trace_digest() == STOCK_DIGESTS[name]
    vec = engine_only(name, seed=0, engine="vec")
    vec.run()
    assert vec.trace_digest() == STOCK_DIGESTS[name]
    _summaries_equal(heap.summary(), vec.summary())


@pytest.mark.parametrize("wheel_dt", [0.25, 1.0, 5.0, 1000.0])
def test_wheel_resolution_is_pure_throughput_knob(wheel_dt):
    # any bucket width replays the exact same event sequence
    for name in ("heavy_churn", "fedbuff_k4"):
        vec = engine_only(name, seed=0, engine="vec", wheel_dt=wheel_dt)
        vec.run()
        assert vec.trace_digest() == STOCK_DIGESTS[name], (name, wheel_dt)


@pytest.mark.parametrize("n_edges", [3, 10])
def test_edge_fanin_preserves_cohorts(n_edges):
    # per-edge buffers dedup independently; concatenating the contiguous
    # edge ranges reproduces the flat engine's cohorts exactly
    for name in ("semi_sync_deadline", "heavy_churn"):
        vec = engine_only(name, seed=0, engine="vec", n_edges=n_edges)
        vec.run()
        assert vec.trace_digest() == STOCK_DIGESTS[name], (name, n_edges)


def test_fast_mode_matches_traced_summary():
    for name in ("pure_async", "heavy_churn"):
        traced = engine_only(name, seed=0, engine="vec")
        traced.run()
        fast = engine_only(name, seed=0, engine="vec", record_trace=False,
                           record_realized=False, collect_agg_log=False)
        fast.run()
        st, sf = traced.summary(), fast.summary()
        assert sf.pop("trace_digest") == "untraced"
        st.pop("trace_digest")
        assert st == sf, name


def test_deferred_upload_fast_path_is_exact():
    # with no dropouts and a declared-no-op upload hook, fast mode keeps
    # uploads out of the wheel and commits them by (time, seq) just before
    # the next wheel event. Fixed latencies land uploads EXACTLY on round
    # and eval ticks, pinning the seq tie-break: round-before-upload,
    # upload-before-next-round's-dispatches
    cfgs = [
        (homogeneous_fleet(12, LatencyDist("lognormal", 0.9, 0.4)),
         True, None),
        (homogeneous_fleet(8, LatencyDist("fixed", 1.0)), True, 2.0),
        (homogeneous_fleet(8, LatencyDist("fixed", 1.0)), False, 1.0),
    ]
    for fleet, pipelined, eval_every in cfgs:
        heap = SimEngine(fleet, SemiSyncDeadline(1.0, pipelined=pipelined),
                         RecordingAggregator(), seed=0, horizon=9.0,
                         eval_every_time=eval_every)
        sh = heap.run()
        fast = VecEngine(fleet, SemiSyncDeadline(1.0, pipelined=pipelined),
                         RecordingAggregator(), seed=0, horizon=9.0,
                         eval_every_time=eval_every, record_trace=False,
                         record_realized=False, collect_agg_log=False)
        assert fast._fast_uploads          # the path actually engages
        sf = fast.run()
        sh.pop("trace_digest"), sf.pop("trace_digest")
        assert sh == sf
        # staged resume keeps pending deferred uploads across run() calls
        # (compared against a STAGED heap run: re-armed timers at a resume
        # legitimately reorder coincident ticks vs a one-shot run)
        staged = VecEngine(fleet, SemiSyncDeadline(1.0, pipelined=pipelined),
                           RecordingAggregator(), seed=0, horizon=4.0,
                           eval_every_time=eval_every, record_trace=False,
                           record_realized=False, collect_agg_log=False)
        staged.run()
        staged.run(until=9.0)
        staged_heap = SimEngine(fleet,
                                SemiSyncDeadline(1.0, pipelined=pipelined),
                                RecordingAggregator(), seed=0, horizon=4.0,
                                eval_every_time=eval_every)
        staged_heap.run()
        staged_heap.run(until=9.0)
        ss, ssh = staged.summary(), staged_heap.summary()
        ss.pop("trace_digest"), ssh.pop("trace_digest")
        assert ss == ssh


def test_staged_resume_matches_across_engines():
    # satellite: run(until=...) twice — the eval tick re-arms and both
    # engines replay the identical staged event sequence
    for name in sorted(_ENGINE_PARTS):
        _, _, horizon, _ = _ENGINE_PARTS[name]
        mid = horizon / 2.0
        heap = engine_only(name, seed=0, engine="heap")
        heap.run(until=mid)
        heap.run(until=horizon)
        vec = engine_only(name, seed=0, engine="vec")
        vec.run(until=mid)
        vec.run(until=horizon)
        assert heap.trace_digest() == vec.trace_digest(), name
        _summaries_equal(heap.summary(), vec.summary())
        assert len(heap.evals) == len(vec.evals)


def test_vec_engine_drives_real_server():
    # the vectorized engine slots under the ServerBridge unchanged: the
    # degenerate oracle reproduces the heap run digest with jax in the loop
    from repro.sim import scenarios
    a = scenarios.build("degenerate_sync", seed=0, horizon=3.0, gi_iters=2,
                        engine="heap").run()
    b = scenarios.build("degenerate_sync", seed=0, horizon=3.0, gi_iters=2,
                        engine="vec").run()
    assert a["trace_digest"] == b["trace_digest"]
    assert a["final_acc"] == b["final_acc"]


# --------------------------------------------------------------------------- #
# Accounting-fix coverage (dropout storms, cancellation after rejoin)
# --------------------------------------------------------------------------- #


def _churn_engines(seed=2):
    fleet = homogeneous_fleet(6, LatencyDist("lognormal", 1.0, 0.4),
                              dropout_prob=0.3,
                              downtime=LatencyDist("fixed", 0.5))
    mk = lambda E: E(fleet, SemiSyncDeadline(1.0, pipelined=True),  # noqa: E731
                     RecordingAggregator(), seed=seed, horizon=12.0)
    return mk(SimEngine), mk(VecEngine)


def test_doomed_job_with_pipelined_inflight():
    # a dropout kills the failing job AND every pipelined job in flight:
    # lost_jobs must exceed dropouts, identically on both engines
    heap, vec = _churn_engines()
    sh, sv = heap.run(), vec.run()
    assert sh["dropouts"] > 0
    assert sh["lost_jobs"] > sh["dropouts"]
    assert heap.trace_digest() == vec.trace_digest()
    _summaries_equal(sh, sv)
    assert sh["dispatches"] == sh["arrivals"] + sh["lost_jobs"] \
        + sh["inflight"]


def test_cancelled_upload_after_rejoin():
    # an upload whose job was killed by a dropout arrives AFTER the client
    # rejoined: it must be dropped as cancelled, not buffered — and the
    # buffers must agree entry-for-entry across engines
    heap, vec = _churn_engines()
    sh, sv = heap.run(), vec.run()
    assert sh["cancelled_uploads"] > 0
    assert sh["rejoins"] > 0
    assert sh["cancelled_uploads"] == sv["cancelled_uploads"]
    assert [(a.client, a.base_version, a.job_id) for a in heap.buffer] == \
        [(a.client, a.base_version, a.job_id) for a in vec.buffer]


# --------------------------------------------------------------------------- #
# RNG: counter-based per-job blocks
# --------------------------------------------------------------------------- #


def test_job_uniform_wave_slicing_is_bitwise():
    whole = job_uniforms(seed=5, job0=0, n=64)
    # any sub-wave drawn at its own counter offset is the same bits
    for j0, k in [(0, 1), (7, 3), (10, 54), (63, 1)]:
        assert np.array_equal(job_uniforms(5, j0, k), whole[j0:j0 + k])
    # the chunk-cached per-job accessor the heap oracle uses agrees too
    jr = JobRandoms(seed=5)
    for j in (0, 13, 63):
        assert np.array_equal(jr.block(j), whole[j])


def test_transforms_scalar_vs_wave_bitwise():
    u = job_uniforms(seed=9, job0=0, n=257)
    u1, u2 = u[:, 0], u[:, 1]
    table = np.sort(np.random.default_rng(0).uniform(0.1, 4.0, 100))
    wave_ln = lognormal_from_uniforms(1.3, 0.7, u1.copy(), u2.copy())
    wave_pa = pareto_from_uniforms(1.3, 0.7, u1)
    wave_tr = trace_from_uniforms(1.3, table, u1)
    for i in range(0, 257, 41):
        assert lognormal_from_uniforms(1.3, 0.7, u1[i], u2[i]) == wave_ln[i]
        assert pareto_from_uniforms(1.3, 0.7, u1[i]) == wave_pa[i]
        assert trace_from_uniforms(1.3, table, u1[i]) == wave_tr[i]


def test_fleet_arrays_match_profile_blocks():
    fleet = homogeneous_fleet(16, LatencyDist("lognormal", 1.2, 0.4),
                              network=LatencyDist("pareto", 0.1, 0.3),
                              dropout_prob=0.2,
                              downtime=LatencyDist("fixed", 2.0))
    fa = fleet.arrays()
    cl = np.arange(16, dtype=np.int64)
    u = job_uniforms(seed=3, job0=100, n=16)
    lat = fa.job_latency(cl, u)
    drops = fa.job_drops(cl, u)
    down = fa.downtime_of(cl, u)
    for i in range(16):
        assert fleet.job_latency_from_block(i, u[i]) == lat[i]
        assert fleet.job_drops_from_block(i, u[i]) == drops[i]
        assert fleet.downtime_from_block(i, u[i]) == down[i]


def test_trace_latency_dist():
    table = [0.5, 1.0, 2.0, 8.0]
    d = LatencyDist("trace", 2.0, table=table)
    rng = np.random.default_rng(0)
    vals = {d.sample(rng) for _ in range(200)}
    assert vals <= {1.0, 2.0, 4.0, 16.0}      # loc-scaled table entries
    assert len(vals) > 1
    fleet = trace_fleet(4, table, loc_spread=0.3, seed=1)
    heap = SimEngine(fleet, PureAsync(), RecordingAggregator(), seed=0,
                     horizon=10.0)
    vec = VecEngine(fleet, PureAsync(), RecordingAggregator(), seed=0,
                    horizon=10.0)
    sh, sv = heap.run(), vec.run()
    assert heap.trace_digest() == vec.trace_digest()
    _summaries_equal(sh, sv)


# --------------------------------------------------------------------------- #
# Time wheel unit tests
# --------------------------------------------------------------------------- #


def _mk_chunk(times, seq0=0):
    n = len(times)
    t = np.asarray(times, float)
    return (t, np.arange(seq0, seq0 + n), np.zeros(n, np.int8),
            np.arange(n, dtype=np.int64), np.arange(n, dtype=np.int64),
            np.zeros(n, bool))


def test_sort_chunk_is_time_seq_lexsort():
    # duplicate times force the stable fallback: seq (storage) order must
    # survive within every tie group
    c = _mk_chunk([3.0, 1.0, 1.0, 2.0, 1.0])
    out = sort_chunk(c)
    assert out[0].tolist() == [1.0, 1.0, 1.0, 2.0, 3.0]
    assert out[1].tolist() == [1, 2, 4, 3, 0]


def test_merge_chunks_is_exact():
    rng = np.random.default_rng(1)
    for _ in range(20):
        na, nb = rng.integers(1, 40, 2)
        a = sort_chunk(_mk_chunk(rng.integers(0, 10, na).astype(float)))
        b = sort_chunk(_mk_chunk(rng.integers(0, 10, nb).astype(float),
                                 seq0=1000))
        m = merge_chunks(a, b)
        ref = sort_chunk(tuple(np.concatenate([x, y])
                               for x, y in zip(a, b)))
        for x, y in zip(m, ref):
            assert np.array_equal(x, y)


def test_wheel_drains_in_time_seq_order():
    w = TimeWheel(dt=1.0)
    t1 = np.array([2.5, 0.5, 7.1, 0.5])
    w.push(t1, np.arange(4), np.zeros(4, np.int8),
           np.arange(4, dtype=np.int64), np.arange(4, dtype=np.int64),
           np.zeros(4, bool))
    t2 = np.array([0.5, 2.5])
    w.push(t2, np.arange(10, 12), np.ones(2, np.int8),
           np.arange(2, dtype=np.int64), np.arange(2, dtype=np.int64),
           np.zeros(2, bool))
    assert len(w) == 6
    drained = []
    while (b := w.next_bucket()) is not None:
        chunk = w.take(b)
        drained += list(zip(chunk[0].tolist(), chunk[1].tolist()))
    assert drained == sorted(drained)          # global (time, seq) order
    assert drained == [(0.5, 1), (0.5, 3), (0.5, 10), (2.5, 0), (2.5, 11),
                       (7.1, 2)]
    assert len(w) == 0 and w.next_bucket() is None


# --------------------------------------------------------------------------- #
# Scale smoke (the benchmark path, shrunk)
# --------------------------------------------------------------------------- #


def test_null_aggregator_scale_smoke():
    fa = FleetArrays.homogeneous(
        10_000, compute=LatencyDist("lognormal", 0.8, 0.3),
        network=LatencyDist("lognormal", 0.05, 0.2))
    eng = VecEngine(fa, SemiSyncDeadline(1.0, pipelined=True),
                    NullAggregator(), seed=0, horizon=5.0,
                    max_events=10_000_000, wheel_dt=0.5,
                    record_trace=False, record_realized=False,
                    collect_agg_log=False)
    s = eng.run()
    assert s["events"] > 80_000
    assert eng.aggregator.n_updates == s["arrivals"] - s["superseded"] \
        - s["buffer_pending"]
