"""Tests for the event-driven async FL simulator (repro.sim).

Covers: deterministic replay (same seed => identical event trace), trigger
policy semantics (FedBuff-K counts, pure-async, semi-sync deadlines),
dropout/rejoin bookkeeping invariants, the observed-staleness view, and the
acceptance oracle — a degenerate scenario (zero latency variance, no
dropout, pipelined deadline) reproduces the round-synchronous ``Server``
trajectory bit-for-bit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.staleness import observed_schedule
from repro.sim import (DeviceFleet, DeviceProfile, FedBuffK, LatencyDist,
                       PureAsync, RecordingAggregator, SemiSyncDeadline,
                       SimEngine, homogeneous_fleet, intertwined_fleet)
from repro.sim import scenarios


# --------------------------------------------------------------------------- #
# Device models
# --------------------------------------------------------------------------- #


def test_latency_dists():
    rng = np.random.default_rng(0)
    assert LatencyDist("fixed", 2.5).sample(rng) == 2.5
    # spread=0 degenerates to loc for every family
    assert LatencyDist("lognormal", 3.0, 0.0).sample(rng) == 3.0
    assert LatencyDist("pareto", 1.5, 0.0).sample(rng) == 1.5
    ln = [LatencyDist("lognormal", 1.0, 0.5).sample(rng) for _ in range(200)]
    pa = [LatencyDist("pareto", 1.0, 0.5).sample(rng) for _ in range(200)]
    assert all(v > 0 for v in ln)
    assert all(v >= 1.0 for v in pa)        # pareto scale is a lower bound
    assert max(pa) > 3.0                    # heavy tail actually shows up
    with pytest.raises(ValueError):
        LatencyDist("weird")


def test_intertwined_fleet_couples_speed_with_label_skew():
    hist = np.array([[0, 10], [0, 8], [5, 5], [10, 0]])
    fleet = intertwined_fleet(hist, target_class=1, n_slow=2,
                              slow=LatencyDist("fixed", 9.0),
                              fast=LatencyDist("fixed", 0.5))
    rng = np.random.default_rng(0)
    lats = [fleet.job_latency(rng, i) for i in range(4)]
    # clients 0 and 1 hold the most of class 1 -> slow tier
    assert lats[0] == 9.0 and lats[1] == 9.0
    assert lats[2] == 0.5 and lats[3] == 0.5


# --------------------------------------------------------------------------- #
# Engine: determinism, policies, bookkeeping
# --------------------------------------------------------------------------- #


def _run_engine(policy, seed=0, horizon=20.0, n=8, dropout=0.0,
                latency=LatencyDist("lognormal", 1.0, 0.3)):
    fleet = homogeneous_fleet(n, latency, dropout_prob=dropout,
                              downtime=LatencyDist("fixed", 1.0))
    eng = SimEngine(fleet, policy, RecordingAggregator(), seed=seed,
                    horizon=horizon)
    return eng, eng.run()


def test_deterministic_replay():
    _, s1 = _run_engine(FedBuffK(4), seed=7)
    _, s2 = _run_engine(FedBuffK(4), seed=7)
    assert s1 == s2                          # full summary, incl. digest
    e1, _ = _run_engine(FedBuffK(4), seed=7)
    e2, _ = _run_engine(FedBuffK(4), seed=7)
    assert e1.trace == e2.trace              # identical event-by-event
    _, s3 = _run_engine(FedBuffK(4), seed=8)
    assert s3["trace_digest"] != s1["trace_digest"]


def test_fedbuff_trigger_counts():
    # raw mode (the pre-fix behavior, kept behind distinct=False): the
    # trigger counts buffer ENTRIES, so superseded duplicates tick it too
    agg = RecordingAggregator()
    fleet = homogeneous_fleet(8, LatencyDist("lognormal", 1.0, 0.3))
    eng = SimEngine(fleet, FedBuffK(4, distinct=False), agg, seed=0,
                    horizon=20.0)
    s = eng.run()
    assert s["aggregations"] == s["arrivals"] // 4
    sizes = [len(c["fresh"]) + len(c["stale"]) for c in agg.cohorts]
    assert all(1 <= n <= 4 for n in sizes)
    assert sum(sizes) + s["superseded"] + s["buffer_pending"] == s["arrivals"]


def test_fedbuff_distinct_gates_on_clients():
    # default mode: the trigger fires on K DISTINCT clients, so every
    # cohort delivers exactly K updates — duplicates can no longer shrink
    # the cohort below the nominal buffer depth
    agg = RecordingAggregator()
    fleet = homogeneous_fleet(8, LatencyDist("lognormal", 1.0, 0.3))
    eng = SimEngine(fleet, FedBuffK(4), agg, seed=0, horizon=20.0)
    s = eng.run()
    assert s["aggregations"] > 0
    assert all(len(c["fresh"]) + len(c["stale"]) == 4 for c in agg.cohorts)
    assert sum(4 for _ in agg.cohorts) + s["superseded"] \
        + s["buffer_pending"] == s["arrivals"]
    # a single client can never supply K=5 distinct uploads: the raw
    # trigger fired on its pile-up, the distinct trigger must not
    solo = SimEngine(homogeneous_fleet(1, LatencyDist("fixed", 0.3)),
                     FedBuffK(5), RecordingAggregator(), seed=0,
                     horizon=10.0)
    assert solo.run()["aggregations"] == 0
    assert solo.buffer_size(distinct=True) == 1


def test_pure_async_aggregates_every_arrival():
    agg = RecordingAggregator()
    fleet = homogeneous_fleet(4, LatencyDist("lognormal", 1.0, 0.2))
    eng = SimEngine(fleet, PureAsync(), agg, seed=0, horizon=15.0)
    s = eng.run()
    assert s["aggregations"] == s["arrivals"] > 0
    assert all(len(c["fresh"]) + len(c["stale"]) == 1 for c in agg.cohorts)


def test_semi_sync_deadline_tick_count():
    _, s = _run_engine(SemiSyncDeadline(1.0), horizon=10.0, n=4,
                       latency=LatencyDist("fixed", 0.5))
    assert s["aggregations"] == 10           # one per deadline tick
    assert s["arrivals"] == 40               # everyone lands every round
    assert s["mean_realized_tau"] == 0.0     # nobody is ever stale


def test_dropout_rejoin_bookkeeping():
    for seed in range(5):
        _, s = _run_engine(PureAsync(), seed=seed, horizon=30.0, n=6,
                           dropout=0.3,
                           latency=LatencyDist("lognormal", 1.0, 0.5))
        assert s["dropouts"] > 0             # churn actually happened
        # every dispatched job is delivered, lost, or still pending
        assert s["dispatches"] == s["arrivals"] + s["lost_jobs"] + s["inflight"]
        # every dropout is either rejoined or still down at the horizon
        assert s["dropouts"] == s["rejoins"] + s["clients_down"]


def test_buffer_dedup_counts_superseded():
    # one fast client under FedBuff-5: its own arrivals pile up in the
    # buffer, the cohort dedupes to the freshest and counts the rest
    agg = RecordingAggregator()
    fleet = homogeneous_fleet(1, LatencyDist("fixed", 0.3))
    eng = SimEngine(fleet, FedBuffK(5, distinct=False), agg, seed=0,
                    horizon=10.0)
    s = eng.run()
    assert s["aggregations"] > 0
    assert all(len(c["fresh"]) + len(c["stale"]) == 1 for c in agg.cohorts)
    assert s["superseded"] == s["arrivals"] - s["aggregations"] \
        - s["buffer_pending"]


def test_eval_ticks_and_realized_view():
    # three fast clients keep versions advancing; client 3 trains through
    # ~2 aggregations per job, so its observed staleness is 2 versions
    fleet = DeviceFleet(
        [DeviceProfile(compute=LatencyDist("fixed", 0.4))] * 3 +
        [DeviceProfile(compute=LatencyDist("fixed", 2.5))])
    eng = SimEngine(fleet, SemiSyncDeadline(1.0), RecordingAggregator(),
                    seed=0, horizon=12.0, eval_every_time=4.0)
    eng.run()
    assert [t for t, _, _ in eng.evals] == [4.0, 8.0, 12.0]
    sched = eng.realized_schedule()
    assert sched.slow_clients == [3]
    assert sched.tau(3) == 2
    assert all(sched.tau(i) == 0 for i in range(3))


def test_summary_reports_every_counter():
    # regression: skipped_busy and cancelled_uploads used to vanish from
    # summary() whenever they were zero — every canonical counter key must
    # appear unconditionally
    from repro.sim.engine import COUNTER_KEYS
    _, s = _run_engine(SemiSyncDeadline(1.0), horizon=5.0, n=4,
                       latency=LatencyDist("fixed", 0.5))
    for key in COUNTER_KEYS:
        assert key in s, key
    assert "skipped_busy" in s and "cancelled_uploads" in s
    # reading summary() must not mutate the counters it reports
    eng, _ = _run_engine(PureAsync(), horizon=3.0, n=2)
    snap = dict(eng.counters)
    eng.summary()
    assert dict(eng.counters) == snap


def test_resume_rearms_eval_ticks():
    # regression: a second run(until=...) never re-scheduled the eval tick,
    # so extending the horizon silently stopped producing eval points
    fleet = homogeneous_fleet(4, LatencyDist("fixed", 0.5))
    eng = SimEngine(fleet, SemiSyncDeadline(1.0), RecordingAggregator(),
                    seed=0, horizon=4.0, eval_every_time=2.0)
    eng.run()
    assert [t for t, _, _ in eng.evals] == [2.0, 4.0]
    eng.run(until=10.0)
    assert [t for t, _, _ in eng.evals] == [2.0, 4.0, 6.0, 8.0, 10.0]
    # one-shot run over the same horizon sees the same eval grid
    one = SimEngine(homogeneous_fleet(4, LatencyDist("fixed", 0.5)),
                    SemiSyncDeadline(1.0), RecordingAggregator(),
                    seed=0, horizon=10.0, eval_every_time=2.0)
    one.run()
    assert [t for t, _, _ in one.evals] == [t for t, _, _ in eng.evals]


def test_observed_schedule_reducers():
    obs = {0: [2, 4], 2: [5]}
    assert observed_schedule(4, obs, "mean").staleness.tolist() == [3, 0, 5, 0]
    assert observed_schedule(4, obs, "max").tau(0) == 4
    assert observed_schedule(4, obs, "last").tau(0) == 4
    assert observed_schedule(4, {1: []}).tau(1) == 0
    with pytest.raises(ValueError):
        observed_schedule(4, obs, "median")


# --------------------------------------------------------------------------- #
# Bridge + scenarios (real Server in the loop)
# --------------------------------------------------------------------------- #


def test_degenerate_oracle_matches_sync_server_bit_for_bit():
    """Acceptance criterion: zero-variance latencies + pipelined deadline
    reproduce the round-synchronous `ours` trajectory exactly — same PRNG
    stream, same cohorts, same params at every version."""
    R, taus = 5, [2, 3, 2]
    run = scenarios.build("degenerate_sync", seed=0, horizon=float(R),
                          tau=taus, gi_iters=4)
    summary = run.run()
    assert summary["aggregations"] == R

    sync_srv, _, _ = scenarios._fl_setup(0, strategy="ours", tau=taus,
                                         gi_iters=4)
    for t in range(R):
        sync_srv.round(t)

    assert len(run.server.history) == len(sync_srv.history) == R + 1
    for v, (wa, wb) in enumerate(zip(run.server.history, sync_srv.history)):
        for a, b in zip(jax.tree_util.tree_leaves(wa),
                        jax.tree_util.tree_leaves(wb)):
            assert bool(jnp.array_equal(a, b)), f"version {v} diverged"
    # same gi activity and metrics rows
    assert run.server.gi_log == sync_srv.gi_log
    assert [m["gi_iters"] for m in run.server.metrics] == \
        [m["gi_iters"] for m in sync_srv.metrics]


def test_named_scenario_end_to_end():
    run = scenarios.build("fedbuff_k4", seed=0, horizon=3.0, gi_iters=2)
    summary = run.run()
    assert summary["aggregations"] > 0
    assert 0.0 <= summary["final_acc"] <= 1.0
    assert summary["policy"] == "fedbuff_k4"
    # version counter and Server history stayed aligned
    assert len(run.server.history) == summary["version"] + 1


def test_cli_list_and_registry():
    from repro.sim.__main__ import main
    assert main(["--list"]) == 0
    assert {"degenerate_sync", "semi_sync_deadline", "pure_async",
            "fedbuff_k4"} <= set(scenarios.names())
    with pytest.raises(KeyError):
        scenarios.build("no_such_scenario")


@pytest.mark.slow
def test_all_named_scenarios_run(tmp_path):
    from repro.sim.__main__ import main
    for name in scenarios.names():
        out = tmp_path / f"{name}.json"
        assert main(["--scenario", name, "--seed", "1", "--horizon", "4",
                     "--gi-iters", "2", "--out", str(out)]) == 0
        assert out.exists()


@pytest.mark.slow
def test_sim_replay_with_real_server():
    a = scenarios.build("pure_async", seed=3, horizon=4.0, gi_iters=2).run()
    b = scenarios.build("pure_async", seed=3, horizon=4.0, gi_iters=2).run()
    assert a["trace_digest"] == b["trace_digest"]
    assert a["final_acc"] == b["final_acc"]
