"""Variant-data scenario (paper §4.3): clients' local data drifts over time.

The paper initializes every client with MNIST and during training replaces
random samples with SVHN samples of the same label (same task, different
feature representation). We reproduce this with two *styles* of the synthetic
image dataset; ``rate`` = samples replaced per client per epoch (rates > 1
supported, fractional rates applied stochastically).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


class VariantDataStream:
    def __init__(self, xs: np.ndarray, ys: np.ndarray, mask: np.ndarray,
                 pool_x: np.ndarray, pool_y: np.ndarray, rate: float,
                 seed: int = 0):
        """xs (N, m, ...) padded client shards; pool_* the style-B dataset."""
        self.xs = xs.copy()
        self.ys = ys
        self.mask = mask
        self.rate = rate
        self.rng = np.random.RandomState(seed)
        # index pool by label for label-preserving replacement
        self.pool_by_class = {
            c: pool_x[pool_y == c] for c in np.unique(pool_y)
        }
        self.replaced = np.zeros(xs.shape[:2], bool)

    def step(self) -> int:
        """Advance one epoch of drift; returns #samples replaced."""
        n_clients, m = self.ys.shape
        total = 0
        for i in range(n_clients):
            k = int(np.floor(self.rate))
            if self.rng.rand() < self.rate - k:
                k += 1
            valid = np.where(self.mask[i] > 0)[0]
            if len(valid) == 0 or k == 0:
                continue
            picks = self.rng.choice(valid, size=min(k, len(valid)), replace=False)
            for j in picks:
                c = int(self.ys[i, j])
                pool = self.pool_by_class.get(c)
                if pool is None or len(pool) == 0:
                    continue
                self.xs[i, j] = pool[self.rng.randint(len(pool))]
                self.replaced[i, j] = True
                total += 1
        return total

    @property
    def drift_fraction(self) -> float:
        valid = self.mask > 0
        return float(self.replaced[valid].mean()) if valid.any() else 0.0
