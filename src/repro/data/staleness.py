"""Device heterogeneity schedule (paper §4.1).

To *intertwine* device heterogeneity with data heterogeneity, a target class
is selected and the ``n_slow`` clients holding the most samples of that class
get staleness tau (their updates arrive tau rounds late). Everyone else is a
normal synchronous client.

Two staleness views coexist:

* **Scheduled** — ``intertwined_schedule`` / ``uniform_random_schedule``
  assign per-client taus a priori; the round-synchronous ``Server`` replays
  them exactly.
* **Observed** — the event-driven simulator (``repro.sim``) realizes delays
  from stochastic device models; ``observed_schedule`` folds the realized
  per-arrival staleness back into a ``StalenessSchedule``-compatible view so
  schedule-consuming code (tiering, analysis, re-runs) works on what actually
  happened instead of what was planned.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Mapping, Sequence, Union

import numpy as np

# heterogeneous tau spec: one scalar for every slow client, an explicit
# per-slow-client array, or a sampler called as sampler(n_slow) -> array
TauSpec = Union[int, Sequence[int], np.ndarray, Callable[[int], Sequence[int]]]


@dataclasses.dataclass(frozen=True)
class StalenessSchedule:
    staleness: np.ndarray          # (n_clients,) int; 0 = unstale

    def tau(self, client: int) -> int:
        return int(self.staleness[client])

    @property
    def slow_clients(self) -> List[int]:
        return [int(i) for i in np.where(self.staleness > 0)[0]]

    @property
    def fast_clients(self) -> List[int]:
        return [int(i) for i in np.where(self.staleness == 0)[0]]

    @property
    def max_tau(self) -> int:
        return int(self.staleness.max(initial=0))


def _resolve_taus(tau: TauSpec, n_slow: int) -> np.ndarray:
    """Materialize a TauSpec into an (n_slow,) int array (>=1 each)."""
    if callable(tau):
        tau = tau(n_slow)
    taus = np.asarray(tau, dtype=np.int64)
    if taus.ndim == 0:
        taus = np.full(n_slow, int(taus), np.int64)
    if taus.shape != (n_slow,):
        raise ValueError(
            f"tau spec must be a scalar, an (n_slow,)={n_slow} array, or a "
            f"sampler returning one; got shape {taus.shape}")
    if (taus < 1).any():
        raise ValueError(f"slow-client taus must be >= 1, got {taus}")
    return taus


def top_holders(label_histograms: np.ndarray, target_class: int,
                n_slow: int) -> np.ndarray:
    """The ``n_slow`` clients holding the most ``target_class`` samples, in
    rank order. Stable sort: tied holders resolve identically on every
    platform. The single source of truth for the data/device coupling —
    both the static schedule and the simulator's device fleets
    (``repro.sim.devices.intertwined_fleet``) select through here, so they
    always pick the same clients."""
    counts = label_histograms[:, target_class]
    return np.argsort(-counts, kind="stable")[:n_slow]


def intertwined_schedule(label_histograms: np.ndarray, target_class: int,
                         n_slow: int, tau: TauSpec) -> StalenessSchedule:
    """Top-``n_slow`` holders of ``target_class`` become stale.

    ``tau`` may be a scalar (every slow client gets it — the original
    signature), an ``(n_slow,)`` array assigned in rank order (heaviest
    holder of the target class gets ``tau[0]``), or a sampler called as
    ``tau(n_slow)`` returning such an array.
    """
    slow = top_holders(label_histograms, target_class, n_slow)
    taus = _resolve_taus(tau, len(slow))
    st = np.zeros(label_histograms.shape[0], np.int64)
    st[slow] = taus
    return StalenessSchedule(st)


def uniform_random_schedule(n_clients: int, n_slow: int, tau: TauSpec,
                            seed: int = 0) -> StalenessSchedule:
    """Staleness NOT intertwined with data (control condition)."""
    rng = np.random.RandomState(seed)
    slow = rng.choice(n_clients, n_slow, replace=False)
    st = np.zeros(n_clients, np.int64)
    st[slow] = _resolve_taus(tau, n_slow)
    return StalenessSchedule(st)


def observed_schedule(n_clients: int,
                      observations: Mapping[int, Sequence[float]],
                      reducer: str = "mean") -> StalenessSchedule:
    """A ``StalenessSchedule`` view of *realized* delays.

    ``observations`` maps client -> list of realized per-arrival staleness
    (in model versions), e.g. ``SimEngine.realized`` after a simulation.
    ``reducer`` folds each client's list to one tau: ``mean`` (rounded),
    ``max``, or ``last``. Clients with no arrivals get tau=0.
    """
    fold = {"mean": lambda v: int(round(float(np.mean(v)))),
            "max": lambda v: int(np.max(v)),
            "last": lambda v: int(v[-1])}
    if reducer not in fold:
        raise ValueError(f"reducer must be one of {sorted(fold)}: {reducer}")
    st = np.zeros(n_clients, np.int64)
    for client, taus in observations.items():
        if len(taus):
            st[int(client)] = fold[reducer](list(taus))
    return StalenessSchedule(st)
