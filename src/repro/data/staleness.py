"""Device heterogeneity schedule (paper §4.1).

To *intertwine* device heterogeneity with data heterogeneity, a target class
is selected and the ``n_slow`` clients holding the most samples of that class
get staleness tau (their updates arrive tau rounds late). Everyone else is a
normal synchronous client.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class StalenessSchedule:
    staleness: np.ndarray          # (n_clients,) int; 0 = unstale

    def tau(self, client: int) -> int:
        return int(self.staleness[client])

    @property
    def slow_clients(self) -> List[int]:
        return [int(i) for i in np.where(self.staleness > 0)[0]]

    @property
    def fast_clients(self) -> List[int]:
        return [int(i) for i in np.where(self.staleness == 0)[0]]


def intertwined_schedule(label_histograms: np.ndarray, target_class: int,
                         n_slow: int, tau: int) -> StalenessSchedule:
    """Top-``n_slow`` holders of ``target_class`` become stale by ``tau``."""
    counts = label_histograms[:, target_class]
    slow = np.argsort(-counts)[:n_slow]
    st = np.zeros(label_histograms.shape[0], np.int64)
    st[slow] = tau
    return StalenessSchedule(st)


def uniform_random_schedule(n_clients: int, n_slow: int, tau: int,
                            seed: int = 0) -> StalenessSchedule:
    """Staleness NOT intertwined with data (control condition)."""
    rng = np.random.RandomState(seed)
    slow = rng.choice(n_clients, n_slow, replace=False)
    st = np.zeros(n_clients, np.int64)
    st[slow] = tau
    return StalenessSchedule(st)
