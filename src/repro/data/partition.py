"""Client data partitioning — the paper's data heterogeneity (§4.1, Fig. 10).

Dirichlet(alpha) label-distribution sampling per Hsu & Brown 2019: each
client draws p_i ~ Dir(alpha) over classes and its samples follow p_i.
Small alpha -> near single-class clients (high heterogeneity).

Clients are materialized as fixed-size padded shards (x (N_clients, m, ...),
y, mask) so the whole cohort can be stacked and vmapped/sharded.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


def dirichlet_partition(y: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0) -> List[np.ndarray]:
    """Returns per-client index lists."""
    rng = np.random.RandomState(seed)
    n_classes = int(y.max()) + 1
    class_idx = [np.where(y == c)[0] for c in range(n_classes)]
    for ci in class_idx:
        rng.shuffle(ci)
    props = rng.dirichlet([alpha] * n_classes, n_clients)  # (clients, classes)
    # normalize per class so every sample is assigned exactly once
    props = props / props.sum(axis=0, keepdims=True)
    client_indices: List[List[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        counts = np.floor(props[:, c] * len(class_idx[c])).astype(int)
        # distribute remainder
        rem = len(class_idx[c]) - counts.sum()
        order = np.argsort(-props[:, c])
        for i in range(rem):
            counts[order[i % n_clients]] += 1
        start = 0
        for i in range(n_clients):
            client_indices[i].extend(class_idx[c][start:start + counts[i]].tolist())
            start += counts[i]
    return [np.asarray(ci, dtype=np.int64) for ci in client_indices]


def one_class_partition(y: np.ndarray, n_clients: int, seed: int = 0
                        ) -> List[np.ndarray]:
    """Each client holds samples of exactly one (random) class — the paper's
    motivating experiment (§2.1) and the uniqueness-detection evaluation."""
    rng = np.random.RandomState(seed)
    n_classes = int(y.max()) + 1
    assignment = rng.randint(0, n_classes, n_clients)
    class_idx = [np.where(y == c)[0] for c in range(n_classes)]
    cursors = [0] * n_classes
    out = []
    for i in range(n_clients):
        c = assignment[i]
        per = max(1, len(class_idx[c]) // max(1, (assignment == c).sum()))
        s = cursors[c]
        out.append(class_idx[c][s:s + per])
        cursors[c] += per
    return out


def pad_client_shards(x: np.ndarray, y: np.ndarray,
                      client_indices: List[np.ndarray], m: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack clients into (N, m, ...) with sample masks (pad or subsample)."""
    n = len(client_indices)
    xs = np.zeros((n, m) + x.shape[1:], x.dtype)
    ys = np.zeros((n, m), np.int32)
    mask = np.zeros((n, m), np.float32)
    for i, idx in enumerate(client_indices):
        take = idx[:m]
        xs[i, :len(take)] = x[take]
        ys[i, :len(take)] = y[take]
        mask[i, :len(take)] = 1.0
    return xs, ys, mask


def client_label_histograms(y: np.ndarray, client_indices: List[np.ndarray],
                            n_classes: int) -> np.ndarray:
    h = np.zeros((len(client_indices), n_classes), np.int64)
    for i, idx in enumerate(client_indices):
        for c in y[idx]:
            h[i, c] += 1
    return h
