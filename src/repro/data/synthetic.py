"""Deterministic synthetic datasets with class structure.

The container is offline (no MNIST/FMNIST/CIFAR/MDI downloads), so the
paper's experiments run on generated datasets that preserve the properties
the claims depend on: learnable class-conditional structure, intra-class
diversity, and a second "feature representation" of the same task for the
variant-data scenario (the paper's MNIST->SVHN drift, §4.3).

Each class c gets a smooth prototype image P_c (random low-frequency pattern
from a class-seeded RNG); samples are P_c + structured noise + random affine
jitter. ``style`` changes the rendering (prototype frequency band, contrast,
background) to emulate the MNIST-vs-SVHN representation shift.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def _prototype(cls: int, hw: int, ch: int, style: int) -> np.ndarray:
    rng = np.random.RandomState(1000 * style + cls)
    # low-frequency pattern: sum of a few random 2-D cosines
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float64) / hw
    img = np.zeros((hw, hw))
    n_waves = 3 if style == 0 else 5
    for _ in range(n_waves):
        fx, fy = rng.uniform(0.5, 3.0 + style, 2)
        px, py = rng.uniform(0, 2 * np.pi, 2)
        img += rng.uniform(0.5, 1.0) * np.cos(2 * np.pi * (fx * xx + px)) \
            * np.cos(2 * np.pi * (fy * yy + py))
    img = (img - img.min()) / (np.ptp(img) + 1e-9)
    if style == 1:  # "SVHN-like": lower contrast, offset background
        img = 0.5 * img + 0.25
    out = np.repeat(img[:, :, None], ch, axis=2)
    return out.astype(np.float32)


def make_image_dataset(n_per_class: int, n_classes: int = 10, hw: int = 28,
                       ch: int = 1, style: int = 0, seed: int = 0,
                       noise: float = 0.25) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x (N,hw,hw,ch) float32 in [0,1]-ish, y (N,) int32)."""
    rng = np.random.RandomState(seed + 7919 * style)
    xs, ys = [], []
    protos = [_prototype(c, hw, ch, style) for c in range(n_classes)]
    for c in range(n_classes):
        base = protos[c][None]
        jitter_x = rng.randint(-2, 3, size=n_per_class)
        jitter_y = rng.randint(-2, 3, size=n_per_class)
        batch = np.repeat(base, n_per_class, axis=0)
        for i in range(n_per_class):
            batch[i] = np.roll(batch[i], (jitter_y[i], jitter_x[i]), axis=(0, 1))
        batch = batch + noise * rng.randn(*batch.shape).astype(np.float32)
        xs.append(batch.astype(np.float32))
        ys.append(np.full((n_per_class,), c, np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]


def make_feature_dataset(n_per_class: int, n_classes: int = 13,
                         n_features: int = 52, seed: int = 0,
                         noise: float = 0.5) -> Tuple[np.ndarray, np.ndarray]:
    """PAMAP2-style tabular data: class-conditional Gaussian clusters."""
    rng = np.random.RandomState(seed)
    means = rng.randn(n_classes, n_features) * 2.0
    xs, ys = [], []
    for c in range(n_classes):
        xs.append(means[c] + noise * rng.randn(n_per_class, n_features))
        ys.append(np.full((n_per_class,), c, np.int32))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]


def make_timeseries_dataset(n_per_class: int, n_classes: int = 7,
                            seq: int = 64, channels: int = 6, seed: int = 0,
                            noise: float = 0.3) -> Tuple[np.ndarray, np.ndarray]:
    """ExtraSensory-style IMU windows: class-specific frequency signatures."""
    rng = np.random.RandomState(seed)
    t = np.arange(seq) / seq
    xs, ys = [], []
    for c in range(n_classes):
        freqs = rng.uniform(1, 8, channels) + c
        phases = rng.uniform(0, 2 * np.pi, (n_per_class, channels))
        sig = np.sin(2 * np.pi * freqs[None, None, :] * t[None, :, None]
                     + phases[:, None, :])
        sig = sig + noise * rng.randn(n_per_class, seq, channels)
        xs.append(sig.astype(np.float32))
        ys.append(np.full((n_per_class,), c, np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]
