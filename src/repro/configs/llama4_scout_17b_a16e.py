"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E]: 48L
d_model=5120 40H GQA(kv=8) d_ff=8192 vocab=202048; MoE 16 experts top-1
(+1 shared, Llama-4 style); early-fusion multimodal (vision stubbed —
text backbone here)."""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,                    # per-expert hidden width
    vocab_size=202048,
    rope="rope",
    rope_theta=500_000.0,
    norm="rmsnorm",
    act="silu_glu",
    moe=MoEConfig(n_experts=16, top_k=1, n_shared=1, d_expert=8192),
)
