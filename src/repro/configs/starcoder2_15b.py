"""StarCoder2-15B [arXiv:2402.19173]: 40L d_model=6144 48H GQA(kv=4)
d_ff=24576 vocab=49152; GQA + RoPE; gelu MLP (non-gated), learned biases.
long_500k runs only as an explicit sliding-window VARIANT (see DESIGN.md)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    rope="rope",
    rope_theta=100_000.0,
    attn_bias=True,
    norm="layernorm",
    act="gelu",
)
