"""Config registry: one module per assigned architecture (+ paper-scale ones).

``get_config(name)`` returns the full production ModelConfig;
``get_config(name, reduced=True)`` returns the CPU smoke-test variant
(2 layers, d_model<=256, <=4 experts) of the same family.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "rwkv6_1_6b",
    "starcoder2_15b",
    "qwen1_5_0_5b",
    "whisper_tiny",
    "deepseek_moe_16b",
    "qwen3_1_7b",
    "hymba_1_5b",
    "h2o_danube_1_8b",
    "qwen2_vl_7b",
    "llama4_scout_17b_a16e",
]

# public ids (dashes) -> module names
ALIASES: Dict[str, str] = {
    "rwkv6-1.6b": "rwkv6_1_6b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "whisper-tiny": "whisper_tiny",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-1.7b": "qwen3_1_7b",
    "hymba-1.5b": "hymba_1_5b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
}


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ModelConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
