"""RWKV6 "Finch" 1.6B — attention-free SSM with data-dependent decay
[arXiv:2404.05892]. 24L d_model=2048 d_ff=7168 vocab=65536; head size 64."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # = d_model / rwkv_head_size (bookkeeping only)
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    block_type="rwkv6",
    rope="none",
    norm="layernorm",      # RWKV uses LayerNorm
    act="silu_glu",
    rwkv_head_size=64,
)
