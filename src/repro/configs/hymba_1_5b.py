"""Hymba-1.5B [arXiv:2411.13676]: 32L d_model=1600 25H GQA(kv=5) d_ff=5504
vocab=32001 ssm_state=16 — hybrid heads: attention and Mamba/S6 run in
PARALLEL within every layer and are averaged. Hymba itself uses sliding-
window attention in all but three layers; we use SWA(1024) uniformly."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    block_type="hybrid",
    rope="rope",
    sliding_window=1024,
    ssm_state=16,
    ssm_expand=2,
    norm="rmsnorm",
    act="silu_glu",
)
