"""DeepSeekMoE-16B [arXiv:2401.06066]: 28L d_model=2048 16H (kv=16)
fine-grained MoE: 64 routed experts top-6 + 2 shared, d_expert=1408,
vocab=102400."""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                     # per-expert hidden width (fine-grained)
    vocab_size=102400,
    rope="rope",
    norm="rmsnorm",
    act="silu_glu",
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
)
