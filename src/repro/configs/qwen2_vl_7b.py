"""Qwen2-VL-7B [arXiv:2409.12191]: 28L d_model=3584 28H GQA(kv=4) d_ff=18944
vocab=152064; M-RoPE (t/h/w rotary sections), dynamic-resolution vision tower
STUBBED — input_specs provides merged text+patch embeddings."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    rope="mrope",
    mrope_sections=(16, 24, 24),
    attn_bias=True,
    norm="rmsnorm",
    act="silu_glu",
    frontend="vision",
)
