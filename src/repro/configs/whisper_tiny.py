"""Whisper-tiny [arXiv:2212.04356]: enc-dec, 4L each, d_model=384 6H
d_ff=1536 vocab=51865. Mel+conv frontend is a STUB (precomputed frame
embeddings, 1500 frames); this config is the transformer backbone."""

from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    rope="none",            # sinusoidal positions
    attn_bias=True,
    norm="layernorm",
    act="gelu",
    encoder=EncoderConfig(n_layers=4, n_ctx=1500),
    frontend="audio",
)
