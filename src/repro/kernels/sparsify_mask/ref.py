"""Pure-jnp oracle for the top-K magnitude sparsification mask (paper §3.3)."""

from __future__ import annotations

import jax.numpy as jnp


def sparsify_mask_reference(u: jnp.ndarray, thresh: jnp.ndarray) -> jnp.ndarray:
    """Zero coordinates with |u| < thresh (u is a flat update vector)."""
    return jnp.where(jnp.abs(u) >= thresh, u, jnp.zeros_like(u))
