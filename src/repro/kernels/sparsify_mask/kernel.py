"""Pallas TPU kernel: fused top-K magnitude mask application (paper §3.3).

The GI server sparsifies every stale update to its top-5% magnitude
coordinates. For the LLM-scale models (up to ~17B parameters = many GiB) the
mask application is a pure streaming op: tiles of the flat update vector move
HBM -> VMEM, compare |u| against the (precomputed) k-th-magnitude threshold,
and write back the masked tile. One (rows, 128)-shaped VMEM tile per grid
step keeps lanes full; arithmetic intensity is ~1 op/byte so the kernel is
bandwidth-bound by construction — fusing compare+select avoids a second pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mask_kernel(u_ref, t_ref, o_ref):
    t = t_ref[0, 0]
    u = u_ref[...]
    o_ref[...] = jnp.where(jnp.abs(u) >= t, u, jnp.zeros_like(u))


def sparsify_mask_pallas(u2d: jax.Array, thresh: jax.Array, *,
                         block_rows: int = 256,
                         interpret: bool = False) -> jax.Array:
    """u2d (R, 128) tiled view of the flat update; thresh (1,1) float32."""
    R, lanes = u2d.shape
    br = min(block_rows, R)
    nr = pl.cdiv(R, br)
    return pl.pallas_call(
        _mask_kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((br, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr * br, lanes), u2d.dtype),
        interpret=interpret,
    )(u2d, thresh)[:R]
