"""Pallas TPU kernel: fused top-K magnitude mask application (paper §3.3).

The GI server sparsifies every stale update to its top-5% magnitude
coordinates. For the LLM-scale models (up to ~17B parameters = many GiB) the
mask application is a pure streaming op: tiles of the flat update vector move
HBM -> VMEM, compare |u| against the (precomputed) k-th-magnitude threshold,
and write back the masked tile. One (rows, 128)-shaped VMEM tile per grid
step keeps lanes full; arithmetic intensity is ~1 op/byte so the kernel is
bandwidth-bound by construction — fusing compare+select avoids a second pass.

Two output modes share the same streaming structure:

* ``binary=False`` — masked *values* ``where(|u| >= t, u, 0)`` (the original
  fused application);
* ``binary=True`` — the 0/1 *mask itself* (float32), which is what the
  batched GI objective consumes: the server computes one mask per stale
  client and feeds the stacked (B, n) masks into the vmapped inversion.

``sparsify_mask_batch_pallas`` extends the grid with a leading batch axis and
reads a per-row threshold, so all B stale clients of a round are masked in a
single kernel launch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mask_kernel(u_ref, t_ref, o_ref, *, binary: bool):
    t = t_ref[0, 0]
    u = u_ref[...]
    keep = jnp.abs(u) >= t
    if binary:
        o_ref[...] = keep.astype(o_ref.dtype)
    else:
        o_ref[...] = jnp.where(keep, u, jnp.zeros_like(u))


def sparsify_mask_pallas(u2d: jax.Array, thresh: jax.Array, *,
                         block_rows: int = 256,
                         binary: bool = False,
                         interpret: bool = False) -> jax.Array:
    """u2d (R, 128) tiled view of the flat update; thresh (1,1) float32."""
    R, lanes = u2d.shape
    br = min(block_rows, R)
    nr = pl.cdiv(R, br)
    out_dtype = jnp.float32 if binary else u2d.dtype
    return pl.pallas_call(
        functools.partial(_mask_kernel, binary=binary),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((br, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr * br, lanes), out_dtype),
        interpret=interpret,
    )(u2d, thresh)[:R]


def _mask_kernel_batch(u_ref, t_ref, o_ref, *, binary: bool):
    t = t_ref[0, 0]
    u = u_ref[0]
    keep = jnp.abs(u) >= t
    if binary:
        o_ref[0] = keep.astype(o_ref.dtype)
    else:
        o_ref[0] = jnp.where(keep, u, jnp.zeros_like(u))


def sparsify_mask_batch_pallas(u3d: jax.Array, thresh: jax.Array, *,
                               block_rows: int = 256,
                               binary: bool = False,
                               interpret: bool = False) -> jax.Array:
    """u3d (B, R, 128) stacked tiled updates; thresh (B, 1) per-client.

    Grid is (B, R/br): each step streams one client's tile against that
    client's threshold — one launch masks the whole round's stale cohort.
    """
    B, R, lanes = u3d.shape
    br = min(block_rows, R)
    nr = pl.cdiv(R, br)
    out_dtype = jnp.float32 if binary else u3d.dtype
    out = pl.pallas_call(
        functools.partial(_mask_kernel_batch, binary=binary),
        grid=(B, nr),
        in_specs=[
            pl.BlockSpec((1, br, lanes), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, br, lanes), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nr * br, lanes), out_dtype),
        interpret=interpret,
    )(u3d, thresh)
    return out[:, :R]
