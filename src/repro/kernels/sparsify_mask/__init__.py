from repro.kernels.sparsify_mask.ops import (sparsify_mask,  # noqa: F401
                                             topk_binary_mask,
                                             topk_binary_mask_batch,
                                             topk_binary_mask_batch_sharded,
                                             topk_threshold,
                                             topk_threshold_batch)
from repro.kernels.sparsify_mask.ref import sparsify_mask_reference  # noqa: F401
