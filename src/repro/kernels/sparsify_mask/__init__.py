from repro.kernels.sparsify_mask.ops import sparsify_mask, topk_threshold  # noqa: F401
from repro.kernels.sparsify_mask.ref import sparsify_mask_reference  # noqa: F401
