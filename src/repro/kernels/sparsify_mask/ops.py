"""Jit'd wrappers: threshold computation + fused mask application.

``sparsify_mask`` applies the top-K mask to the values (seed API);
``topk_binary_mask`` / ``topk_binary_mask_batch`` return the boolean mask
itself via the same Pallas kernel — the form the batched GI engine consumes
(one stacked (B, n) mask tensor per round, computed in one launch).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sparsify_mask.kernel import (sparsify_mask_batch_pallas,
                                                sparsify_mask_pallas)

LANES = 128


def topk_threshold(u: jax.Array, keep_fraction: float) -> jax.Array:
    """k-th largest |u| (k = keep_fraction * n) — the §3.3 mask threshold."""
    n = u.shape[0]
    k = max(1, int(round(n * keep_fraction)))
    return jax.lax.top_k(jnp.abs(u), k)[0][-1]


def topk_threshold_batch(u2: jax.Array, keep_fraction: float) -> jax.Array:
    """Per-row thresholds for a stacked (B, n) batch of flat updates."""
    n = u2.shape[-1]
    k = max(1, int(round(n * keep_fraction)))
    return jax.lax.top_k(jnp.abs(u2), k)[0][..., -1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparsify_mask(u: jax.Array, thresh: jax.Array,
                  interpret: bool | None = None) -> jax.Array:
    """Apply |u| >= thresh masking to a flat vector via the Pallas kernel."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = u.shape[0]
    pad = (-n) % LANES
    up = jnp.pad(u, (0, pad)) if pad else u
    u2d = up.reshape(-1, LANES)
    t = jnp.asarray(thresh, jnp.float32).reshape(1, 1)
    out = sparsify_mask_pallas(u2d, t, interpret=interpret)
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("keep_fraction", "interpret"))
def topk_binary_mask(u: jax.Array, keep_fraction: float,
                     interpret: bool | None = None) -> jax.Array:
    """Boolean top-``keep_fraction`` magnitude mask of a flat vector,
    computed by the streaming Pallas kernel (binary output mode)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = u.shape[0]
    thresh = topk_threshold(u, keep_fraction)
    pad = (-n) % LANES
    up = jnp.pad(u, (0, pad)) if pad else u
    u2d = up.reshape(-1, LANES)
    t = jnp.asarray(thresh, jnp.float32).reshape(1, 1)
    out = sparsify_mask_pallas(u2d, t, binary=True, interpret=interpret)
    return out.reshape(-1)[:n] >= 0.5


@functools.partial(jax.jit, static_argnames=("keep_fraction", "interpret"))
def topk_binary_mask_batch(u2: jax.Array, keep_fraction: float,
                           interpret: bool | None = None) -> jax.Array:
    """(B, n) boolean masks for a stacked batch of flat updates — one kernel
    launch with a (B, tiles) grid and per-client SMEM thresholds."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, n = u2.shape
    thresh = topk_threshold_batch(u2, keep_fraction).astype(jnp.float32)
    pad = (-n) % LANES
    up = jnp.pad(u2, ((0, 0), (0, pad))) if pad else u2
    u3d = up.reshape(B, -1, LANES)
    out = sparsify_mask_batch_pallas(u3d, thresh.reshape(B, 1), binary=True,
                                     interpret=interpret)
    return out.reshape(B, -1)[:, :n] >= 0.5
