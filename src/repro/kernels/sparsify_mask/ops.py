"""Jit'd wrapper: threshold computation + fused mask application."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sparsify_mask.kernel import sparsify_mask_pallas

LANES = 128


def topk_threshold(u: jax.Array, keep_fraction: float) -> jax.Array:
    """k-th largest |u| (k = keep_fraction * n) — the §3.3 mask threshold."""
    n = u.shape[0]
    k = max(1, int(round(n * keep_fraction)))
    return jax.lax.top_k(jnp.abs(u), k)[0][-1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparsify_mask(u: jax.Array, thresh: jax.Array,
                  interpret: bool | None = None) -> jax.Array:
    """Apply |u| >= thresh masking to a flat vector via the Pallas kernel."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = u.shape[0]
    pad = (-n) % LANES
    up = jnp.pad(u, (0, pad)) if pad else u
    u2d = up.reshape(-1, LANES)
    t = jnp.asarray(thresh, jnp.float32).reshape(1, 1)
    out = sparsify_mask_pallas(u2d, t, interpret=interpret)
    return out.reshape(-1)[:n]
