"""Jit'd wrappers: threshold computation + fused mask application.

``sparsify_mask`` applies the top-K mask to the values (seed API);
``topk_binary_mask`` / ``topk_binary_mask_batch`` return the boolean mask
itself via the same Pallas kernel — the form the batched GI engine consumes
(one stacked (B, n) mask tensor per round, computed in one launch).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sparsify_mask.kernel import (sparsify_mask_batch_pallas,
                                                sparsify_mask_pallas)

LANES = 128


def topk_threshold(u: jax.Array, keep_fraction: float) -> jax.Array:
    """k-th largest |u| (k = keep_fraction * n) — the §3.3 mask threshold."""
    n = u.shape[0]
    k = max(1, int(round(n * keep_fraction)))
    return jax.lax.top_k(jnp.abs(u), k)[0][-1]


def topk_threshold_batch(u2: jax.Array, keep_fraction: float) -> jax.Array:
    """Per-row thresholds for a stacked (B, n) batch of flat updates."""
    n = u2.shape[-1]
    k = max(1, int(round(n * keep_fraction)))
    return jax.lax.top_k(jnp.abs(u2), k)[0][..., -1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparsify_mask(u: jax.Array, thresh: jax.Array,
                  interpret: bool | None = None) -> jax.Array:
    """Apply |u| >= thresh masking to a flat vector via the Pallas kernel."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = u.shape[0]
    pad = (-n) % LANES
    up = jnp.pad(u, (0, pad)) if pad else u
    u2d = up.reshape(-1, LANES)
    t = jnp.asarray(thresh, jnp.float32).reshape(1, 1)
    out = sparsify_mask_pallas(u2d, t, interpret=interpret)
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("keep_fraction", "interpret"))
def topk_binary_mask(u: jax.Array, keep_fraction: float,
                     interpret: bool | None = None) -> jax.Array:
    """Boolean top-``keep_fraction`` magnitude mask of a flat vector,
    computed by the streaming Pallas kernel (binary output mode)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = u.shape[0]
    thresh = topk_threshold(u, keep_fraction)
    pad = (-n) % LANES
    up = jnp.pad(u, (0, pad)) if pad else u
    u2d = up.reshape(-1, LANES)
    t = jnp.asarray(thresh, jnp.float32).reshape(1, 1)
    out = sparsify_mask_pallas(u2d, t, binary=True, interpret=interpret)
    return out.reshape(-1)[:n] >= 0.5


@functools.partial(jax.jit, static_argnames=("keep_fraction", "interpret"))
def topk_binary_mask_batch(u2: jax.Array, keep_fraction: float,
                           interpret: bool | None = None) -> jax.Array:
    """(B, n) boolean masks for a stacked batch of flat updates — one kernel
    launch with a (B, tiles) grid and per-client SMEM thresholds."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, n = u2.shape
    thresh = topk_threshold_batch(u2, keep_fraction).astype(jnp.float32)
    pad = (-n) % LANES
    up = jnp.pad(u2, ((0, 0), (0, pad))) if pad else u2
    u3d = up.reshape(B, -1, LANES)
    out = sparsify_mask_batch_pallas(u3d, thresh.reshape(B, 1), binary=True,
                                     interpret=interpret)
    return out.reshape(B, -1)[:, :n] >= 0.5


@functools.lru_cache(maxsize=None)
def _sharded_mask_fn(mesh, keep_fraction: float):
    """One jitted shard_map executable per (mesh, keep_fraction) — cached so
    round-to-round calls with the same cohort bucket reuse the compile."""
    from repro.launch.mesh import shard_map_compat
    from repro.launch.sharding import cohort_spec

    # kernel only on TPU, matching the unsharded path's backend policy
    # (repro.core.sparsify._kernel_default): on CPU it would run
    # interpreted inside every shard, and the TPU memory spaces don't
    # lower on GPU — both take the exactly-equivalent jnp compare
    on_tpu = jax.default_backend() == "tpu"

    def body(u_local: jax.Array) -> jax.Array:
        if not on_tpu:
            thresh = topk_threshold_batch(u_local, keep_fraction)
            return jnp.abs(u_local) >= thresh[:, None]
        # TPU shards reuse the single-launch batched kernel on their local
        # (B_local, tiles) grid
        return topk_binary_mask_batch(jnp.abs(u_local), keep_fraction,
                                      interpret=False)

    ax = cohort_spec(mesh)
    return jax.jit(shard_map_compat(body, mesh, in_specs=(ax,),
                                    out_specs=ax))


def topk_binary_mask_batch_sharded(u2: jax.Array, keep_fraction: float,
                                   mesh) -> jax.Array:
    """Sharded form of ``topk_binary_mask_batch``: the cohort (row) axis is
    split over the mesh's data axes and each shard masks its local
    ``(B_local, tiles)`` grid with one kernel launch. Thresholds are
    row-local (per-client top-K), so no cross-shard communication happens.

    On CPU shards the batched Pallas grid falls back to the equivalent
    pure-jnp compare (the kernel only *interprets* on CPU, which inside
    shard_map would run per shard per call); TPU/accelerator shards keep
    the kernel's local (B_local, tiles) grid. Rows must already be padded
    to a multiple of the shard count
    (``repro.launch.sharding.shard_bucket``); the sharded and unsharded
    masks are identical booleans, not approximations.
    """
    from repro.launch.mesh import mesh_shard_count

    n_shards = mesh_shard_count(mesh)
    B = u2.shape[0]
    if B % n_shards:
        raise ValueError(f"rows B={B} not a multiple of shards {n_shards}")
    return _sharded_mask_fn(mesh, float(keep_fraction))(u2)
