# Pallas TPU kernels for the framework's compute hot spots:
#   flash_attention  - blockwise causal GQA attention (+ sliding window)
#   rwkv6_wkv        - Finch data-dependent-decay recurrence
#   gqa_decode       - single-token decode attention over a long KV cache
#   sparsify_mask    - paper SS3.3 top-K magnitude mask application
#   fused_disparity  - concat-free masked L1 / cosine reduction terms with a
#                      closed-form custom_vjp (the GI loss hot loop)
# Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper, interpret=True on CPU), ref.py (pure-jnp oracle used in tests).
