"""Jit'd wrapper for the flash attention kernel.

On CPU (this container) the kernel runs in ``interpret=True`` mode for
correctness validation; on TPU the same call compiles natively. Inputs are
padded to block multiples before the kernel and cropped after.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q (B, Sq, H, D); k/v (B, Skv, KV, D) -> (B, Sq, H, D)."""
    if interpret is None:
        interpret = _on_cpu()
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    bq_ = min(bq, Sq) if Sq >= 8 else Sq
    bk_ = min(bk, Skv) if Skv >= 8 else Skv
    pad_q = (-Sq) % bq_
    pad_k = (-Skv) % bk_
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 bq=bq_, bk=bk_, interpret=interpret,
                                 sq_valid=Sq, skv_valid=Skv)
    return out[:, :Sq]
