"""Jit'd wrapper for the flash attention kernel (fwd + bwd).

On CPU (this container) the kernel runs in ``interpret=True`` mode for
correctness validation; on TPU the same call compiles natively. Inputs are
padded to block multiples before the kernel and cropped after.

``flash_attention`` is differentiable: a ``jax.custom_vjp`` routes the
backward pass through the Pallas dq / dkv kernels
(``flash_attention_bwd_pallas``), recomputing attention probabilities from
the forward pass's saved log-sum-exp instead of materializing the
(Sq, Skv) score matrix — this is what lets the transformer LocalUpdate
(and gradient inversion differentiating through it) train with the kernel
on the hot path.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (flash_attention_bwd_pallas,
                                                  flash_attention_pallas)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# cfg = (causal, window, bq, bk, interpret, sq_valid, skv_valid) — a single
# hashable static tuple so the custom_vjp has one nondiff arg
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_core(cfg, q, k, v):
    causal, window, bq, bk, interpret, sq, skv = cfg
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk, interpret=interpret,
                                  sq_valid=sq, skv_valid=skv)


def _flash_core_fwd(cfg, q, k, v):
    causal, window, bq, bk, interpret, sq, skv = cfg
    out, lse = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      bq=bq, bk=bk, interpret=interpret,
                                      sq_valid=sq, skv_valid=skv,
                                      return_lse=True)
    return out, (q, k, v, out, lse)


def _flash_core_bwd(cfg, res, do):
    causal, window, bq, bk, interpret, sq, skv = cfg
    q, k, v, out, lse = res
    KV = k.shape[2]
    rep = q.shape[2] // KV
    # delta = rowsum(dO * O) per query row — the softmax-jacobian correction
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1).transpose(0, 2, 1)          # (B, H, Sq)
    dq, dk_h, dv_h = flash_attention_bwd_pallas(
        q, k, v, do, lse, delta, causal=causal, window=window,
        bq=bq, bk=bk, interpret=interpret, sq_valid=sq, skv_valid=skv)
    if rep > 1:
        # GQA: fold each group of rep query heads onto its kv head
        B, Skv = dk_h.shape[0], dk_h.shape[1]
        D = dk_h.shape[-1]
        dk = dk_h.reshape(B, Skv, KV, rep, D).sum(3)
        dv = dv_h.reshape(B, Skv, KV, rep, D).sum(3)
    else:
        dk, dv = dk_h, dv_h
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q (B, Sq, H, D); k/v (B, Skv, KV, D) -> (B, Sq, H, D)."""
    if interpret is None:
        interpret = _on_cpu()
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    bq_ = min(bq, Sq) if Sq >= 8 else Sq
    bk_ = min(bk, Skv) if Skv >= 8 else Skv
    pad_q = (-Sq) % bq_
    pad_k = (-Skv) % bk_
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    cfg = (causal, window, bq_, bk_, interpret, Sq, Skv)
    out = _flash_core(cfg, q, k, v)
    return out[:, :Sq]
