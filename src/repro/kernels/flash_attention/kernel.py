"""Pallas TPU flash attention (blockwise online softmax, GQA, sliding window).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv-block axis is
innermost, so VMEM scratch (running max m, denominator l, accumulator acc)
persists across kv steps of one (b, h, qi) tile, MaxText-style.

BlockSpecs keep one (Bq, D) query tile, one (Bk, D) key/value tile, and the
fp32 accumulator in VMEM; D is the full head dim (MXU-aligned 64/128) so
every matmul hits the MXU with lane=128-friendly shapes. GQA is handled in
the index_map: query head h reads kv head h // rep.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                  *, bq: int, bk: int, sq: int, skv: int,
                  causal: bool, window: Optional[int], scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (skv - sq)                                  # right-aligned positions
    kv_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    mask = kv_pos < skv
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window

    # skip fully-masked blocks (structural: causal upper triangle / window)
    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale   # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, D)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    if causal or window is not None:
        lo = qi * bq + (skv - sq)
        hi = (qi + 1) * bq - 1 + (skv - sq)
        block_lo = ki * bk
        block_hi = (ki + 1) * bk - 1
        live = block_lo <= hi
        if window is not None:
            live &= block_hi > lo - window

        @pl.when(live)
        def _():
            compute()
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        # log-sum-exp per query row: the bwd kernels rebuild p = exp(s - lse)
        # from it instead of re-running the online softmax
        lse_ref[0, 0] = m_scr[...] + jnp.log(l)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: Optional[int] = None,
    bq: int = 128, bk: int = 128, interpret: bool = False,
    sq_valid: Optional[int] = None, skv_valid: Optional[int] = None,
    return_lse: bool = False,
):
    """q (B, Sq, H, D); k/v (B, Skv, KV, D) -> (B, Sq, H, D).

    ``sq_valid``/``skv_valid``: logical lengths when inputs are padded to
    block multiples (masking and right-alignment use the logical lengths).
    ``return_lse=True`` also returns the per-row log-sum-exp (B, H, Sq)
    fp32 — the residual the backward kernels consume.
    """
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    sq_valid = sq_valid or Sq
    skv_valid = skv_valid or Skv
    rep = H // KV
    bq = min(bq, max(Sq, 8))
    bk = min(bk, max(Skv, 8))
    nq = pl.cdiv(Sq, bq)
    nk = pl.cdiv(Skv, bk)
    scale = 1.0 / math.sqrt(D)

    # layout: (B, H, S, D) blocks of (1, 1, b, D)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, sq=sq_valid, skv=skv_valid,
        causal=causal, window=window, scale=scale)

    out, lse = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki, _rep=rep: (b, h // _rep, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki, _rep=rep: (b, h // _rep, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nq * bq, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, nq * bq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :Sq].transpose(0, 2, 1, 3)
    if return_lse:
        return out, lse[:, :, :Sq]
    return out


def _block_mask(qi, ki, bq, bk, sq, skv, causal, window):
    """(bq, bk) validity mask + the structural liveness predicate for the
    (qi, ki) tile — shared by the fwd and both bwd kernels so all three
    agree exactly on which scores exist."""
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (skv - sq)                                  # right-aligned positions
    kv_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kv_pos < skv
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    live = None
    if causal or window is not None:
        lo = qi * bq + (skv - sq)
        hi = (qi + 1) * bq - 1 + (skv - sq)
        live = ki * bk <= hi
        if window is not None:
            live &= (ki + 1) * bk - 1 > lo - window
    return mask, live


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, dq_scr,
                         *, bq: int, bk: int, sq: int, skv: int,
                         causal: bool, window: Optional[int], scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    mask, live = _block_mask(qi, ki, bq, bk, sq, skv, causal, window)

    def compute():
        qs = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)         # (bq, D)
        lse = lse_ref[0, 0]                           # (bq,)
        delta = delta_ref[0, 0]                       # (bq,)
        s = jax.lax.dot_general(qs, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if live is not None:
        @pl.when(live)
        def _():
            compute()
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finish():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_scr, dv_scr,
                          *, bq: int, bk: int, sq: int, skv: int,
                          causal: bool, window: Optional[int], scale: float):
    # grid (B, H, nk, nq): the q-block axis is innermost so the dk/dv
    # scratch accumulators persist across q steps of one (b, h, ki) tile
    ki = pl.program_id(2)
    qi = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    mask, live = _block_mask(qi, ki, bq, bk, sq, skv, causal, window)

    def compute():
        qs = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)         # (bq, D)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(qs, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)  # (bq, bk)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bk, D)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dk_scr[...] += jax.lax.dot_general(
            ds, qs, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bk, D)

    if live is not None:
        @pl.when(live)
        def _():
            compute()
    else:
        compute()

    @pl.when(qi == nq - 1)
    def _finish():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, do: jax.Array,
    lse: jax.Array, delta: jax.Array, *,
    causal: bool = True, window: Optional[int] = None,
    bq: int = 128, bk: int = 128, interpret: bool = False,
    sq_valid: Optional[int] = None, skv_valid: Optional[int] = None,
):
    """Backward pass: q/do (B, Sq, H, D); k/v (B, Skv, KV, D);
    lse/delta (B, H, Sq) fp32 (delta = rowsum(dO * O)).

    Returns ``(dq, dk_h, dv_h)`` with dq (B, Sq, H, D) and dk_h/dv_h
    **per query head** (B, Skv, H, D) — the caller sums each group of
    ``H // KV`` query heads back onto its kv head (GQA), which keeps both
    kernels free of cross-program accumulation.
    """
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    sq_valid = sq_valid or Sq
    skv_valid = skv_valid or Skv
    rep = H // KV
    bq = min(bq, max(Sq, 8))
    bk = min(bk, max(Skv, 8))
    nq = pl.cdiv(Sq, bq)
    nk = pl.cdiv(Skv, bk)
    scale = 1.0 / math.sqrt(D)

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    dot = do.transpose(0, 2, 1, 3)
    lse = lse.astype(jnp.float32)
    delta = delta.astype(jnp.float32)

    common = dict(bq=bq, bk=bk, sq=sq_valid, skv=skv_valid,
                  causal=causal, window=window, scale=scale)
    q_spec = pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, bk, D), lambda b, h, qi, ki, _rep=rep: (b, h // _rep, ki, 0))
    row_spec = pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi))

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, **common),
        grid=(B, H, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)

    # dkv grid swaps the two block axes (q innermost); remap the specs
    q_spec2 = pl.BlockSpec((1, 1, bq, D), lambda b, h, ki, qi: (b, h, qi, 0))
    kv_spec2 = pl.BlockSpec(
        (1, 1, bk, D), lambda b, h, ki, qi, _rep=rep: (b, h // _rep, ki, 0))
    row_spec2 = pl.BlockSpec((1, 1, bq), lambda b, h, ki, qi: (b, h, qi))
    out_kv2 = pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, qi: (b, h, ki, 0))
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, **common),
        grid=(B, H, nk, nq),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, row_spec2, row_spec2],
        out_specs=[out_kv2, out_kv2],
        out_shape=[jax.ShapeDtypeStruct((B, H, nk * bk, D), k.dtype),
                   jax.ShapeDtypeStruct((B, H, nk * bk, D), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)

    dq = dq[:, :, :Sq].transpose(0, 2, 1, 3)
    dk_h = dk_h[:, :, :Skv].transpose(0, 2, 1, 3)
    dv_h = dv_h[:, :, :Skv].transpose(0, 2, 1, 3)
    return dq, dk_h, dv_h
