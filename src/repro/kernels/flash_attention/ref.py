"""Pure-jnp oracle for blockwise causal GQA attention (+ sliding window)."""

from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp


def attention_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """q (B, Sq, H, D); k/v (B, Skv, KV, D); returns (B, Sq, H, D).

    Naive O(S^2) attention in fp32 — the correctness oracle for the Pallas
    flash kernel and for ``repro.models.layers.chunked_attention``.
    """
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    rep = H // KV
    qf = q.astype(jnp.float32) / math.sqrt(D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, rep, axis=2)
    vf = jnp.repeat(vf, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # right-aligned
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jnp.exp(scores - jnp.max(scores, -1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)
