"""Differentiable fused disparity terms over pytrees (no flatten-concat).

``masked_l1_terms`` / ``masked_cosine_terms`` take two same-structure
pytrees plus an optional flat mask over the concatenated coordinate order
(the order ``tree_to_vector`` uses: ``jax.tree_util.tree_leaves``) and
return the reduction *terms* the disparity metrics are built from:

* l1:     ``(sum |a-b|*m, count)`` — count is ``sum m`` masked, the static
  coordinate total unmasked;
* cosine: ``(sum am*bm, sum am^2, sum bm^2)`` with ``am = a*m``.

Both are wrapped in a ``custom_vjp`` whose backward is the closed
elementwise form (``g * sign(a-b) * m`` etc.), so neither direction ever
materializes the two full ``tree_to_vector`` concatenations the historic
``l1_disparity``/``cosine_distance`` paid per GI iteration per lane — the
mask is *sliced* per leaf (cheap views), partial sums accumulate across
leaves, and the backward writes only the cotangents that must exist anyway.

Backend policy differs from ``sparsify_mask`` on purpose: these terms sit
inside the GI Adam loop (hundreds of evaluations per client per round), so
the Pallas kernels are used on TPU only — running the Pallas *interpreter*
per iteration on CPU would dominate the loop. Every other backend takes the
exact jnp fallback (same math, leaf-wise partials, still concat-free).
Tests drive the kernels explicitly with ``interpret=True``.
"""

from __future__ import annotations

import functools
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import QuantizedTree, dequant_flat
from repro.kernels.fused_disparity.kernel import (
    LANES, l1_terms_dq_pallas, l1_terms_pallas, masked_cosine_terms_dq_pallas,
    masked_cosine_terms_pallas, masked_l1_terms_dq_pallas,
    masked_l1_terms_pallas)

# below this many coordinates a leaf stays in plain jnp even in kernel mode
# (same rationale as repro.core.sparsify.KERNEL_MIN_SIZE: the launch costs
# more than the reduction)
KERNEL_MIN_SIZE = 4096


def _kernel_default() -> bool:
    # TPU only — see module docstring (unlike sparsify_mask, which is called
    # once per round and can afford the CPU interpreter in tests)
    return jax.default_backend() == "tpu"


def _flat_leaves(tree: Any) -> List[jax.Array]:
    return [l.astype(jnp.float32).reshape(-1)
            for l in jax.tree_util.tree_leaves(tree)]


def _mask_slices(mask: Optional[jax.Array], leaves: List[jax.Array]
                 ) -> Optional[List[jax.Array]]:
    """Per-leaf views of the flat mask (tree_to_vector coordinate order)."""
    if mask is None:
        return None
    m = mask.astype(jnp.float32)
    out, off = [], 0
    for l in leaves:
        n = l.shape[-1]
        out.append(jax.lax.slice_in_dim(m, off, off + n, axis=-1))
        off += n
    return out


def _use_kernel(leaf: jax.Array, static) -> bool:
    use_kernel, _ = static
    return use_kernel and leaf.shape[-1] >= KERNEL_MIN_SIZE


# --------------------------------------------------------------------------- #
# L1 terms
# --------------------------------------------------------------------------- #


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _l1_terms(static, a_leaves, b_leaves, m_leaves):
    """(sum |a-b|*m, sum m) over flat leaf lists; m_leaves=None -> m=1 and
    the count term is the static coordinate total."""
    _, interpret = static
    s = jnp.zeros((), jnp.float32)
    c = jnp.zeros((), jnp.float32)
    total = 0
    for i, (a, b) in enumerate(zip(a_leaves, b_leaves)):
        total += a.shape[-1]
        m = None if m_leaves is None else m_leaves[i]
        if _use_kernel(a, static):
            if m is None:
                s = s + l1_terms_pallas(a, b, interpret=interpret)
            else:
                ls, lc = masked_l1_terms_pallas(a, b, m, interpret=interpret)
                s, c = s + ls, c + lc
        else:
            d = jnp.abs(a - b)
            if m is None:
                s = s + jnp.sum(d)
            else:
                s = s + jnp.sum(d * m)
                c = c + jnp.sum(m)
    if m_leaves is None:
        c = jnp.asarray(float(total), jnp.float32)
    return s, c


def _l1_terms_fwd(static, a_leaves, b_leaves, m_leaves):
    return _l1_terms(static, a_leaves, b_leaves, m_leaves), \
        (a_leaves, b_leaves, m_leaves)


def _l1_terms_bwd(static, res, cts):
    a_leaves, b_leaves, m_leaves = res
    gs, gc = cts
    da, db, dm = [], [], []
    for i, (a, b) in enumerate(zip(a_leaves, b_leaves)):
        sign = jnp.sign(a - b)                  # matches d|x| = sign(x) dx
        if m_leaves is None:
            g = gs * sign
            da.append(g)
            db.append(-g)
        else:
            m = m_leaves[i]
            g = gs * sign * m
            da.append(g)
            db.append(-g)
            dm.append(gs * jnp.abs(a - b) + gc)
    return da, db, (None if m_leaves is None else dm)


_l1_terms.defvjp(_l1_terms_fwd, _l1_terms_bwd)


# --------------------------------------------------------------------------- #
# Cosine terms
# --------------------------------------------------------------------------- #


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _cos_terms(static, a_leaves, b_leaves, m_leaves):
    """(sum am*bm, sum am^2, sum bm^2) with am = a*m over flat leaf lists."""
    _, interpret = static
    d = jnp.zeros((), jnp.float32)
    na = jnp.zeros((), jnp.float32)
    nb = jnp.zeros((), jnp.float32)
    for i, (a, b) in enumerate(zip(a_leaves, b_leaves)):
        m = None if m_leaves is None else m_leaves[i]
        if _use_kernel(a, static):
            ld, lna, lnb = masked_cosine_terms_pallas(a, b, m,
                                                      interpret=interpret)
        else:
            am = a if m is None else a * m
            bm = b if m is None else b * m
            ld = jnp.sum(am * bm)
            lna = jnp.sum(am * am)
            lnb = jnp.sum(bm * bm)
        d, na, nb = d + ld, na + lna, nb + lnb
    return d, na, nb


def _cos_terms_fwd(static, a_leaves, b_leaves, m_leaves):
    return _cos_terms(static, a_leaves, b_leaves, m_leaves), \
        (a_leaves, b_leaves, m_leaves)


def _cos_terms_bwd(static, res, cts):
    a_leaves, b_leaves, m_leaves = res
    gd, gna, gnb = cts
    da, db, dm = [], [], []
    for i, (a, b) in enumerate(zip(a_leaves, b_leaves)):
        m = None if m_leaves is None else m_leaves[i]
        am = a if m is None else a * m
        bm = b if m is None else b * m
        ga = gd * bm + 2.0 * gna * am           # d/d(am), then chain by m
        gb = gd * am + 2.0 * gnb * bm
        if m is None:
            da.append(ga)
            db.append(gb)
        else:
            da.append(ga * m)
            db.append(gb * m)
            dm.append(a * ga + b * gb)
    return da, db, (None if m_leaves is None else dm)


_cos_terms.defvjp(_cos_terms_fwd, _cos_terms_bwd)


# --------------------------------------------------------------------------- #
# Dequant-fused terms: the b operand is a quantized payload (int8 leaves +
# per-tile f32 scales). Neither direction materializes the dequantized fp32
# tree: the forward reconstructs q*s in-register (Pallas) or as a fused
# elementwise chain (jnp fallback), and the custom_vjp's residuals keep the
# *int8* payload — at B=128 cohorts that is the HBM saving, since the plain
# path would otherwise hold fp32 dequant buffers live across fwd->bwd.
# The payload gets a float0 cotangent (integer primal, nothing to
# differentiate); scales get symbolic zeros (the GI loss only
# differentiates the estimate side).
# --------------------------------------------------------------------------- #


def _use_kernel_dq(leaf: jax.Array, static) -> bool:
    use_kernel, _, tile = static
    # the Pallas dq kernels hard-wire one scale per 128-lane row; any other
    # tile stays on the (exact) jnp fallback
    return use_kernel and tile == LANES and leaf.shape[-1] >= KERNEL_MIN_SIZE


def _float0_like(leaves: List[jax.Array]) -> List[np.ndarray]:
    """Symbolic-zero cotangents for integer payload leaves."""
    return [np.zeros(q.shape, jax.dtypes.float0) for q in leaves]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _l1_terms_dq(static, a_leaves, q_leaves, s_leaves, m_leaves):
    """(sum |a - q*s|*m, sum m) over flat leaf lists; m_leaves=None -> m=1
    and the count term is the static coordinate total."""
    _, interpret, tile = static
    s = jnp.zeros((), jnp.float32)
    c = jnp.zeros((), jnp.float32)
    total = 0
    for i, (a, q) in enumerate(zip(a_leaves, q_leaves)):
        total += a.shape[-1]
        sc = s_leaves[i]
        m = None if m_leaves is None else m_leaves[i]
        if _use_kernel_dq(a, static):
            if m is None:
                s = s + l1_terms_dq_pallas(a, q, sc, interpret=interpret)
            else:
                ls, lc = masked_l1_terms_dq_pallas(a, q, sc, m,
                                                   interpret=interpret)
                s, c = s + ls, c + lc
        else:
            d = jnp.abs(a - dequant_flat(q, sc, tile))
            if m is None:
                s = s + jnp.sum(d)
            else:
                s = s + jnp.sum(d * m)
                c = c + jnp.sum(m)
    if m_leaves is None:
        c = jnp.asarray(float(total), jnp.float32)
    return s, c


def _l1_terms_dq_fwd(static, a_leaves, q_leaves, s_leaves, m_leaves):
    return _l1_terms_dq(static, a_leaves, q_leaves, s_leaves, m_leaves), \
        (a_leaves, q_leaves, s_leaves, m_leaves)


def _l1_terms_dq_bwd(static, res, cts):
    a_leaves, q_leaves, s_leaves, m_leaves = res
    _, _, tile = static
    gs, gc = cts
    da, dm = [], []
    for i, (a, q) in enumerate(zip(a_leaves, q_leaves)):
        diff = a - dequant_flat(q, s_leaves[i], tile)  # recomputed, fused
        sign = jnp.sign(diff)
        if m_leaves is None:
            da.append(gs * sign)
        else:
            m = m_leaves[i]
            da.append(gs * sign * m)
            dm.append(gs * jnp.abs(diff) + gc)
    return (da, _float0_like(q_leaves),
            [jnp.zeros_like(s) for s in s_leaves],
            (None if m_leaves is None else dm))


_l1_terms_dq.defvjp(_l1_terms_dq_fwd, _l1_terms_dq_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _cos_terms_dq(static, a_leaves, q_leaves, s_leaves, m_leaves):
    """(sum am*bm, sum am^2, sum bm^2) with b = q*s over flat leaf lists."""
    _, interpret, tile = static
    d = jnp.zeros((), jnp.float32)
    na = jnp.zeros((), jnp.float32)
    nb = jnp.zeros((), jnp.float32)
    for i, (a, q) in enumerate(zip(a_leaves, q_leaves)):
        sc = s_leaves[i]
        m = None if m_leaves is None else m_leaves[i]
        if _use_kernel_dq(a, static):
            ld, lna, lnb = masked_cosine_terms_dq_pallas(
                a, q, sc, m, interpret=interpret)
        else:
            b = dequant_flat(q, sc, tile)
            am = a if m is None else a * m
            bm = b if m is None else b * m
            ld = jnp.sum(am * bm)
            lna = jnp.sum(am * am)
            lnb = jnp.sum(bm * bm)
        d, na, nb = d + ld, na + lna, nb + lnb
    return d, na, nb


def _cos_terms_dq_fwd(static, a_leaves, q_leaves, s_leaves, m_leaves):
    return _cos_terms_dq(static, a_leaves, q_leaves, s_leaves, m_leaves), \
        (a_leaves, q_leaves, s_leaves, m_leaves)


def _cos_terms_dq_bwd(static, res, cts):
    a_leaves, q_leaves, s_leaves, m_leaves = res
    _, _, tile = static
    gd, gna, _gnb = cts
    da, dm = [], []
    for i, (a, q) in enumerate(zip(a_leaves, q_leaves)):
        b = dequant_flat(q, s_leaves[i], tile)
        m = None if m_leaves is None else m_leaves[i]
        am = a if m is None else a * m
        bm = b if m is None else b * m
        ga = gd * bm + 2.0 * gna * am           # d/d(am), then chain by m
        if m is None:
            da.append(ga)
        else:
            gb = gd * am + 2.0 * _gnb * bm
            da.append(ga * m)
            dm.append(a * ga + b * gb)
    return (da, _float0_like(q_leaves),
            [jnp.zeros_like(s) for s in s_leaves],
            (None if m_leaves is None else dm))


_cos_terms_dq.defvjp(_cos_terms_dq_fwd, _cos_terms_dq_bwd)


# --------------------------------------------------------------------------- #
# Public pytree-level API
# --------------------------------------------------------------------------- #


def masked_l1_terms(tree_a: Any, tree_b: Any,
                    mask: Optional[jax.Array] = None,
                    use_kernel: Optional[bool] = None,
                    interpret: bool = False
                    ) -> Tuple[jax.Array, jax.Array]:
    """``(sum |a-b|*m, count)`` over two pytrees and an optional flat mask.

    ``count`` is ``sum m`` when masked, the total coordinate count when not.
    Differentiable in ``tree_a``/``tree_b``/``mask``.
    """
    if use_kernel is None:
        use_kernel = _kernel_default()
    la, lb = _flat_leaves(tree_a), _flat_leaves(tree_b)
    lm = _mask_slices(mask, la)
    return _l1_terms((bool(use_kernel), bool(interpret)), la, lb, lm)


def masked_cosine_terms(tree_a: Any, tree_b: Any,
                        mask: Optional[jax.Array] = None,
                        use_kernel: Optional[bool] = None,
                        interpret: bool = False
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """``(dot, |a*m|^2, |b*m|^2)`` terms of the masked cosine distance."""
    if use_kernel is None:
        use_kernel = _kernel_default()
    la, lb = _flat_leaves(tree_a), _flat_leaves(tree_b)
    lm = _mask_slices(mask, la)
    return _cos_terms((bool(use_kernel), bool(interpret)), la, lb, lm)


def masked_l1_terms_dq(tree_a: Any, qt: QuantizedTree,
                       mask: Optional[jax.Array] = None,
                       use_kernel: Optional[bool] = None,
                       interpret: bool = False
                       ) -> Tuple[jax.Array, jax.Array]:
    """``masked_l1_terms`` with the b operand as a quantized payload —
    b is never materialized in fp32. Differentiable in ``tree_a``/``mask``;
    the payload/scales get zero cotangents."""
    if use_kernel is None:
        use_kernel = _kernel_default()
    la = _flat_leaves(tree_a)
    lm = _mask_slices(mask, la)
    return _l1_terms_dq((bool(use_kernel), bool(interpret), int(qt.tile)),
                        la, list(qt.q), list(qt.s), lm)


def masked_cosine_terms_dq(tree_a: Any, qt: QuantizedTree,
                           mask: Optional[jax.Array] = None,
                           use_kernel: Optional[bool] = None,
                           interpret: bool = False
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """``masked_cosine_terms`` with the b operand as a quantized payload."""
    if use_kernel is None:
        use_kernel = _kernel_default()
    la = _flat_leaves(tree_a)
    lm = _mask_slices(mask, la)
    return _cos_terms_dq((bool(use_kernel), bool(interpret), int(qt.tile)),
                         la, list(qt.q), list(qt.s), lm)
