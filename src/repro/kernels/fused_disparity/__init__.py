from repro.kernels.fused_disparity.kernel import (  # noqa: F401
    l1_terms_dq_pallas, l1_terms_pallas, masked_cosine_terms_dq_pallas,
    masked_cosine_terms_pallas, masked_l1_terms_dq_pallas,
    masked_l1_terms_pallas)
from repro.kernels.fused_disparity.ops import (  # noqa: F401
    masked_cosine_terms, masked_cosine_terms_dq, masked_l1_terms,
    masked_l1_terms_dq)
from repro.kernels.fused_disparity.ref import (  # noqa: F401
    cosine_distance_dequant_reference, cosine_distance_reference,
    l1_disparity_dequant_reference, l1_disparity_reference)
