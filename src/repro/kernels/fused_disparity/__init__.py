from repro.kernels.fused_disparity.kernel import (  # noqa: F401
    l1_terms_pallas, masked_cosine_terms_pallas, masked_l1_terms_pallas)
from repro.kernels.fused_disparity.ops import (  # noqa: F401
    masked_cosine_terms, masked_l1_terms)
from repro.kernels.fused_disparity.ref import (  # noqa: F401
    cosine_distance_reference, l1_disparity_reference)
