"""Pallas TPU kernels: fused masked disparity reductions (paper Eq. 6/7).

The GI loss evaluates ``Disparity[est_update, target_update]`` once per Adam
iteration per lane, forward AND backward. The historic implementation
flattened both pytrees with ``tree_to_vector`` — two full model-size
concatenations (plus the ``|a-b|`` intermediate) materialized per iteration
per lane. These kernels compute the reduction *terms* directly from tiled
views of the operands, one streaming pass per leaf, so nothing but the
scalar partials ever hits memory:

* ``masked_l1_terms_pallas``     — ``(sum |a-b|*m, sum m)``;
* ``l1_terms_pallas``            — unmasked ``sum |a-b|`` (count is static);
* ``masked_cosine_terms_pallas`` — ``(sum am*bm, sum am^2, sum bm^2)`` with
  ``am = a*m`` (exactly the historic masked-cosine semantics for any mask);
* ``cosine_terms_pallas``        — the unmasked dot/norm terms.

Each kernel streams ``(block_rows, 128)`` VMEM tiles over a 1-D grid and
writes one partial per grid step into a per-tile SMEM row — no cross-step
accumulation, so the kernels stay correct under ``jax.vmap`` lifting (vmap
prepends a batch grid axis; program_id-based init patterns would break).
The wrapper sums the tiny ``(tiles,)`` partials. Inputs are zero-padded to
the tile grid: padding contributes ``|0-0|*0 = 0`` to every term.

Backward passes are closed-form elementwise (``sign(a-b)*m`` etc.) and live
in ``ops.py`` behind a ``custom_vjp`` — ``pallas_call`` is not
auto-differentiable, and the hand-written VJP also avoids re-materializing
the concat in the backward sweep.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _l1_kernel(a_ref, b_ref, m_ref, s_ref, c_ref):
    d = jnp.abs(a_ref[...] - b_ref[...])
    m = m_ref[...]
    s_ref[0, 0] = jnp.sum(d * m)
    c_ref[0, 0] = jnp.sum(m)


def _l1_kernel_nomask(a_ref, b_ref, s_ref):
    s_ref[0, 0] = jnp.sum(jnp.abs(a_ref[...] - b_ref[...]))


def _cos_kernel(a_ref, b_ref, m_ref, d_ref, na_ref, nb_ref):
    m = m_ref[...]
    am = a_ref[...] * m
    bm = b_ref[...] * m
    d_ref[0, 0] = jnp.sum(am * bm)
    na_ref[0, 0] = jnp.sum(am * am)
    nb_ref[0, 0] = jnp.sum(bm * bm)


def _cos_kernel_nomask(a_ref, b_ref, d_ref, na_ref, nb_ref):
    a = a_ref[...]
    b = b_ref[...]
    d_ref[0, 0] = jnp.sum(a * b)
    na_ref[0, 0] = jnp.sum(a * a)
    nb_ref[0, 0] = jnp.sum(b * b)


# --------------------------------------------------------------------------- #
# Dequant-fused variants: the b operand arrives as an int8 payload tile plus
# one f32 scale per 128-lane row (core.quantize tile == LANES), and q*s is
# reconstructed in-register — the quantized GI target never exists as an
# fp32 buffer in HBM. int8 rows are a quarter of the f32 read traffic, which
# is the point at B=128 cohorts.
# --------------------------------------------------------------------------- #


def _l1_dq_kernel(a_ref, q_ref, s_ref, m_ref, s_out, c_out):
    b = q_ref[...].astype(jnp.float32) * s_ref[...]   # (br,128) * (br,1)
    d = jnp.abs(a_ref[...] - b)
    m = m_ref[...]
    s_out[0, 0] = jnp.sum(d * m)
    c_out[0, 0] = jnp.sum(m)


def _l1_dq_kernel_nomask(a_ref, q_ref, s_ref, s_out):
    b = q_ref[...].astype(jnp.float32) * s_ref[...]
    s_out[0, 0] = jnp.sum(jnp.abs(a_ref[...] - b))


def _cos_dq_kernel(a_ref, q_ref, s_ref, m_ref, d_ref, na_ref, nb_ref):
    m = m_ref[...]
    am = a_ref[...] * m
    bm = q_ref[...].astype(jnp.float32) * s_ref[...] * m
    d_ref[0, 0] = jnp.sum(am * bm)
    na_ref[0, 0] = jnp.sum(am * am)
    nb_ref[0, 0] = jnp.sum(bm * bm)


def _cos_dq_kernel_nomask(a_ref, q_ref, s_ref, d_ref, na_ref, nb_ref):
    a = a_ref[...]
    b = q_ref[...].astype(jnp.float32) * s_ref[...]
    d_ref[0, 0] = jnp.sum(a * b)
    na_ref[0, 0] = jnp.sum(a * a)
    nb_ref[0, 0] = jnp.sum(b * b)


def _tile_call(kernel, inputs, n_out: int, *, block_rows: int,
               interpret: bool):
    """Run ``kernel`` over row tiles of the 2-D inputs; returns ``n_out``
    per-tile partial vectors of shape (tiles,)."""
    R, lanes = inputs[0].shape
    br = min(block_rows, R)
    nr = pl.cdiv(R, br)
    scalar = functools.partial(pl.BlockSpec, (1, 1), lambda i: (i, 0),
                               memory_space=pltpu.SMEM)
    out = pl.pallas_call(
        kernel,
        grid=(nr,),
        in_specs=[pl.BlockSpec((br, lanes), lambda i: (i, 0))
                  for _ in inputs],
        out_specs=tuple(scalar() for _ in range(n_out)),
        out_shape=tuple(jax.ShapeDtypeStruct((nr, 1), jnp.float32)
                        for _ in range(n_out)),
        interpret=interpret,
    )(*inputs)
    return tuple(o.reshape(-1) for o in out)


def _tiled(v: jax.Array, block_rows: int) -> jax.Array:
    """Zero-pad a flat vector (f32, or int8 payload) to a (R, 128) tile view
    with R a multiple of ``block_rows`` (zeros are term-neutral for every
    kernel above: padded scales are zero too, so padded dequant is 0*0)."""
    n = v.shape[0]
    per_tile = block_rows * LANES
    pad = (-n) % per_tile
    if pad:
        v = jnp.pad(v, (0, pad))
    return v.reshape(-1, LANES)


def _tiled_scales(s: jax.Array, rows: int) -> jax.Array:
    """Zero-pad per-128-lane-row scales ``(t,)`` to an ``(R, 1)`` column
    matching a ``_tiled`` payload view with R rows (R >= t always: R is t
    rounded up to the block grid)."""
    pad = rows - s.shape[0]
    if pad:
        s = jnp.pad(s, (0, pad))
    return s.reshape(-1, 1)


def _tile_call_dq(kernel, a, q, s, extra, n_out: int, *, block_rows: int,
                  interpret: bool):
    """`_tile_call` for dequant kernels: the scale operand blocks as
    ``(br, 1)`` columns while payload/mask operands block as ``(br, 128)``."""
    R, lanes = a.shape
    br = min(block_rows, R)
    nr = pl.cdiv(R, br)
    inputs = [a, q, s] + list(extra)
    widths = [lanes, lanes, 1] + [lanes] * len(extra)
    scalar = functools.partial(pl.BlockSpec, (1, 1), lambda i: (i, 0),
                               memory_space=pltpu.SMEM)
    out = pl.pallas_call(
        kernel,
        grid=(nr,),
        in_specs=[pl.BlockSpec((br, w), lambda i: (i, 0)) for w in widths],
        out_specs=tuple(scalar() for _ in range(n_out)),
        out_shape=tuple(jax.ShapeDtypeStruct((nr, 1), jnp.float32)
                        for _ in range(n_out)),
        interpret=interpret,
    )(*inputs)
    return tuple(o.reshape(-1) for o in out)


def masked_l1_terms_pallas(a: jax.Array, b: jax.Array, m: jax.Array, *,
                           block_rows: int = 256,
                           interpret: bool = False
                           ) -> Tuple[jax.Array, jax.Array]:
    """(sum |a-b|*m, sum m) for flat f32 vectors a, b and f32 mask m."""
    args = [_tiled(v, block_rows) for v in (a, b, m)]
    s, c = _tile_call(_l1_kernel, args, 2, block_rows=block_rows,
                      interpret=interpret)
    return jnp.sum(s), jnp.sum(c)


def l1_terms_pallas(a: jax.Array, b: jax.Array, *, block_rows: int = 256,
                    interpret: bool = False) -> jax.Array:
    """sum |a-b| for flat f32 vectors (the count is just ``a.size``)."""
    args = [_tiled(v, block_rows) for v in (a, b)]
    (s,) = _tile_call(_l1_kernel_nomask, args, 1, block_rows=block_rows,
                      interpret=interpret)
    return jnp.sum(s)


def masked_cosine_terms_pallas(a: jax.Array, b: jax.Array,
                               m: Optional[jax.Array], *,
                               block_rows: int = 256,
                               interpret: bool = False
                               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(sum am*bm, sum am^2, sum bm^2) with am = a*m (m=None -> unmasked)."""
    if m is None:
        args = [_tiled(v, block_rows) for v in (a, b)]
        d, na, nb = _tile_call(_cos_kernel_nomask, args, 3,
                               block_rows=block_rows, interpret=interpret)
    else:
        args = [_tiled(v, block_rows) for v in (a, b, m)]
        d, na, nb = _tile_call(_cos_kernel, args, 3, block_rows=block_rows,
                               interpret=interpret)
    return jnp.sum(d), jnp.sum(na), jnp.sum(nb)


def masked_l1_terms_dq_pallas(a: jax.Array, q: jax.Array, s: jax.Array,
                              m: jax.Array, *, block_rows: int = 256,
                              interpret: bool = False
                              ) -> Tuple[jax.Array, jax.Array]:
    """(sum |a - q*s|*m, sum m): b is an int8 payload with one f32 scale per
    128 coordinates (core.quantize tile == LANES), dequantized in-register."""
    at, qt, mt = (_tiled(v, block_rows) for v in (a, q, m))
    st = _tiled_scales(s, at.shape[0])
    ps, pc = _tile_call_dq(_l1_dq_kernel, at, qt, st, [mt], 2,
                           block_rows=block_rows, interpret=interpret)
    return jnp.sum(ps), jnp.sum(pc)


def l1_terms_dq_pallas(a: jax.Array, q: jax.Array, s: jax.Array, *,
                       block_rows: int = 256,
                       interpret: bool = False) -> jax.Array:
    """Unmasked ``sum |a - q*s|`` (the count is ``a.size``, static)."""
    at, qt = _tiled(a, block_rows), _tiled(q, block_rows)
    st = _tiled_scales(s, at.shape[0])
    (ps,) = _tile_call_dq(_l1_dq_kernel_nomask, at, qt, st, [], 1,
                          block_rows=block_rows, interpret=interpret)
    return jnp.sum(ps)


def masked_cosine_terms_dq_pallas(a: jax.Array, q: jax.Array, s: jax.Array,
                                  m: Optional[jax.Array], *,
                                  block_rows: int = 256,
                                  interpret: bool = False
                                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(sum am*bm, sum am^2, sum bm^2) with b = q*s dequantized in-register
    (m=None -> unmasked)."""
    at, qt = _tiled(a, block_rows), _tiled(q, block_rows)
    st = _tiled_scales(s, at.shape[0])
    if m is None:
        d, na, nb = _tile_call_dq(_cos_dq_kernel_nomask, at, qt, st, [], 3,
                                  block_rows=block_rows, interpret=interpret)
    else:
        mt = _tiled(m, block_rows)
        d, na, nb = _tile_call_dq(_cos_dq_kernel, at, qt, st, [mt], 3,
                                  block_rows=block_rows, interpret=interpret)
    return jnp.sum(d), jnp.sum(na), jnp.sum(nb)
