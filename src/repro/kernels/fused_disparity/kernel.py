"""Pallas TPU kernels: fused masked disparity reductions (paper Eq. 6/7).

The GI loss evaluates ``Disparity[est_update, target_update]`` once per Adam
iteration per lane, forward AND backward. The historic implementation
flattened both pytrees with ``tree_to_vector`` — two full model-size
concatenations (plus the ``|a-b|`` intermediate) materialized per iteration
per lane. These kernels compute the reduction *terms* directly from tiled
views of the operands, one streaming pass per leaf, so nothing but the
scalar partials ever hits memory:

* ``masked_l1_terms_pallas``     — ``(sum |a-b|*m, sum m)``;
* ``l1_terms_pallas``            — unmasked ``sum |a-b|`` (count is static);
* ``masked_cosine_terms_pallas`` — ``(sum am*bm, sum am^2, sum bm^2)`` with
  ``am = a*m`` (exactly the historic masked-cosine semantics for any mask);
* ``cosine_terms_pallas``        — the unmasked dot/norm terms.

Each kernel streams ``(block_rows, 128)`` VMEM tiles over a 1-D grid and
writes one partial per grid step into a per-tile SMEM row — no cross-step
accumulation, so the kernels stay correct under ``jax.vmap`` lifting (vmap
prepends a batch grid axis; program_id-based init patterns would break).
The wrapper sums the tiny ``(tiles,)`` partials. Inputs are zero-padded to
the tile grid: padding contributes ``|0-0|*0 = 0`` to every term.

Backward passes are closed-form elementwise (``sign(a-b)*m`` etc.) and live
in ``ops.py`` behind a ``custom_vjp`` — ``pallas_call`` is not
auto-differentiable, and the hand-written VJP also avoids re-materializing
the concat in the backward sweep.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _l1_kernel(a_ref, b_ref, m_ref, s_ref, c_ref):
    d = jnp.abs(a_ref[...] - b_ref[...])
    m = m_ref[...]
    s_ref[0, 0] = jnp.sum(d * m)
    c_ref[0, 0] = jnp.sum(m)


def _l1_kernel_nomask(a_ref, b_ref, s_ref):
    s_ref[0, 0] = jnp.sum(jnp.abs(a_ref[...] - b_ref[...]))


def _cos_kernel(a_ref, b_ref, m_ref, d_ref, na_ref, nb_ref):
    m = m_ref[...]
    am = a_ref[...] * m
    bm = b_ref[...] * m
    d_ref[0, 0] = jnp.sum(am * bm)
    na_ref[0, 0] = jnp.sum(am * am)
    nb_ref[0, 0] = jnp.sum(bm * bm)


def _cos_kernel_nomask(a_ref, b_ref, d_ref, na_ref, nb_ref):
    a = a_ref[...]
    b = b_ref[...]
    d_ref[0, 0] = jnp.sum(a * b)
    na_ref[0, 0] = jnp.sum(a * a)
    nb_ref[0, 0] = jnp.sum(b * b)


def _tile_call(kernel, inputs, n_out: int, *, block_rows: int,
               interpret: bool):
    """Run ``kernel`` over row tiles of the 2-D inputs; returns ``n_out``
    per-tile partial vectors of shape (tiles,)."""
    R, lanes = inputs[0].shape
    br = min(block_rows, R)
    nr = pl.cdiv(R, br)
    scalar = functools.partial(pl.BlockSpec, (1, 1), lambda i: (i, 0),
                               memory_space=pltpu.SMEM)
    out = pl.pallas_call(
        kernel,
        grid=(nr,),
        in_specs=[pl.BlockSpec((br, lanes), lambda i: (i, 0))
                  for _ in inputs],
        out_specs=tuple(scalar() for _ in range(n_out)),
        out_shape=tuple(jax.ShapeDtypeStruct((nr, 1), jnp.float32)
                        for _ in range(n_out)),
        interpret=interpret,
    )(*inputs)
    return tuple(o.reshape(-1) for o in out)


def _tiled(v: jax.Array, block_rows: int) -> jax.Array:
    """Zero-pad a flat f32 vector to a (R, 128) tile view with R a multiple
    of ``block_rows`` (zeros are term-neutral for every kernel above)."""
    n = v.shape[0]
    per_tile = block_rows * LANES
    pad = (-n) % per_tile
    if pad:
        v = jnp.pad(v, (0, pad))
    return v.reshape(-1, LANES)


def masked_l1_terms_pallas(a: jax.Array, b: jax.Array, m: jax.Array, *,
                           block_rows: int = 256,
                           interpret: bool = False
                           ) -> Tuple[jax.Array, jax.Array]:
    """(sum |a-b|*m, sum m) for flat f32 vectors a, b and f32 mask m."""
    args = [_tiled(v, block_rows) for v in (a, b, m)]
    s, c = _tile_call(_l1_kernel, args, 2, block_rows=block_rows,
                      interpret=interpret)
    return jnp.sum(s), jnp.sum(c)


def l1_terms_pallas(a: jax.Array, b: jax.Array, *, block_rows: int = 256,
                    interpret: bool = False) -> jax.Array:
    """sum |a-b| for flat f32 vectors (the count is just ``a.size``)."""
    args = [_tiled(v, block_rows) for v in (a, b)]
    (s,) = _tile_call(_l1_kernel_nomask, args, 1, block_rows=block_rows,
                      interpret=interpret)
    return jnp.sum(s)


def masked_cosine_terms_pallas(a: jax.Array, b: jax.Array,
                               m: Optional[jax.Array], *,
                               block_rows: int = 256,
                               interpret: bool = False
                               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(sum am*bm, sum am^2, sum bm^2) with am = a*m (m=None -> unmasked)."""
    if m is None:
        args = [_tiled(v, block_rows) for v in (a, b)]
        d, na, nb = _tile_call(_cos_kernel_nomask, args, 3,
                               block_rows=block_rows, interpret=interpret)
    else:
        args = [_tiled(v, block_rows) for v in (a, b, m)]
        d, na, nb = _tile_call(_cos_kernel, args, 3, block_rows=block_rows,
                               interpret=interpret)
    return jnp.sum(d), jnp.sum(na), jnp.sum(nb)
