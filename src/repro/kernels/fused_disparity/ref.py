"""Pure-jnp concat-based oracles for the fused disparity terms.

These are the *historic* implementations (flatten both pytrees with a full
concatenation, then reduce) kept verbatim as the correctness reference for
the fused kernels and their jnp fallbacks — and as the "concat" side of the
``gi/disparity_*`` benchmark rows.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def _to_vector(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                            for l in leaves])


def l1_disparity_reference(a: Any, b: Any,
                           mask: Optional[jax.Array] = None) -> jax.Array:
    """Masked mean |a-b| via full concatenation (the seed implementation)."""
    d = jnp.abs(_to_vector(a) - _to_vector(b))
    if mask is None:
        return jnp.mean(d)
    m = mask.astype(jnp.float32)
    return jnp.sum(d * m) / jnp.maximum(jnp.sum(m), 1.0)


def cosine_distance_reference(a: Any, b: Any,
                              mask: Optional[jax.Array] = None) -> jax.Array:
    """1 - cos(a*m, b*m) via full concatenation (the seed implementation —
    the unmasked form is the seed ``cosine_distance``, the masked form is
    the seed ``_gi_loss`` cosine branch)."""
    va, vb = _to_vector(a), _to_vector(b)
    if mask is not None:
        m = mask.astype(jnp.float32)
        va, vb = va * m, vb * m
    return 1.0 - jnp.dot(va, vb) / jnp.maximum(
        jnp.linalg.norm(va) * jnp.linalg.norm(vb), 1e-12)


def l1_disparity_dequant_reference(a: Any, qt: Any,
                                   mask: Optional[jax.Array] = None
                                   ) -> jax.Array:
    """Dequantize-then-fp32 oracle for the dequant-fused l1 terms: the
    quantized payload is fully materialized as an fp32 pytree, then reduced
    through the historic concat path — the traffic the fused variants
    avoid, and the "dequant" side of the ``quant/`` benchmark rows."""
    return l1_disparity_reference(a, qt.to_tree(), mask)


def cosine_distance_dequant_reference(a: Any, qt: Any,
                                      mask: Optional[jax.Array] = None
                                      ) -> jax.Array:
    """Dequantize-then-fp32 oracle for the dequant-fused cosine terms."""
    return cosine_distance_reference(a, qt.to_tree(), mask)
