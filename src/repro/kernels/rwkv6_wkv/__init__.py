from repro.kernels.rwkv6_wkv.ops import wkv6  # noqa: F401
from repro.kernels.rwkv6_wkv.ref import wkv6_reference  # noqa: F401
