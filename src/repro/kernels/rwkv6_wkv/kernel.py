"""Pallas TPU kernel for the RWKV6 WKV recurrence (data-dependent decay).

This is the Finch architecture's core op and has no XLA-native fused
equivalent — on GPU, RWKV ships a CUDA kernel; the TPU adaptation tiles over
(batch, head, time-chunks) with the (N, N) state held in VMEM scratch across
time-chunk grid steps (the innermost grid axis), processing C timesteps per
step with an in-kernel fori_loop. N = 64 keeps the state (64x64 fp32 = 16 KiB)
and one (C, N) slab per operand comfortably in VMEM, and the per-step
outer-product/mat-vec pair maps onto the VPU/MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *, chunk: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)                  # (N,)
    r = r_ref[0, :, 0].astype(jnp.float32)            # (C, N)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    w = w_ref[0, :, 0].astype(jnp.float32)

    def step(t, carry):
        S, out = carry
        a = k[t][:, None] * v[t][None, :]             # (N, N)
        o = jnp.sum((S + u[:, None] * a) * r[t][:, None], axis=0)  # (N,)
        S = w[t][:, None] * S + a
        out = jax.lax.dynamic_update_slice(out, o[None], (t, 0))
        return S, out

    S0 = s_scr[...]
    out0 = jnp.zeros((chunk, r.shape[1]), jnp.float32)
    S, out = jax.lax.fori_loop(0, chunk, step, (S0, out0))
    s_scr[...] = S
    o_ref[0, :, 0] = out.astype(o_ref.dtype)


def wkv6_pallas(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, *, chunk: int = 64,
                interpret: bool = False) -> jax.Array:
    """r,k,v,w (B,T,H,N); u (H,N) -> out (B,T,H,N). T % chunk == 0."""
    B, T, H, N = r.shape
    assert T % chunk == 0, (T, chunk)
    nt = T // chunk

    spec = pl.BlockSpec((1, chunk, 1, N), lambda b, h, ti: (b, ti, h, 0))
    out = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=(B, H, nt),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, N), lambda b, h, ti: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, H, N), r.dtype),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return out
