"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_reference(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                   u: jax.Array) -> jax.Array:
    """r,k,v,w (B,T,H,N) with w in (0,1); u (H,N). Returns out (B,T,H,N).

    S_t[n,m]: state; a_t = k_t (x) v_t;  out_t[m] = sum_n r[n](S[n,m]+u[n]a[n,m]);
    S <- diag(w_t) S + a_t.
    """
    B, T, H, N = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp
        a = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhn,bhnm->bhm", rt, S + uf[None, :, :, None] * a)
        S = wt[..., :, None] * S + a
        return S, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    S0 = jnp.zeros((B, H, N, N), jnp.float32)
    _, outs = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype)
