"""Jit'd wrapper for the WKV6 kernel (interpret=True on CPU)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.kernel import wkv6_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, *, chunk: int = 64,
         interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, T, H, N = r.shape
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        zeros = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    out = wkv6_pallas(r, k, v, w, u, chunk=c, interpret=interpret)
    return out[:, :T]
