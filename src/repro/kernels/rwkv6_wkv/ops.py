"""Jit'd wrapper for the WKV6 kernel (interpret=True on CPU).

``wkv6`` is differentiable: the forward pass runs the Pallas kernel, and a
``jax.custom_vjp`` backward recomputes the recurrence through the exact
pure-jnp oracle (``wkv6_reference``) with ``jax.vjp`` — a remat-style
trade (the recurrence is cheap to replay relative to storing every
per-step state S_t) that keeps gradients bit-comparable to
differentiating the oracle directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.kernel import wkv6_pallas
from repro.kernels.rwkv6_wkv.ref import wkv6_reference


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _wkv6_core(cfg, r, k, v, w, u):
    chunk, interpret = cfg
    return wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)


def _wkv6_core_fwd(cfg, r, k, v, w, u):
    chunk, interpret = cfg
    out = wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return out, (r, k, v, w, u)


def _wkv6_core_bwd(cfg, res, dout):
    r, k, v, w, u = res
    _, vjp = jax.vjp(wkv6_reference, r, k, v, w, u)
    return vjp(dout)


_wkv6_core.defvjp(_wkv6_core_fwd, _wkv6_core_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, *, chunk: int = 64,
         interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, T, H, N = r.shape
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        zeros = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    out = _wkv6_core((c, interpret), r, k, v, w, u)
    return out[:, :T]
