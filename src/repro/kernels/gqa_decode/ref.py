"""Pure-jnp oracle for single-token GQA decode attention over a KV cache."""

from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp


def gqa_decode_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         valid_len: int, *, window: Optional[int] = None
                         ) -> jnp.ndarray:
    """q (B, H, D); k/v (B, S, KV, D); attends positions < valid_len
    (current token at valid_len - 1); optional sliding window."""
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    rep = H // KV
    qf = q.astype(jnp.float32) / math.sqrt(D)
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", qf, kf)
    pos = jnp.arange(S)[None, None, :]
    mask = pos < valid_len
    if window is not None:
        mask = mask & (pos > valid_len - 1 - window)
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhs,bshd->bhd", p, vf).astype(q.dtype)
