"""Jit'd wrapper for the decode attention kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.gqa_decode.kernel import gqa_decode_pallas


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def gqa_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid_len: jax.Array, *,
                         window: Optional[int] = None, bk: int = 512,
                         interpret: Optional[bool] = None) -> jax.Array:
    """q (B, H, D); k/v (B, S, KV, D); positions < valid_len are attended."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, H, D = q.shape
    S = k.shape[1]
    bk_ = min(bk, S)
    pad = (-S) % bk_
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return gqa_decode_pallas(q, k, v, valid_len, window=window, bk=bk_,
                             interpret=interpret)
