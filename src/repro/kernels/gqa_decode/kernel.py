"""Pallas TPU kernel: single-token GQA decode attention over a long KV cache.

decode_32k / long_500k lower this op. The query is one token per sequence;
K/V stream HBM -> VMEM in (Bk, D) blocks along the innermost grid axis with
the online-softmax running (m, l, acc) in VMEM scratch. The dynamic valid
length (current cache position + 1) arrives via scalar prefetch so block
shapes stay static while masking follows the decode position; with a sliding
window, blocks wholly outside [valid-window, valid) are skipped.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, bk: int, window: Optional[int], scale: float):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    valid = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = kv_pos < valid
    if window is not None:
        mask &= kv_pos > valid - 1 - window

    block_live = (ki * bk) < valid
    if window is not None:
        block_live &= ((ki + 1) * bk - 1) > valid - 1 - window

    @pl.when(block_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (1, bk)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, D)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def gqa_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                      valid_len: jax.Array, *, window: Optional[int] = None,
                      bk: int = 512, interpret: bool = False) -> jax.Array:
    """q (B, H, D); k/v (B, S, KV, D); valid_len () int32 -> out (B, H, D)."""
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    rep = H // KV
    bk = min(bk, S)
    nk = pl.cdiv(S, bk)
    scale = 1.0 / math.sqrt(D)

    qt = q.reshape(B, H, 1, D)
    kt = k.transpose(0, 2, 1, 3)          # (B, KV, S, D)
    vt = v.transpose(0, 2, 1, 3)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ki, ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, ref, _rep=rep: (b, h // _rep, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, ref, _rep=rep: (b, h // _rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ki, ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, bk=bk, window=window, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(valid_len, jnp.int32).reshape(1), qt, kt, vt)
    return out[:, :, 0, :]
