from repro.kernels.gqa_decode.ops import gqa_decode_attention  # noqa: F401
from repro.kernels.gqa_decode.ref import gqa_decode_reference  # noqa: F401
