"""Pure-JAX optimizers (the container has no optax).

API mirrors optax minimally::

    opt = sgd(lr=0.01, momentum=0.5)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

All optimizers are pytree-polymorphic and jit-safe. ``fedprox_wrap`` adds the
FedProx proximal term mu*(w - w_global) to the gradients, which is how the
paper runs its FedProx local-program ablation (Appendix E, Table 20).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]   # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), gn


# --------------------------------------------------------------------------- #


def sgd(lr: float, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params=None):
        del params
        if momentum == 0.0:
            return jax.tree_util.tree_map(lambda g: -lr * g, grads), state
        new_m = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda m, g: -lr * (momentum * m + g.astype(jnp.float32)), new_m, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "mu": jax.tree_util.tree_map(z, params),
            "nu": jax.tree_util.tree_map(z, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params=None):
        t = state["t"] + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            updates = jax.tree_util.tree_map(lambda m, v: upd(m, v, None), mu, nu)
        else:
            updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "t": t}

    return Optimizer(init, update)


def fedprox_wrap(base: Optimizer, mu: float, global_params) -> Optimizer:
    """FedProx: grads += mu * (w - w_global) before the base optimizer."""

    def init(params):
        return base.init(params)

    def update(grads, state, params=None):
        assert params is not None, "fedprox needs current params"
        g = jax.tree_util.tree_map(
            lambda gr, p, gp: gr + mu * (p - gp).astype(gr.dtype),
            grads, params, global_params)
        return base.update(g, state, params)

    return Optimizer(init, update)
