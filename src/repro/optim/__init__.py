from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adam,
    fedprox_wrap,
    sgd,
    apply_updates,
    global_norm,
    clip_by_global_norm,
)
