"""Admission control for the stale-upload queue (backpressure frontend).

When stale arrivals outpace GI throughput the service cannot buffer them
unboundedly — recovered-dataset inversion is the expensive stage, so the
queue between the upload stream and the aggregation trigger is *bounded*
(``capacity``) with a configurable overflow policy:

* ``reject``      — turn the new arrival away (client retries later);
* ``drop_oldest`` — evict the oldest queued upload to make room (freshest
  information wins);
* ``coalesce``    — per-client dedup at admission: a new upload from a
  client already queued *replaces* that entry in place (the freshest base
  version wins, queue depth unchanged — the admission-time version of the
  engine's per-cohort dedup); with no duplicate to replace, a full queue
  rejects.

Counter contract (asserted by the soak tests): every offer is counted
exactly once — ``offered == admitted + coalesced + rejected`` — and queued
entries are conserved — ``admitted == popped + dropped_oldest + depth``.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List

POLICIES = ("reject", "drop_oldest", "coalesce")


@dataclasses.dataclass
class StreamArrival:
    """One delivered upload as the service sees it: ``base_version`` is the
    global version the job trained from (assigned at dispatch, possibly
    refreshed by timely dissemination), ``arrival_t`` the virtual time it
    reached the server."""
    client: int
    base_version: int
    dispatch_t: float
    arrival_t: float
    job_id: int


class AdmissionQueue:
    """Bounded FIFO of :class:`StreamArrival` with an overflow policy."""

    def __init__(self, capacity: int, policy: str = "reject"):
        if policy not in POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"have {POLICIES}")
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.policy = policy
        self._q: Deque[StreamArrival] = deque()
        self.counters: Dict[str, int] = {
            "offered": 0, "admitted": 0, "coalesced": 0, "rejected": 0,
            "dropped_oldest": 0, "popped": 0}
        self.max_depth = 0

    def __len__(self) -> int:
        return len(self._q)

    def distinct(self) -> int:
        """Distinct clients queued (the FedBuff trigger counts these, same
        as ``SimEngine.buffer_size(distinct=True)``)."""
        return len({a.client for a in self._q})

    def offer(self, arrival: StreamArrival) -> str:
        """Admit / coalesce / reject one arrival; returns what happened
        (``"admitted" | "coalesced" | "rejected"``)."""
        c = self.counters
        c["offered"] += 1
        if self.policy == "coalesce":
            for i, q in enumerate(self._q):
                if q.client == arrival.client:
                    # in-place replace keeps the old queue position: the
                    # client does not jump the line by re-uploading
                    self._q[i] = arrival
                    c["coalesced"] += 1
                    return "coalesced"
        if len(self._q) >= self.capacity:
            if self.policy == "drop_oldest":
                self._q.popleft()
                c["dropped_oldest"] += 1
            else:
                c["rejected"] += 1
                return "rejected"
        self._q.append(arrival)
        c["admitted"] += 1
        self.max_depth = max(self.max_depth, len(self._q))
        return "admitted"

    def pop_cohort(self, limit: int = 0) -> List[StreamArrival]:
        """Oldest-first drain of up to ``limit`` entries (0 = everything);
        what stays queued waits for the next trigger — that remainder is
        the backpressure signal."""
        n = len(self._q) if limit <= 0 else min(limit, len(self._q))
        out = [self._q.popleft() for _ in range(n)]
        self.counters["popped"] += n
        return out
