"""Long-running streaming aggregation service (``python -m repro.service``).

The drive-a-loop harness (``repro.sim``) builds a world and runs it to a
horizon; this package is the production-shaped complement — a service that
never stops: a persistent ``Server`` (global model, ``VersionStore``,
``WarmStartCache``) plus the GI executor's resident ``LanePool`` behind an
upload-stream frontend with admission control, backpressure and timely
update dissemination. See docs/streaming_service.md.
"""

from repro.service.admission import AdmissionQueue, StreamArrival
from repro.service.runtime import (ServiceConfig, StreamingService,
                                   build_service)
from repro.service.stream import (UploadJob, UploadLog, log_from_scenario,
                                  read_upload_log, synthetic_log)

__all__ = [
    "AdmissionQueue", "StreamArrival", "ServiceConfig", "StreamingService",
    "build_service", "UploadJob", "UploadLog", "log_from_scenario",
    "read_upload_log", "synthetic_log",
]
