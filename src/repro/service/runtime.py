"""The long-running streaming aggregation service.

:class:`StreamingService` wraps a ``core.server.Server`` behind an
upload-stream frontend: a virtual-time event loop consumes an
``UploadLog`` (dispatch → in-flight → arrival), admission control bounds
the stale-upload queue (``admission.AdmissionQueue``), and a trigger rule
(pure-async / FedBuff-K / deadline — the service-side mirrors of
``sim.policies``) decides when the queued cohort flushes through
``Server.step``. Everything warm persists across triggers: the ``Server``
(global model, ``VersionStore``, ``WarmStartCache``) and the GI
executor's resident :class:`~repro.core.gradient_inversion.LanePool` —
the service never reconstructs them, which is the whole point of running
as a service instead of drive-a-loop.

Base-version semantics: a job's base version is the service's global
version at the moment its *dispatch* event is processed. **Timely
dissemination** (``ServiceConfig.disseminate``, after arxiv 2507.06031)
refreshes that choice while the job is still in flight: on each model
advance the service pushes the fresh global to in-flight jobs whose
progress is below ``disseminate_max_progress`` — the job's eventual
upload is then computed from the fresher base (the update-dissemination
rule: the client merges the pushed model into its in-progress training
instead of restarting), so realized staleness drops without delaying the
arrival.

Determinism: for a fixed (log, config) the event order, every admission
decision and every cohort are fully determined — ``digest()`` fingerprints
the event stream exactly like ``sim.engine.trace_digest`` and replaying
the same log through a fused-step server and through the loop-mode oracle
(``FLConfig(fused_step=False)``) yields bit-for-bit identical global
trajectories (pinned by tests/test_service.py).
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.quantize import tree_payload_bytes
from repro.obs import tracer
from repro.service.admission import AdmissionQueue, StreamArrival
from repro.service.stream import UploadLog
from repro.sim.engine import trace_digest

TRIGGERS = ("async", "fedbuff", "deadline")


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    trigger: str = "fedbuff"        # async | fedbuff | deadline
    k: int = 4                      # FedBuff: aggregate at K distinct clients
    round_len: float = 1.0          # deadline: virtual seconds between ticks
    queue_capacity: int = 64        # admission: bounded stale-upload queue
    admission: str = "reject"       # reject | drop_oldest | coalesce
    # cap on uploads drained per trigger (0 = whole queue) — the GI lane
    # budget: arrivals beyond it stay queued, which is where backpressure
    # becomes visible
    max_cohort: int = 0
    # timely update dissemination (arxiv 2507.06031): push the fresh global
    # to in-flight jobs on each model advance
    disseminate: bool = False
    # only jobs less than this far through their training get the push —
    # a nearly-finished job keeps its base (the merge would cost more than
    # the staleness it saves)
    disseminate_max_progress: float = 0.5


@dataclasses.dataclass
class _InFlight:
    client: int
    base_version: int
    dispatch_t: float
    duration: float
    job_id: int
    payload_bytes: int = 0


class StreamingService:
    """Event-loop frontend over a persistent ``Server``. Build it once,
    feed it logs forever — versions, warm state and counters carry over
    every ``run_log`` call."""

    def __init__(self, server, cfg: Optional[ServiceConfig] = None):
        cfg = cfg or ServiceConfig()
        if cfg.trigger not in TRIGGERS:
            raise ValueError(f"unknown trigger {cfg.trigger!r}; "
                             f"have {TRIGGERS}")
        self.server = server
        self.cfg = cfg
        self.queue = AdmissionQueue(cfg.queue_capacity, cfg.admission)
        # Server.__init__ seeded history with version 0; step(t=version)
        # asserts this alignment the same way ServerBridge does
        self.version = len(server.history) - 1
        self.vclock = 0.0
        self._seq = 0
        self._inflight: Dict[int, _InFlight] = {}
        self.counters: Dict[str, int] = {
            "dispatches": 0, "arrivals": 0, "aggregations": 0,
            "empty_triggers": 0, "superseded": 0, "disseminated": 0,
            "payload_bytes": 0}
        # wire size of one upload under the server's quant config (exact
        # packed accounting: bits/8 per coordinate + one f32 scale per
        # tile; plain 4 bytes/coordinate at bits=32) — used for jobs whose
        # log rows carry no explicit payload size
        self._upload_bytes = tree_payload_bytes(server.global_params,
                                                server.cfg.quant)
        # event stream for the determinism digest (same line format as the
        # sim engines' trace)
        self.events: List[Tuple[float, str, int, str]] = []
        # per-trigger wall seconds (trigger decision -> Server.step done)
        # and per-upload virtual queue waits / realized staleness
        self.trigger_walls: List[float] = []
        self.queue_waits: List[float] = []
        self.realized_taus: List[int] = []
        self._wall_spent = 0.0

    # ------------------------------------------------------------------ #
    def _trace(self, t: float, kind: str, client: int, info: str) -> None:
        self.events.append((t, kind, client, info))

    def digest(self) -> str:
        """Fingerprint of the service's event stream — identical digests
        certify identical admission decisions and cohorts."""
        return trace_digest(self.events)

    # ------------------------------------------------------------------ #
    def run_log(self, log: UploadLog) -> Dict[str, Any]:
        """Replay one upload log to completion (virtual time continues from
        wherever the service left off; versions and warm state persist).
        Returns ``summary()``."""
        t_start = time.perf_counter()
        offset = self.vclock
        heap: List[Tuple[float, int, str, Any]] = []
        for job in log:
            self._push(heap, offset + job.dispatch_t, "dispatch", job)
        if self.cfg.trigger == "deadline" and len(log):
            end = offset + log.horizon
            t = offset + self.cfg.round_len
            while t <= end:
                self._push(heap, t, "tick", None)
                t += self.cfg.round_len
        with tracer.span("service.run") as sp:
            sp.arg("jobs", len(log))
            while heap:
                t, _, kind, payload = heapq.heappop(heap)
                self.vclock = t
                if kind == "dispatch":
                    self._on_dispatch(heap, t, payload)
                elif kind == "arrival":
                    self._on_arrival(t, payload)
                else:
                    self._aggregate(t, "deadline")
        self._wall_spent += time.perf_counter() - t_start
        return self.summary()

    def run_for(self, wall_seconds: float, log: UploadLog) -> Dict[str, Any]:
        """Sustained mode: replay ``log`` back to back until ``wall_seconds``
        of wall time have elapsed (the never-stops flavor the CI smoke
        runs). Each pass continues virtual time and the version counter."""
        deadline = time.monotonic() + float(wall_seconds)
        passes = 0
        while True:
            summary = self.run_log(log)
            passes += 1
            if time.monotonic() >= deadline:
                break
        summary["log_passes"] = passes
        return summary

    # ------------------------------------------------------------------ #
    def _push(self, heap, t: float, kind: str, payload) -> None:
        heapq.heappush(heap, (t, self._seq, kind, payload))
        self._seq += 1

    def _on_dispatch(self, heap, t: float, job) -> None:
        fl = _InFlight(job.client, self.version, t, job.duration, job.job_id,
                       payload_bytes=getattr(job, "payload_bytes", 0))
        self._inflight[job.job_id] = fl
        self.counters["dispatches"] += 1
        self._trace(t, "dispatch", job.client, f"v{self.version}")
        self._push(heap, t + job.duration, "arrival", fl)

    def _on_arrival(self, t: float, fl: _InFlight) -> None:
        del self._inflight[fl.job_id]
        self.counters["arrivals"] += 1
        # bytes hit the wire whether or not admission keeps the upload
        self.counters["payload_bytes"] += (fl.payload_bytes
                                           or self._upload_bytes)
        arrival = StreamArrival(fl.client, fl.base_version, fl.dispatch_t,
                                t, fl.job_id)
        action = self.queue.offer(arrival)
        tracer.counter(f"service.{action}")
        self._trace(t, "arrival", fl.client,
                    f"v{fl.base_version} {action} q{len(self.queue)}")
        if action == "rejected":
            return
        cfg = self.cfg
        if cfg.trigger == "async":
            self._aggregate(t, "async")
        elif cfg.trigger == "fedbuff" and self.queue.distinct() >= cfg.k:
            self._aggregate(t, "fedbuff")

    # ------------------------------------------------------------------ #
    def _aggregate(self, now: float, reason: str) -> None:
        cohort = self.queue.pop_cohort(self.cfg.max_cohort)
        if not cohort:
            self.counters["empty_triggers"] += 1
            self._trace(now, "trigger", -1, f"{reason} empty")
            return
        # per-client dedup, freshest base wins — the same rule as
        # SimEngine.aggregate, applied to the drained slice only
        best: Dict[int, StreamArrival] = {}
        for a in cohort:
            b = best.get(a.client)
            if b is None or a.base_version > b.base_version:
                best[a.client] = a
        self.counters["superseded"] += len(cohort) - len(best)
        batch = sorted(best.values(), key=lambda a: a.client)
        fresh = [a.client for a in batch if a.base_version == self.version]
        stale = [(a.client, a.base_version) for a in batch
                 if a.base_version < self.version]
        t0 = time.perf_counter()
        with tracer.span("service.aggregate") as sp:
            sp.arg("reason", reason)
            sp.arg("version", self.version)
            sp.arg("n_fresh", len(fresh))
            sp.arg("n_stale", len(stale))
            self.server.step(self.version, fresh, stale, eval_now=False)
        wall = time.perf_counter() - t0
        self.version += 1
        self.counters["aggregations"] += 1
        self.trigger_walls.append(wall)
        for a in batch:
            self.queue_waits.append(now - a.arrival_t)
            self.realized_taus.append(self.version - 1 - a.base_version)
        self._trace(now, "aggregate", -1,
                    f"v{self.version} f{len(fresh)} s{len(stale)} {reason}")
        if tracer.enabled:
            tracer.metric("service_trigger", reason=reason,
                          version=self.version, n_fresh=len(fresh),
                          n_stale=len(stale), wall_s=wall,
                          queue_depth=len(self.queue),
                          vclock=now)
        if self.cfg.disseminate:
            self._disseminate(now)

    def _disseminate(self, now: float) -> None:
        """Timely update dissemination (arxiv 2507.06031): on a model
        advance, push the fresh global to in-flight jobs early enough in
        their training that merging it is worth it — their eventual upload
        then counts from the new base, so realized staleness drops."""
        pushed = 0
        with tracer.span("service.disseminate") as sp:
            for fl in self._inflight.values():
                if fl.base_version >= self.version:
                    continue
                prog = ((now - fl.dispatch_t) / fl.duration
                        if fl.duration > 0 else 1.0)
                if prog < self.cfg.disseminate_max_progress:
                    fl.base_version = self.version
                    pushed += 1
            sp.arg("pushed", pushed)
        if pushed:
            self.counters["disseminated"] += pushed
            tracer.counter("service.disseminated", pushed)
            self._trace(now, "disseminate", -1,
                        f"v{self.version} n{pushed}")

    # ------------------------------------------------------------------ #
    def flush(self) -> None:
        """Force-aggregate whatever is queued (drains in ``max_cohort``
        slices until empty)."""
        while len(self.queue):
            self._aggregate(self.vclock, "flush")

    def summary(self) -> Dict[str, Any]:
        walls = np.asarray(self.trigger_walls or [0.0])
        waits = np.asarray(self.queue_waits or [0.0])
        taus = np.asarray(self.realized_taus or [0], np.int64)
        wall = self._wall_spent
        out: Dict[str, Any] = {
            "version": self.version,
            "vclock": self.vclock,
            "wall_s": wall,
            "uploads_per_sec": (self.counters["arrivals"] / wall
                                if wall > 0 else 0.0),
            "bytes_per_sec": (self.counters["payload_bytes"] / wall
                              if wall > 0 else 0.0),
            "bytes_per_upload": (self.counters["payload_bytes"]
                                 / self.counters["arrivals"]
                                 if self.counters["arrivals"] else 0.0),
            "trigger_wall_p50_ms": float(np.percentile(walls, 50) * 1e3),
            "trigger_wall_p99_ms": float(np.percentile(walls, 99) * 1e3),
            "trigger_wall_mean_ms": float(walls.mean() * 1e3),
            "queue_wait_p50": float(np.percentile(waits, 50)),
            "queue_wait_p99": float(np.percentile(waits, 99)),
            "queue_depth": len(self.queue),
            "queue_depth_max": self.queue.max_depth,
            "realized_tau_mean": float(taus.mean()),
            "realized_tau_max": int(taus.max()),
            "digest": self.digest(),
        }
        out.update(self.counters)
        out.update({k: v for k, v in self.queue.counters.items()})
        return out


# --------------------------------------------------------------------------- #
# Builder
# --------------------------------------------------------------------------- #


def build_service(seed: int = 0, strategy: str = "ours",
                  n_clients: int = 10, n_slow: int = 3, gi_iters: int = 6,
                  segment_iters: int = 3, max_lanes: int = 8,
                  fused_step: bool = True, mesh=None,
                  quant_bits: int = 32,
                  cfg: Optional[ServiceConfig] = None) -> StreamingService:
    """A ready service over the stock small-scale FL setup
    (``sim.scenarios.fl_setup``). ``segment_iters > 0`` (the default)
    selects the segmented GI executor so triggers share the resident
    ``LanePool``; ``fused_step=False`` builds the loop-mode oracle the
    bit-for-bit replay tests compare against. ``quant_bits`` (32/8/4)
    selects the upload wire format (docs/compression.md) — the event
    stream and digest are invariant to it; only the model trajectory and
    the bytes-on-wire counters change."""
    from repro.sim.scenarios import fl_setup

    server, _, _ = fl_setup(seed, strategy=strategy, n_clients=n_clients,
                            n_slow=n_slow, gi_iters=gi_iters,
                            eval_every=10 ** 9, mesh=mesh,
                            segment_iters=segment_iters,
                            max_lanes=max_lanes, fused_step=fused_step,
                            quant_bits=quant_bits)
    return StreamingService(server, cfg)
