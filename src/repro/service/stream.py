"""Upload streams: the replayable arrival process the service consumes.

An :class:`UploadJob` is one client training job — dispatched at
``dispatch_t`` (virtual seconds), its update arriving ``duration`` later.
A log deliberately does NOT record base versions: which global version a
job trained from is decided at replay time by the service (the version
current when the dispatch event is processed, possibly refreshed by timely
dissemination). That is what makes one log replayable under different
trigger / admission / dissemination configurations while staying fully
deterministic for a fixed configuration — the determinism contract the
soak tests pin with :func:`UploadLog.digest`.

Three ways to obtain a log:

* :func:`synthetic_log` — open-loop per-client job chains from
  ``sim.devices.LatencyDist`` latency models (a slow tier for staleness),
  counter-seeded so each client's chain is independent of the others;
* :func:`log_from_scenario` — record the arrival process of a stock
  ``sim.scenarios`` scenario by running its fleet + trigger policy on the
  event engine (``VecEngine`` by default — heap and vec traces are pinned
  identical, so either engine yields the same log);
* :func:`read_upload_log` — replay a JSONL file written by
  :func:`UploadLog.write_jsonl` (schema ``upload-log-v1``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.sim.devices import LatencyDist

SCHEMA = "upload-log-v1"


@dataclasses.dataclass(frozen=True)
class UploadJob:
    """One client job: dispatched at ``dispatch_t``, arrives ``duration``
    later. ``job_id`` is the log-order index (assigned by UploadLog).
    ``payload_bytes`` optionally records the wire size of the upload
    (0 = unknown: the service falls back to the model-derived size for its
    bytes-on-wire counters). Payload size is carried in the log but kept
    OUT of :func:`UploadLog.digest` — replay identity is about the arrival
    process, and recompressing a log must not change which aggregations
    fire."""
    client: int
    dispatch_t: float
    duration: float
    job_id: int = 0
    payload_bytes: int = 0

    @property
    def arrival_t(self) -> float:
        return self.dispatch_t + self.duration


class UploadLog:
    """An ordered, replayable stream of :class:`UploadJob`."""

    def __init__(self, jobs: Iterable[UploadJob], n_clients: int,
                 meta: Optional[Dict[str, Any]] = None):
        ordered = sorted(jobs, key=lambda j: (j.dispatch_t, j.client))
        self.jobs: List[UploadJob] = [
            dataclasses.replace(j, job_id=i) for i, j in enumerate(ordered)]
        self.n_clients = int(n_clients)
        self.meta = dict(meta or {})

    def __len__(self) -> int:
        return len(self.jobs)

    def __iter__(self):
        return iter(self.jobs)

    @property
    def horizon(self) -> float:
        """Virtual time of the last arrival (0.0 for an empty log)."""
        return max((j.arrival_t for j in self.jobs), default=0.0)

    def digest(self) -> str:
        """Content fingerprint (16 hex chars): identical digests mean the
        service will see an identical arrival process."""
        lines = "\n".join(f"{j.client}|{j.dispatch_t:.9f}|{j.duration:.9f}"
                          for j in self.jobs)
        return hashlib.sha256(lines.encode()).hexdigest()[:16]

    # ------------------------------------------------------------------ #
    # JSONL round-trip
    # ------------------------------------------------------------------ #
    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(json.dumps({"schema": SCHEMA,
                                "n_clients": self.n_clients,
                                "meta": self.meta}) + "\n")
            for j in self.jobs:
                row = {"c": j.client, "t": j.dispatch_t, "d": j.duration}
                if j.payload_bytes:
                    # only written when known, so logs from builds that
                    # never set it stay byte-identical
                    row["b"] = j.payload_bytes
                f.write(json.dumps(row) + "\n")


def read_upload_log(path: str) -> UploadLog:
    with open(path) as f:
        header = json.loads(f.readline())
        if header.get("schema") != SCHEMA:
            raise ValueError(f"{path}: not an {SCHEMA} document")
        jobs = [UploadJob(int(r["c"]), float(r["t"]), float(r["d"]),
                          payload_bytes=int(r.get("b", 0)))
                for r in map(json.loads, f) if r]
    return UploadLog(jobs, header["n_clients"], header.get("meta"))


# --------------------------------------------------------------------------- #
# Generators
# --------------------------------------------------------------------------- #


def synthetic_log(n_clients: int = 10, horizon: float = 8.0, seed: int = 0,
                  slow_ids: Sequence[int] = (),
                  fast: Optional[LatencyDist] = None,
                  slow: Optional[LatencyDist] = None) -> UploadLog:
    """Open-loop job chains: each client trains back-to-back until
    ``horizon`` (jobs whose arrival would land beyond it are cut). Each
    chain draws from a per-client ``default_rng((seed, client))`` stream,
    so one client's latencies never depend on another's — adding a client
    or changing a tier perturbs only that chain."""
    fast = fast or LatencyDist("lognormal", 0.4, 0.3)
    slow = slow or LatencyDist("lognormal", 2.5, 0.4)
    slow_set = set(int(c) for c in slow_ids)
    jobs: List[UploadJob] = []
    for c in range(n_clients):
        dist = slow if c in slow_set else fast
        rng = np.random.default_rng((seed, c))
        t = 0.0
        while True:
            d = float(dist.sample(rng))
            if t + d > horizon:
                break
            jobs.append(UploadJob(c, t, d))
            t += d
    return UploadLog(jobs, n_clients,
                     meta={"source": "synthetic", "seed": seed,
                           "horizon": horizon,
                           "slow_ids": sorted(slow_set)})


class _RecordingPolicy:
    """Wraps a scenario's trigger policy, recording every delivered
    ``Arrival``. Per-event hooks delegate to the inner policy; the passive
    flags are cleared so both engines call ``on_upload`` per arrival (the
    vectorized engine's batched and per-event replays are pinned
    trace-identical, so clearing the flags never changes the event
    process)."""
    passive_uploads = False
    passive_rejoins = False
    uploads_noop = False

    def __init__(self, inner, out: List):
        self.inner = inner
        self.out = out
        self.name = inner.name

    def start(self, eng) -> None:
        self.inner.start(eng)

    def on_resume(self, eng) -> None:
        self.inner.on_resume(eng)

    def on_upload(self, eng, arrival) -> None:
        self.out.append(arrival)
        self.inner.on_upload(eng, arrival)

    def on_timer(self, eng, payload) -> None:
        self.inner.on_timer(eng, payload)

    def on_rejoin(self, eng, client: int) -> None:
        self.inner.on_rejoin(eng, client)


def log_from_scenario(name: str, seed: int = 0,
                      horizon: Optional[float] = None,
                      engine: str = "vec") -> UploadLog:
    """Record a stock scenario's realized arrival process as a replayable
    log: its fleet + trigger policy run on the event engine with a
    recording shim, and every delivered upload becomes an
    :class:`UploadJob`. Doomed (dropped) jobs never arrive and are absent
    by construction."""
    from repro.sim import scenarios

    arrivals: List = []
    eng = scenarios.engine_only(
        name, seed=seed, horizon=horizon, engine=engine,
        policy_wrap=lambda p: _RecordingPolicy(p, arrivals))
    eng.run()
    jobs = [UploadJob(a.client, a.dispatch_time,
                      a.arrival_time - a.dispatch_time)
            for a in arrivals]
    return UploadLog(jobs, len(eng.fleet),
                     meta={"source": f"scenario:{name}", "seed": seed,
                           "engine": engine, "horizon": float(eng.horizon)})
