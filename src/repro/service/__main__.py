"""CLI for the streaming aggregation service.

    PYTHONPATH=src python -m repro.service --horizon 6
    PYTHONPATH=src python -m repro.service --trigger async --admission coalesce
    PYTHONPATH=src python -m repro.service --scenario-log fedbuff_k4 \
        --log-out /tmp/uploads.jsonl --trace /tmp/service.json
    PYTHONPATH=src python -m repro.service --log-in /tmp/uploads.jsonl \
        --min-wall 30

Prints one JSON summary: sustained uploads/sec, p50/p99
trigger-to-aggregate wall latency, queue depth / admission counters,
realized staleness and the event-stream digest (the replay fingerprint:
same log + config => same digest). ``--min-wall`` keeps replaying the log
back to back until that many wall seconds have elapsed — the sustained
mode the CI smoke runs.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.service.admission import POLICIES
from repro.service.runtime import (TRIGGERS, ServiceConfig, StreamingService,
                                   build_service)
from repro.service.stream import (log_from_scenario, read_upload_log,
                                  synthetic_log)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.service")
    # upload stream: a file, a recorded scenario, or synthetic chains
    ap.add_argument("--log-in", default=None, metavar="PATH",
                    help="replay an upload-log-v1 JSONL file")
    ap.add_argument("--scenario-log", default=None, metavar="NAME",
                    help="record NAME's arrival process (repro.sim "
                         "scenario) as the upload stream")
    ap.add_argument("--log-out", default=None, metavar="PATH",
                    help="also write the upload log used (JSONL)")
    ap.add_argument("--horizon", type=float, default=8.0,
                    help="synthetic/scenario log length in virtual seconds")
    ap.add_argument("--n-clients", type=int, default=10)
    ap.add_argument("--n-slow", type=int, default=3,
                    help="clients on the slow latency tier (synthetic log)")
    ap.add_argument("--seed", type=int, default=0)
    # FL server
    ap.add_argument("--strategy", default="ours")
    ap.add_argument("--gi-iters", type=int, default=6)
    ap.add_argument("--segment-iters", type=int, default=3,
                    help="segmented GI executor segment length (0 = "
                         "one-shot engine, no LanePool)")
    ap.add_argument("--max-lanes", type=int, default=8)
    ap.add_argument("--loop-oracle", action="store_true",
                    help="FLConfig(fused_step=False): the per-client loop "
                         "path (bit-for-bit oracle for a replayed log)")
    ap.add_argument("--mesh", type=int, default=None,
                    help="shard the server hot path over the first N devices")
    # service
    ap.add_argument("--trigger", choices=TRIGGERS, default="fedbuff")
    ap.add_argument("--k", type=int, default=4,
                    help="FedBuff trigger threshold (distinct clients)")
    ap.add_argument("--round-len", type=float, default=1.0,
                    help="deadline trigger period (virtual seconds)")
    ap.add_argument("--queue-capacity", type=int, default=64)
    ap.add_argument("--admission", choices=POLICIES, default="reject")
    ap.add_argument("--max-cohort", type=int, default=8,
                    help="uploads drained per trigger (0 = whole queue)")
    ap.add_argument("--disseminate", action="store_true",
                    help="timely update dissemination (arxiv 2507.06031)")
    ap.add_argument("--min-wall", type=float, default=None, metavar="SECONDS",
                    help="keep replaying the log until this much wall time "
                         "has elapsed (sustained mode)")
    ap.add_argument("--flush", action="store_true",
                    help="force-aggregate the queue remainder at the end")
    ap.add_argument("--eval-final", action="store_true",
                    help="evaluate the final global model (adds final_acc)")
    ap.add_argument("--out", default=None, help="also write JSON here")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable tracing; write a Chrome trace-event JSON "
                         "(open in https://ui.perfetto.dev)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable tracing; write the obs-metrics-v1 JSONL "
                         "stream (input to python -m repro.obs.report)")
    args = ap.parse_args(argv)

    tracing = args.trace is not None or args.metrics is not None
    if tracing:
        from repro import obs
        obs.configure(enabled=True, reset=True)

    if args.log_in:
        log = read_upload_log(args.log_in)
    elif args.scenario_log:
        log = log_from_scenario(args.scenario_log, seed=args.seed,
                                horizon=args.horizon)
    else:
        log = synthetic_log(n_clients=args.n_clients, horizon=args.horizon,
                            seed=args.seed,
                            slow_ids=range(args.n_slow))
    if args.log_out:
        log.write_jsonl(args.log_out)
        print(f"wrote {args.log_out} ({len(log)} jobs, "
              f"digest {log.digest()})", file=sys.stderr)

    mesh = None
    if args.mesh is not None:
        from repro.launch.mesh import make_server_mesh
        mesh = make_server_mesh(args.mesh)
    cfg = ServiceConfig(trigger=args.trigger, k=args.k,
                        round_len=args.round_len,
                        queue_capacity=args.queue_capacity,
                        admission=args.admission,
                        max_cohort=args.max_cohort,
                        disseminate=args.disseminate)
    svc = build_service(seed=args.seed, strategy=args.strategy,
                        n_clients=log.n_clients, gi_iters=args.gi_iters,
                        segment_iters=args.segment_iters,
                        max_lanes=args.max_lanes,
                        fused_step=not args.loop_oracle, mesh=mesh, cfg=cfg)
    if args.min_wall is not None:
        summary = svc.run_for(args.min_wall, log)
    else:
        summary = svc.run_log(log)
    if args.flush:
        svc.flush()
        summary = svc.summary()
    summary["log_digest"] = log.digest()
    summary["log_jobs"] = len(log)
    summary["pool_stats"] = dict(svc.server.inverter.pool.stats)
    if args.eval_final:
        summary["final_acc"] = float(svc.server.evaluate()[0])
    text = json.dumps(summary, indent=2, default=float)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if tracing:
        from repro import obs
        if args.trace:
            n = obs.write_chrome_trace(
                obs.tracer, args.trace,
                label=f"repro.service {args.trigger} seed{args.seed}")
            print(f"wrote {args.trace} ({n} trace events)", file=sys.stderr)
        if args.metrics:
            n = obs.write_jsonl(obs.tracer.metrics, args.metrics)
            print(f"wrote {args.metrics} ({n} metric rows)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
