"""Unified telemetry layer: spans, counters, and staleness metrics.

Usage at an instrumentation site (hot paths bind the singleton once)::

    from repro.obs import tracer

    with tracer.span("step.gi", args={"batch": B}) as sp:
        out = invert(...)
        sp.fence(out)            # span covers the dispatched device work
    if tracer.enabled:
        tracer.metric("gi_exec", batch=B, occupancy=occ)

Enabling/exporting (CLIs, benchmarks, tests)::

    from repro import obs
    obs.configure(enabled=True, reset=True)
    ... run workload ...
    obs.write_chrome_trace(obs.tracer, "trace.json")   # open in Perfetto
    obs.write_jsonl(obs.tracer.metrics, "metrics.jsonl")

Disabled (the default) is a true no-op: ``tracer.span`` returns a shared
singleton and ``metric``/``counter`` return immediately, so instrumented
code paths stay bit-for-bit identical and allocation-free. See
``docs/observability.md`` for the span taxonomy and metrics schema.
"""

from .export import chrome_trace, write_chrome_trace
from .metrics import SCHEMA, read_rows, rows_of_kind, write_jsonl
from .tracer import NOOP_SPAN, Tracer, configure, tracer

__all__ = [
    "Tracer", "tracer", "configure", "NOOP_SPAN",
    "SCHEMA", "write_jsonl", "read_rows", "rows_of_kind",
    "chrome_trace", "write_chrome_trace",
]
