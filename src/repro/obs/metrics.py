"""The unified JSONL metrics schema (``obs-metrics-v1``).

One row = one JSON object with a ``kind`` discriminator. This schema
replaces the two ad-hoc wall-row formats that used to live in
``sim/bridge.py`` (``ServerBridge.rows``) and ``repro.sweep``: both emit
and consume these rows. (The transitional ``step_walls`` trajectory alias
shipped for exactly one release, as promised, and is gone — trajectory
JSONs carry their kind-tagged rows under ``metrics``.)

Row kinds (producers in parentheses; every kind may carry extra fields —
readers must ignore unknown keys):

``server_step`` (``ServerBridge.aggregate``)
    ``version, n_fresh, n_stale, n_base_rounds, wall_s, gi_iters,
    gi_occupancy`` — per-aggregation server hot-path cost — plus
    ``spans``: the span-name → seconds breakdown of that step when the
    tracer was enabled (where the wall time went: fresh/stale update, GI,
    stacked FedAvg, eval).
``aggregation`` (sim engines)
    Cohort composition as the *engine* saw it: ``time, version, n_fresh,
    n_stale, n_base_rounds, mean_tau, tau_hist`` (realized-staleness
    histogram: ``tau_hist[t]`` = number of stale updates with realized
    staleness ``t``; index 0 counts fresh).
``gi_exec`` (``core.gradient_inversion``)
    Per-invocation executor telemetry: ``engine`` (oneshot|segmented),
    ``batch, padded_to, occupancy, iters_mean/min/max, segments,
    final_loss_mean/max`` (disparity proxies).
``compensation`` (``core.compensation`` / ``Server``)
    Per-strategy mixing weights: ``strategy`` plus e.g. ``alpha_mean``
    for staleness weighting or ``gamma`` for the ours-blend.
``wave`` (vectorized engine)
    Per-wave dispatch/upload batch sizes: ``wave`` (dispatch|upload),
    ``time, n``.

Trajectory JSONs (``repro.sweep``) load via ``read_rows`` too: their
``metrics`` list is already kind-tagged, and the per-round
``server_metrics`` list (accuracy/gamma rows without a ``kind``) is
tagged ``server_metric`` on the way in — see ``_normalize_trajectory``.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List

SCHEMA = "obs-metrics-v1"

__all__ = ["SCHEMA", "write_jsonl", "read_rows", "rows_of_kind"]


def write_jsonl(rows: Iterable[Dict[str, Any]], path: str) -> int:
    """Write metric rows as JSONL, one object per line, preceded by a
    schema header line. Returns the number of data rows written."""
    n = 0
    with open(path, "w") as f:
        f.write(json.dumps({"schema": SCHEMA}) + "\n")
        for row in rows:
            f.write(json.dumps(row, default=float) + "\n")
            n += 1
    return n


def _normalize_trajectory(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Rows from a trajectory JSON: the kind-tagged ``metrics`` list plus
    the per-round ``server_metrics`` rows tagged ``server_metric``."""
    rows: List[Dict[str, Any]] = [dict(r) for r in doc.get("metrics") or []]
    for r in doc.get("server_metrics", []) or []:
        row = dict(r)
        row.setdefault("kind", "server_metric")
        rows.append(row)
    return rows


def read_rows(path: str) -> List[Dict[str, Any]]:
    """Load metric rows from any supported container:

    * ``*.jsonl`` — the canonical stream (schema header line optional);
    * a JSON object with a ``metrics`` or ``rows`` list of kind-tagged rows;
    * a trajectory JSON (``metrics`` + per-round ``server_metrics`` keys).
    """
    if path.endswith(".jsonl"):
        rows = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                obj = json.loads(line)
                if set(obj.keys()) == {"schema"}:
                    continue
                rows.append(obj)
        return rows
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, list):
        return doc
    if "server_metrics" in doc:
        return _normalize_trajectory(doc)
    for key in ("metrics", "rows"):
        if isinstance(doc.get(key), list):
            return doc[key]
    raise ValueError(f"{os.path.basename(path)}: no metric rows found "
                     f"(expected .jsonl, a metrics/rows list, or a "
                     f"trajectory JSON)")


def rows_of_kind(rows: Iterable[Dict[str, Any]], kind: str
                 ) -> List[Dict[str, Any]]:
    return [r for r in rows if r.get("kind") == kind]
