"""Render a per-round time/staleness breakdown from recorded telemetry.

    PYTHONPATH=src python -m repro.obs.report trace.json
    PYTHONPATH=src python -m repro.obs.report metrics.jsonl
    PYTHONPATH=src python -m repro.obs.report sweep_out/trajectory_*.json

Accepts any artifact the obs layer (or its predecessors) writes: a Chrome
trace exported by ``repro.obs.export``, an ``obs-metrics-v1`` JSONL
stream, or a ``repro.sweep`` trajectory JSON. Prints one row
per aggregation round — wall time, cohort composition (fresh/stale split,
base-round scatter), realized staleness, GI occupancy — followed by the
span-time breakdown and counters when the source carries spans.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Tuple

from . import metrics as obs_metrics

__all__ = ["load_any", "per_round_table", "render", "main"]


def _is_chrome_trace(doc: Any) -> bool:
    return isinstance(doc, dict) and "traceEvents" in doc


def _from_chrome(doc: Dict[str, Any]) -> Tuple[List[Dict[str, Any]],
                                               Dict[str, float],
                                               Dict[str, float]]:
    """(metric_rows, span_totals_s, counters) out of a trace document."""
    rows: List[Dict[str, Any]] = []
    span_totals: Dict[str, float] = {}
    for ev in doc.get("traceEvents", []):
        ph = ev.get("ph")
        if ph == "i":
            row = dict(ev.get("args") or {})
            row["kind"] = ev.get("name", "metric")
            row["ts_s"] = float(ev.get("ts", 0.0)) / 1e6
            rows.append(row)
        elif ph == "X":
            name = ev.get("name", "?")
            span_totals[name] = (span_totals.get(name, 0.0)
                                 + float(ev.get("dur", 0.0)) / 1e6)
    counters = (doc.get("otherData") or {}).get("counters") or {}
    return rows, span_totals, counters


def load_any(path: str) -> Tuple[List[Dict[str, Any]], Dict[str, float],
                                 Dict[str, float]]:
    """Load (metric_rows, span_totals_s, counters) from any supported
    artifact; span info is empty for metrics-only sources."""
    if path.endswith(".jsonl"):
        return obs_metrics.read_rows(path), {}, {}
    with open(path) as f:
        doc = json.load(f)
    if _is_chrome_trace(doc):
        return _from_chrome(doc)
    if isinstance(doc, dict) and "server_metrics" in doc:
        return obs_metrics._normalize_trajectory(doc), {}, {}
    if isinstance(doc, dict):
        for key in ("metrics", "rows"):
            if isinstance(doc.get(key), list):
                return doc[key], {}, {}
    if isinstance(doc, list):
        return doc, {}, {}
    raise ValueError(f"{path}: unrecognized telemetry artifact")


def _mean_tau(row: Dict[str, Any]) -> Optional[float]:
    if row.get("mean_tau") is not None:
        return float(row["mean_tau"])
    hist = row.get("tau_hist")
    if hist:
        total = sum(hist)
        if total:
            return sum(t * n for t, n in enumerate(hist)) / total
    return None


def per_round_table(rows: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Join ``server_step`` and engine ``aggregation`` rows per round."""
    by_version: Dict[int, Dict[str, Any]] = {}
    order: List[int] = []

    def slot(v: int) -> Dict[str, Any]:
        if v not in by_version:
            by_version[v] = {"round": v}
            order.append(v)
        return by_version[v]

    for row in rows:
        kind = row.get("kind")
        if kind == "server_step" and row.get("version") is not None:
            s = slot(int(row["version"]))
            for key in ("n_fresh", "n_stale", "n_base_rounds", "wall_s",
                        "gi_iters", "gi_occupancy", "spans"):
                if row.get(key) is not None:
                    s[key] = row[key]
        elif kind == "aggregation" and row.get("version") is not None:
            s = slot(int(row["version"]))
            s.setdefault("n_fresh", row.get("n_fresh"))
            s.setdefault("n_stale", row.get("n_stale"))
            s.setdefault("n_base_rounds", row.get("n_base_rounds"))
            mt = _mean_tau(row)
            if mt is not None:
                s["mean_tau"] = mt
            if row.get("time") is not None:
                s["time"] = row["time"]
    return [by_version[v] for v in order]


def _fmt(val, spec: str, width: int) -> str:
    if val is None:
        return "-".rjust(width)
    try:
        return format(val, spec).rjust(width)
    except (TypeError, ValueError):
        return str(val).rjust(width)


def render(rows: List[Dict[str, Any]], span_totals: Dict[str, float],
           counters: Dict[str, float], out=None) -> None:
    out = out or sys.stdout
    table = per_round_table(rows)
    if table:
        hdr = (f"{'round':>5} {'wall_ms':>8} {'fresh':>5} {'stale':>5} "
               f"{'bases':>5} {'mean_tau':>8} {'gi_iters':>8} {'gi_occ':>6}")
        print(hdr, file=out)
        print("-" * len(hdr), file=out)
        for r in table:
            wall_ms = (r["wall_s"] * 1e3) if r.get("wall_s") is not None \
                else None
            print(f"{_fmt(r.get('round'), 'd', 5)} "
                  f"{_fmt(wall_ms, '.1f', 8)} "
                  f"{_fmt(r.get('n_fresh'), 'd', 5)} "
                  f"{_fmt(r.get('n_stale'), 'd', 5)} "
                  f"{_fmt(r.get('n_base_rounds'), 'd', 5)} "
                  f"{_fmt(r.get('mean_tau'), '.2f', 8)} "
                  f"{_fmt(r.get('gi_iters'), 'd', 8)} "
                  f"{_fmt(r.get('gi_occupancy'), '.2f', 6)}", file=out)
        # per-round span breakdown when server_step rows carried one
        spanned = [r for r in table if r.get("spans")]
        if spanned:
            names = sorted({n for r in spanned for n in r["spans"]})
            print(f"\nper-round span breakdown (ms):", file=out)
            print(f"{'round':>5} " + " ".join(f"{n:>18}" for n in names),
                  file=out)
            for r in spanned:
                cells = " ".join(
                    _fmt(r["spans"].get(n, 0.0) * 1e3, ".1f", 18)
                    for n in names)
                print(f"{_fmt(r.get('round'), 'd', 5)} {cells}", file=out)
    else:
        print("no per-round rows (source has no server_step/aggregation "
              "metrics)", file=out)

    gi = obs_metrics.rows_of_kind(rows, "gi_exec")
    if gi:
        occ = [r.get("occupancy") for r in gi if r.get("occupancy")
               is not None]
        segs = sum(int(r.get("segments") or 0) for r in gi)
        print(f"\ngi executor: {len(gi)} invocation(s), "
              f"{segs} segment(s)"
              + (f", mean occupancy "
                 f"{sum(occ) / len(occ):.2f}" if occ else ""), file=out)
    waves = obs_metrics.rows_of_kind(rows, "wave")
    if waves:
        n_disp = sum(int(r.get("n") or 0) for r in waves
                     if r.get("wave") == "dispatch")
        n_up = sum(int(r.get("n") or 0) for r in waves
                   if r.get("wave") == "upload")
        print(f"engine waves: {len(waves)} wave(s), "
              f"{n_disp} dispatches, {n_up} uploads", file=out)
    if span_totals:
        print("\nspan totals:", file=out)
        for name, secs in sorted(span_totals.items(),
                                 key=lambda kv: -kv[1]):
            print(f"  {name:<24} {secs * 1e3:10.1f} ms", file=out)
    if counters:
        print("\ncounters:", file=out)
        for name, val in sorted(counters.items()):
            print(f"  {name:<24} {val:g}", file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="per-round time/staleness breakdown from a Chrome "
                    "trace, obs-metrics-v1 JSONL, or trajectory JSON")
    ap.add_argument("paths", nargs="+", help="telemetry artifact(s)")
    args = ap.parse_args(argv)
    status = 0
    for path in args.paths:
        if len(args.paths) > 1:
            print(f"== {path} ==")
        try:
            rows, span_totals, counters = load_any(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"error: {e}", file=sys.stderr)
            status = 2
            continue
        render(rows, span_totals, counters)
    return status


if __name__ == "__main__":
    sys.exit(main())
