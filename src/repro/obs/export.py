"""Chrome trace-event exporter: ``Tracer`` spans -> Perfetto-loadable JSON.

Emits the Trace Event Format's JSON-object flavor::

    {"traceEvents": [...], "otherData": {...}}

* every closed span becomes one complete ("X") event with ``ts``/``dur``
  in microseconds, named args, and a ``compiles`` arg whenever XLA backend
  compiles happened inside it (so compile-paying rounds stand out);
* metric rows become instant ("i") events on a second track so cohort
  composition / GI occupancy line up against the span timeline;
* counters land in ``otherData`` (totals, not samples).

Open the file in https://ui.perfetto.dev or chrome://tracing. Nesting
renders from the timestamps alone — Perfetto stacks overlapping same-track
slices — so the recorded ``parent`` column is exported as an arg only.
"""

from __future__ import annotations

import json
from typing import Any, Dict

from .tracer import Tracer

__all__ = ["chrome_trace", "write_chrome_trace"]

_PID = 1
_TID_SPANS = 1
_TID_METRICS = 2


def chrome_trace(tracer: Tracer, label: str = "repro") -> Dict[str, Any]:
    """Build the trace document (pure; no I/O)."""
    events = [
        {"ph": "M", "pid": _PID, "name": "process_name",
         "args": {"name": label}},
        {"ph": "M", "pid": _PID, "tid": _TID_SPANS, "name": "thread_name",
         "args": {"name": "spans"}},
        {"ph": "M", "pid": _PID, "tid": _TID_METRICS, "name": "thread_name",
         "args": {"name": "metrics"}},
    ]
    for i, sp in enumerate(tracer.spans()):
        if sp["dur_ns"] < 0:        # never closed (aborted run): skip
            continue
        args = dict(sp["args"] or {})
        args["parent"] = sp["parent"]
        if sp["compiles"]:
            args["compiles"] = sp["compiles"]
        events.append({"ph": "X", "pid": _PID, "tid": _TID_SPANS,
                       "name": sp["name"],
                       "ts": sp["start_ns"] / 1e3,
                       "dur": max(sp["dur_ns"] / 1e3, 0.001),
                       "args": args})
    for row in tracer.metrics:
        ts_us = float(row.get("ts_s", 0.0)) * 1e6
        events.append({"ph": "i", "pid": _PID, "tid": _TID_METRICS,
                       "name": row.get("kind", "metric"), "s": "t",
                       "ts": ts_us,
                       "args": {k: v for k, v in row.items()
                                if k not in ("kind", "ts_s")}})
    return {"traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"counters": dict(tracer.counters),
                          "n_spans": len(tracer)}}


def write_chrome_trace(tracer: Tracer, path: str, label: str = "repro"
                       ) -> int:
    """Write the trace JSON; returns the number of events written."""
    doc = chrome_trace(tracer, label=label)
    with open(path, "w") as f:
        json.dump(doc, f, default=float)
    return len(doc["traceEvents"])
