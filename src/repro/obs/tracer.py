"""Low-overhead tracing/metrics core (the unified telemetry layer).

One process-wide ``Tracer`` (``repro.obs.tracer``) is threaded through every
hot layer — sim engines, ``Server.step``, the GI executor, compensation —
and records three kinds of telemetry:

* **spans** — nestable wall-time intervals stored as monotonically-growing
  struct-of-arrays columns (``name_id`` / ``start_ns`` / ``dur_ns`` /
  ``parent`` / ``compiles``; names interned to ids) — the same SoA ethos as
  ``sim/engine_vec.py``: no per-span dict, no per-span object retained.
  Exported as Chrome trace events (``repro.obs.export``) loadable in
  Perfetto / chrome://tracing.
* **counters** — monotonically-growing named totals (``tracer.counter``),
  e.g. per-wave dispatch/upload counts from the vectorized engine and the
  jit compile accounting below.
* **metric rows** — structured dict records (``tracer.metric``) forming the
  JSONL metrics stream (``repro.obs.metrics``): per-aggregation cohort
  composition, realized-staleness histograms, GI executor occupancy,
  compensation mixing weights. One schema shared by ``sim/bridge.py`` and
  ``repro.sweep``.

**Disabled is a true no-op.** ``tracer.span(name)`` on a disabled tracer
returns one preallocated singleton whose ``__enter__``/``__exit__``/
``fence`` do nothing — no allocation, no clock read, no dict; ``counter``
and ``metric`` return immediately. The neutrality contract (identical trace
digests and bit-for-bit trajectories with tracing on or off) holds because
every record is read-only and the only side effect — ``fence`` — is a
``jax.block_until_ready`` wait that cannot change values.

**JAX-awareness.** Spans accept an explicit fence (``sp.fence(x)``) so the
recorded duration covers the device work a dispatch launched, not just the
Python dispatch itself; and a ``jax.monitoring`` duration listener counts
backend compiles (``jit_compiles`` / ``jit_compile_s`` counters, per-span
``compiles`` column), so a trace distinguishes a round that paid an XLA
compile from one that ran entirely from the jit cache
(``spans_with_compile`` vs ``spans_cache_hit`` counters).
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = ["Tracer", "tracer", "configure", "NOOP_SPAN"]


class _Col:
    """Append-only growable column (amortized doubling) — SoA building
    block shared with the vectorized engine's ``_Grow``."""

    __slots__ = ("a", "n")

    def __init__(self, dtype, cap: int = 256):
        self.a = np.empty(cap, dtype)
        self.n = 0

    def push(self, val) -> int:
        i = self.n
        if i == len(self.a):
            grown = np.empty(2 * len(self.a), self.a.dtype)
            grown[:i] = self.a
            self.a = grown
        self.a[i] = val
        self.n = i + 1
        return i

    def view(self) -> np.ndarray:
        return self.a[:self.n]


class _NoopSpan:
    """The disabled fast path: one shared instance, zero work."""

    __slots__ = ()

    def __enter__(self):
        return self

    # explicit 3-arg signature: ``*exc`` would pack a tuple per call and
    # the disabled span path is pinned allocation-free by tests/test_obs.py
    def __exit__(self, exc_type=None, exc=None, tb=None):
        return False

    def fence(self, x):
        return x

    def arg(self, name, value):
        return None


NOOP_SPAN = _NoopSpan()


class _LiveSpan:
    """Context manager recording one span row on exit."""

    __slots__ = ("_tr", "_name", "_args", "_idx", "_fence")

    def __init__(self, tr: "Tracer", name: str, args: Optional[dict]):
        self._tr = tr
        self._name = name
        self._args = args
        self._fence = None

    def __enter__(self):
        self._idx = self._tr._open(self._name, self._args)
        return self

    def fence(self, x):
        """Register a jax value to block on at span close, so the span
        covers the asynchronously-dispatched device work. Returns ``x``."""
        self._fence = x
        return x

    def arg(self, name, value):
        """Attach one arg to the span after it opened (values often only
        exist mid-span, e.g. the pow2 bucket an executor picked)."""
        self._tr._arg(self._idx, name, value)

    def __exit__(self, exc_type=None, exc=None, tb=None):
        if self._fence is not None:
            import jax
            jax.block_until_ready(self._fence)
            self._fence = None
        self._tr._close(self._idx)
        return False


class Tracer:
    """SoA span recorder + counters + metric-row stream."""

    def __init__(self, enabled: bool = False):
        self.enabled = bool(enabled)
        self.counters: Dict[str, float] = {}
        self.metrics: List[Dict[str, Any]] = []
        self._names: Dict[str, int] = {}      # interned span names
        self._name_list: List[str] = []
        self._name_id = _Col(np.int32)
        self._start_ns = _Col(np.int64)
        self._dur_ns = _Col(np.int64)
        self._parent = _Col(np.int32)
        self._compiles = _Col(np.int32)       # backend compiles inside span
        self._span_args: Dict[int, Dict[str, Any]] = {}   # sparse
        self._stack: List[int] = []
        self._t0_ns = time.perf_counter_ns()

    # -------------------------------------------------------------- #
    # Recording (fast paths first)
    # -------------------------------------------------------------- #
    def span(self, name: str, args: Optional[dict] = None):
        """Open a nested span. Disabled: returns the shared no-op singleton
        (no allocation — the span fast path the neutrality tests pin)."""
        if not self.enabled:
            return NOOP_SPAN
        return _LiveSpan(self, name, args)

    def counter(self, name: str, n: float = 1) -> None:
        if not self.enabled:
            return
        self.counters[name] = self.counters.get(name, 0) + n

    def metric(self, kind: str, **fields) -> None:
        """Append one structured metric row (the JSONL stream). Callers
        building non-trivial fields should guard with ``tracer.enabled``."""
        if not self.enabled:
            return
        fields["kind"] = kind
        fields.setdefault("ts_s", (time.perf_counter_ns() - self._t0_ns)
                          / 1e9)
        self.metrics.append(fields)

    def metric_row(self, row: Dict[str, Any]) -> None:
        """Append an externally-built row (e.g. a bridge server_step row)
        to the metrics stream without copying."""
        if self.enabled:
            self.metrics.append(row)

    def fence(self, x):
        """Module-style fence: block on ``x`` only when tracing. Returns x."""
        if self.enabled:
            import jax
            jax.block_until_ready(x)
        return x

    # -------------------------------------------------------------- #
    # Span internals
    # -------------------------------------------------------------- #
    def _intern(self, name: str) -> int:
        nid = self._names.get(name)
        if nid is None:
            nid = len(self._name_list)
            self._names[name] = nid
            self._name_list.append(name)
        return nid

    def _open(self, name: str, args: Optional[dict]) -> int:
        parent = self._stack[-1] if self._stack else -1
        idx = self._name_id.push(self._intern(name))
        self._start_ns.push(time.perf_counter_ns() - self._t0_ns)
        self._dur_ns.push(-1)
        self._parent.push(parent)
        self._compiles.push(self.counters.get("jit_compiles", 0))
        if args:
            self._span_args[idx] = dict(args)
        self._stack.append(idx)
        return idx

    def _close(self, idx: int) -> None:
        now = time.perf_counter_ns() - self._t0_ns
        self._dur_ns.a[idx] = now - self._start_ns.a[idx]
        # compiles column held the open-time snapshot; close resolves it to
        # the delta (compiles that happened inside the span, children incl.)
        n_comp = int(self.counters.get("jit_compiles", 0)
                     - self._compiles.a[idx])
        self._compiles.a[idx] = n_comp
        if n_comp:
            self.counter("spans_with_compile")
        else:
            self.counter("spans_cache_hit")
        # tolerate mis-nested exits: pop back to this span
        while self._stack:
            top = self._stack.pop()
            if top == idx:
                break

    def _arg(self, idx: int, name: str, value) -> None:
        self._span_args.setdefault(idx, {})[name] = value

    # -------------------------------------------------------------- #
    # Reading
    # -------------------------------------------------------------- #
    def __len__(self) -> int:
        return self._name_id.n

    def mark(self) -> int:
        """Current span-row count; pass to ``span_totals`` to aggregate the
        spans recorded after a point in time (e.g. one ``Server.step``)."""
        return self._name_id.n

    def span_totals(self, since: int = 0) -> Dict[str, float]:
        """Total seconds per span name over rows ``[since:]`` (closed spans
        only). Nested spans each count under their own name."""
        if not self.enabled and self._name_id.n <= since:
            return {}
        nid = self._name_id.view()[since:]
        dur = self._dur_ns.view()[since:]
        ok = dur >= 0
        out: Dict[str, float] = {}
        if not ok.any():
            return out
        totals = np.bincount(nid[ok], weights=dur[ok],
                             minlength=len(self._name_list))
        for name, tot in zip(self._name_list, totals):
            if tot > 0:
                out[name] = float(tot) / 1e9
        return out

    def spans(self) -> List[Dict[str, Any]]:
        """Materialized span rows (exporters / tests; not a hot path)."""
        out = []
        for i in range(self._name_id.n):
            out.append({
                "name": self._name_list[int(self._name_id.a[i])],
                "start_ns": int(self._start_ns.a[i]),
                "dur_ns": int(self._dur_ns.a[i]),
                "parent": int(self._parent.a[i]),
                "compiles": int(self._compiles.a[i]),
                "args": self._span_args.get(i),
            })
        return out

    def reset(self) -> None:
        """Drop recorded spans/counters/metrics (keeps interned names)."""
        self.counters = {}
        self.metrics = []
        self._name_id = _Col(np.int32)
        self._start_ns = _Col(np.int64)
        self._dur_ns = _Col(np.int64)
        self._parent = _Col(np.int32)
        self._compiles = _Col(np.int32)
        self._span_args = {}
        self._stack = []
        self._t0_ns = time.perf_counter_ns()


# process-wide singleton: call sites bind ``from repro.obs import tracer``
# once at import time; ``configure`` toggles the flag on the same object so
# the binding stays valid however early the import happened
tracer = Tracer(enabled=False)

_JIT_LISTENER_INSTALLED = False


def _install_jit_listener() -> None:
    """Count XLA backend compiles via jax.monitoring (best-effort: the
    event name is version-dependent, so a missing API degrades to zero
    counters rather than failing)."""
    global _JIT_LISTENER_INSTALLED
    if _JIT_LISTENER_INSTALLED:
        return
    _JIT_LISTENER_INSTALLED = True
    try:
        from jax import monitoring

        def _on_duration(event: str, duration: float, **kw) -> None:
            if not tracer.enabled:
                return
            if event.endswith("backend_compile_duration"):
                tracer.counter("jit_compiles")
                tracer.counter("jit_compile_s", duration)

        monitoring.register_event_duration_secs_listener(_on_duration)
    except Exception:       # noqa: BLE001 - monitoring API moved/missing
        pass


def configure(enabled: Optional[bool] = None, reset: bool = False) -> Tracer:
    """Toggle/reset the process-wide tracer. ``configure(enabled=True)``
    also installs the jit compile listener (once)."""
    if reset:
        tracer.reset()
    if enabled is not None:
        tracer.enabled = bool(enabled)
        if enabled:
            _install_jit_listener()
    return tracer
