"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` describes any of the six families in the assignment pool:

* ``dense``   — decoder-only transformer (GQA, RoPE, optional qk_norm / QKV
                bias / sliding window).
* ``moe``     — dense skeleton with the FFN replaced by shared+routed experts.
* ``ssm``     — attention-free RWKV6 (Finch) blocks with data-dependent decay.
* ``hybrid``  — Hymba-style blocks running attention heads and a Mamba/S6 head
                in parallel within every layer.
* ``audio``   — Whisper-style encoder-decoder; the mel+conv frontend is a stub
                that supplies precomputed frame embeddings (the one allowed
                carve-out).
* ``vlm``     — Qwen2-VL-style decoder with M-RoPE; the vision tower is a stub
                that supplies precomputed patch embeddings.

Everything is a frozen dataclass so configs are hashable and usable as static
arguments to jit.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (DeepSeekMoE / Llama-4 style)."""

    n_experts: int            # routed experts
    top_k: int                # experts activated per token
    n_shared: int = 0         # always-on shared experts
    d_expert: int = 0         # per-expert hidden width (0 -> use d_ff)
    router_aux_coef: float = 0.01   # load-balance auxiliary loss weight
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for encoder-decoder (audio) models."""

    n_layers: int
    n_ctx: int               # number of frames after the (stubbed) conv frontend


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    block_type: str = "attention"    # attention | rwkv6 | hybrid
    rope: str = "rope"               # none | rope | mrope
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # temporal/h/w rotary dims
    qk_norm: bool = False
    attn_bias: bool = False          # QKV projection bias (Qwen1.5)
    sliding_window: Optional[int] = None
    moe: Optional[MoEConfig] = None
    ssm_state: int = 16              # S6 / mamba state size (hybrid family)
    ssm_expand: int = 2              # mamba inner expansion
    encoder: Optional[EncoderConfig] = None
    frontend: str = "none"           # none | audio | vision
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu_glu"            # silu_glu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # RWKV6-specific
    rwkv_head_size: int = 64
    # remat policy for scan-over-layers ("none" | "full" | "dots")
    remat: str = "none"
    max_seq_len: int = 524_288
    # probe mode: unroll layer/attention scans so cost_analysis sees every op
    # (used by the roofline probe on 1-2 layer variants; see benchmarks/roofline)
    probe_unroll: bool = False
    attn_chunk: int = 512
    # remat the chunked-attention inner scan (flash-style backward recompute;
    # without it one layer's saved per-chunk probs = the full S x S matrix)
    remat_attn_chunks: bool = True
    # train/prefill attention implementation: "chunked" (pure-jnp online
    # softmax, the exact fallback used on CPU) or "flash" (Pallas fwd+bwd
    # kernel, repro.kernels.flash_attention). "flash" silently falls back
    # to "chunked" off-accelerator so configs are portable.
    attn_impl: str = "chunked"
    # WKV recurrence implementation for rwkv6 blocks: "scan" (pure-jnp
    # lax.scan oracle) or "pallas" (chunked Pallas kernel + recompute vjp);
    # same CPU fallback rule as attn_impl.
    wkv_impl: str = "scan"
    # mesh axes the activation batch dim is sharded over (set by the launcher;
    # constrains the residual stream so GSPMD never silently replicates batch)
    act_batch_axes: Optional[Tuple[str, ...]] = None
    # sequence-parallel axis for the residual stream between layers (Megatron
    # SP): shards the remat-saved (L, B, S, d) carries by the model axis
    act_seq_axis: Optional[str] = None
    # expert-parallel axis for the MoE (E, C, d) dispatch buffers
    moe_expert_axis: Optional[str] = None
    # axes sharding the MoE capacity dim (perf: without this the dispatch
    # buffer is replicated across the data axis -> data-axis-times redundant
    # expert FFN compute; see EXPERIMENTS.md §Perf hillclimb 1)
    moe_capacity_axes: Optional[Tuple[str, ...]] = None
    # MoE implementation: "gather" (GSPMD index-gathers) or "shard_map"
    # (expert-parallel local dispatch + psum; see layers.moe_fwd_shardmap)
    moe_impl: str = "gather"
    # decode: use direct (non-chunked) attention for single-query steps —
    # chunk-scanning a seq-sharded cache makes GSPMD gather every chunk
    # (54x on the dominant roofline term; EXPERIMENTS.md §Perf hillclimb 2)
    decode_direct_attn: bool = True
    # decode KV-cache sharding: batch axes and seq axes for the stacked
    # (L, B, S, KV, hd) k/v leaves. Pinned inside serve_step — without the
    # pin GSPMD shards the stacked cache's L dim and pays an involuntary
    # full rematerialization per layer slice.
    cache_batch_axes: Optional[Tuple[str, ...]] = None
    cache_seq_axes: Optional[Tuple[str, ...]] = None

    # ------------------------------------------------------------------ #
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: <=2 layers, d_model<=512,
        <=4 experts — used by per-arch CPU smoke tests."""
        d_model = min(self.d_model, 256)
        n_heads = max(2, min(self.n_heads, 4))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        kw = dict(
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=d_model // n_heads,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            dtype="float32",
            rwkv_head_size=d_model // max(2, min(self.n_heads, 4)),
            max_seq_len=4096,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                n_shared=min(self.moe.n_shared, 1),
                d_expert=min(self.moe.d_expert or self.d_ff, 128),
            )
        if self.encoder is not None:
            kw["encoder"] = EncoderConfig(n_layers=2, n_ctx=64)
        if self.sliding_window is not None:
            kw["sliding_window"] = min(self.sliding_window, 64)
        if self.rope == "mrope":
            kw["mrope_sections"] = _mrope_sections_for(d_model // n_heads)
        return self.with_(**kw)


def _mrope_sections_for(head_dim: int) -> Tuple[int, int, int]:
    """Split half the head_dim rotary coordinates into t/h/w sections."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return (t, h, w)


# --------------------------------------------------------------------------- #
# Input shape specifications (the four assigned workloads).
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
