"""Paper-scale models: the paper's experiments use LeNet (MNIST/FMNIST),
ResNet18 (CIFAR-10/MDI), an MLP (PAMAP2) and a 1D-CNN (ExtraSensory).

At CPU scale we implement: LeNet (faithful), a small residual CNN standing in
for ResNet18's role, the 3-layer MLP and the 1D-CNN. All take *continuous*
inputs so gradient inversion can optimize D_rec directly in input space.

API: ``SmallModel(init, apply, input_shape, n_classes)`` where
``apply(params, x) -> logits``.
"""

from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class SmallModel(NamedTuple):
    name: str
    init: Callable[[jax.Array], Any]
    apply: Callable[[Any, jax.Array], jax.Array]
    input_shape: Tuple[int, ...]
    n_classes: int
    # optional ModelConfig for transformer-backed models
    # (repro.models.fl_bridge): carries the weight-sharding rules the
    # server needs when the mesh has a model axis. None for the paper-scale
    # models — they never model-shard.
    cfg: Any = None


def _dense(key, fan_in, shape):
    return jax.random.normal(key, shape, jnp.float32) / math.sqrt(max(fan_in, 1))


def _conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv1d(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride,), padding, dimension_numbers=("NWC", "WIO", "NWC"))


# --------------------------------------------------------------------------- #
# LeNet (paper: MNIST / FMNIST experiments)
# --------------------------------------------------------------------------- #


def lenet(n_classes: int = 10, in_hw: int = 28, in_ch: int = 1) -> SmallModel:
    hw4 = in_hw // 4

    def init(key):
        ks = jax.random.split(key, 5)
        return {
            "c1": _dense(ks[0], 25 * in_ch, (5, 5, in_ch, 6)),
            "c2": _dense(ks[1], 25 * 6, (5, 5, 6, 16)),
            "f1": _dense(ks[2], hw4 * hw4 * 16, (hw4 * hw4 * 16, 120)),
            "f2": _dense(ks[3], 120, (120, 84)),
            "f3": _dense(ks[4], 84, (84, n_classes)),
            "b1": jnp.zeros((120,)), "b2": jnp.zeros((84,)),
            "b3": jnp.zeros((n_classes,)),
        }

    def apply(p, x):
        x = jnp.tanh(_conv(x, p["c1"]))
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        x = jnp.tanh(_conv(x, p["c2"]))
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        x = x.reshape(x.shape[0], -1)
        x = jnp.tanh(x @ p["f1"] + p["b1"])
        x = jnp.tanh(x @ p["f2"] + p["b2"])
        return x @ p["f3"] + p["b3"]

    return SmallModel("lenet", init, apply, (in_hw, in_hw, in_ch), n_classes)


# --------------------------------------------------------------------------- #
# Small residual CNN (stands in for ResNet18 at CPU scale; CIFAR/MDI role)
# --------------------------------------------------------------------------- #


def rescnn(n_classes: int = 10, in_hw: int = 32, in_ch: int = 3, width: int = 16
           ) -> SmallModel:
    def init(key):
        ks = jax.random.split(key, 8)
        return {
            "stem": _dense(ks[0], 9 * in_ch, (3, 3, in_ch, width)),
            "b1a": _dense(ks[1], 9 * width, (3, 3, width, width)),
            "b1b": _dense(ks[2], 9 * width, (3, 3, width, width)),
            "down": _dense(ks[3], 9 * width, (3, 3, width, 2 * width)),
            "b2a": _dense(ks[4], 9 * 2 * width, (3, 3, 2 * width, 2 * width)),
            "b2b": _dense(ks[5], 9 * 2 * width, (3, 3, 2 * width, 2 * width)),
            "head": _dense(ks[6], 2 * width, (2 * width, n_classes)),
            "hb": jnp.zeros((n_classes,)),
        }

    def apply(p, x):
        x = jax.nn.relu(_conv(x, p["stem"]))
        h = jax.nn.relu(_conv(x, p["b1a"]))
        x = jax.nn.relu(x + _conv(h, p["b1b"]))
        x = jax.nn.relu(_conv(x, p["down"], stride=2))
        h = jax.nn.relu(_conv(x, p["b2a"]))
        x = jax.nn.relu(x + _conv(h, p["b2b"]))
        x = jnp.mean(x, axis=(1, 2))
        return x @ p["head"] + p["hb"]

    return SmallModel("rescnn", init, apply, (in_hw, in_hw, in_ch), n_classes)


# --------------------------------------------------------------------------- #
# 3-layer MLP (paper Appendix A: PAMAP2)
# --------------------------------------------------------------------------- #


def mlp3(n_features: int = 52, n_classes: int = 13, hidden: int = 128) -> SmallModel:
    def init(key):
        ks = jax.random.split(key, 3)
        return {
            "w1": _dense(ks[0], n_features, (n_features, hidden)),
            "w2": _dense(ks[1], hidden, (hidden, hidden)),
            "w3": _dense(ks[2], hidden, (hidden, n_classes)),
            "b1": jnp.zeros((hidden,)), "b2": jnp.zeros((hidden,)),
            "b3": jnp.zeros((n_classes,)),
        }

    def apply(p, x):
        x = jax.nn.relu(x @ p["w1"] + p["b1"])
        x = jax.nn.relu(x @ p["w2"] + p["b2"])
        return x @ p["w3"] + p["b3"]

    return SmallModel("mlp3", init, apply, (n_features,), n_classes)


# --------------------------------------------------------------------------- #
# 1D-CNN (paper Appendix A: ExtraSensory)
# --------------------------------------------------------------------------- #


def cnn1d(seq: int = 64, channels: int = 6, n_classes: int = 7, width: int = 32
          ) -> SmallModel:
    def init(key):
        ks = jax.random.split(key, 4)
        return {
            "c1": _dense(ks[0], 5 * channels, (5, channels, width)),
            "c2": _dense(ks[1], 5 * width, (5, width, width)),
            "f": _dense(ks[2], width, (width, n_classes)),
            "fb": jnp.zeros((n_classes,)),
        }

    def apply(p, x):
        x = jax.nn.relu(_conv1d(x, p["c1"], stride=2))
        x = jax.nn.relu(_conv1d(x, p["c2"], stride=2))
        x = jnp.mean(x, axis=1)
        return x @ p["f"] + p["fb"]

    return SmallModel("cnn1d", init, apply, (seq, channels), n_classes)


SMALL_MODELS = {
    "lenet": lenet,
    "rescnn": rescnn,
    "mlp3": mlp3,
    "cnn1d": cnn1d,
}
