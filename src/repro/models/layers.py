"""Model building blocks (pure JAX, functional, params-as-pytrees).

Every module is a pair of functions::

    init_<mod>(key, cfg, ...) -> params dict
    <mod>_fwd(params, cfg, x, ...) -> output

so layer stacks can be built with ``jax.vmap`` (stacked init) and
``jax.lax.scan`` (stacked apply) in ``transformer.py``.

Design notes
------------
* Attention is *chunked* (online-softmax over KV blocks via ``lax.scan``) so
  that lowering at 32k context never materializes an S x S score matrix —
  this is the pure-jnp analogue of the Pallas flash kernel in
  ``repro.kernels.flash_attention`` and doubles as its oracle.
* MoE uses sort-based dispatch with a static capacity (Megablocks-lite):
  honest FLOPs (no all-experts-on-all-tokens waste) and it induces the real
  all-to-all when experts are sharded on the ``model`` mesh axis.
* RWKV6 and the S6/Mamba head keep recurrent state explicitly so decode is
  O(1) in sequence length.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = Dict[str, Any]

# --------------------------------------------------------------------------- #
# Initializers
# --------------------------------------------------------------------------- #


def _dense_init(key, fan_in: int, shape, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #


def init_norm(cfg: ModelConfig, d: int) -> Params:
    p = {"scale": jnp.ones((d,), cfg.param_dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.param_dtype)
    return p


def norm_fwd(p: Params, cfg: ModelConfig, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm used by qk_norm (Qwen3)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------- #
# Rotary embeddings (RoPE and Qwen2-VL M-RoPE)
# --------------------------------------------------------------------------- #


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (..., S) -> cos/sin (..., S, head_dim//2), float32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(
    positions3: jax.Array, head_dim: int, theta: float, sections: Tuple[int, ...]
) -> Tuple[jax.Array, jax.Array]:
    """M-RoPE: positions3 (3, B, S); sections sum to head_dim//2.

    Rotary coordinate j uses the temporal/h/w position depending on which
    section j falls in (Qwen2-VL).
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # section id per rotary coordinate
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
    )  # (half,)
    pos = positions3.astype(jnp.float32)  # (3, B, S)
    pos_per_coord = jnp.take(pos, sec_id, axis=0)  # (half, B, S) via axis-0 gather
    ang = jnp.moveaxis(pos_per_coord, 0, -1) * freqs  # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, D); cos/sin (B, S, half) or (S, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    c = cos[:, :, None, :].astype(jnp.float32)
    s = sin[:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Attention (GQA, causal / bidirectional / cross, sliding window, cache)
# --------------------------------------------------------------------------- #


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    p = {
        "wq": _dense_init(ks[0], d, (d, h * hd), dt),
        "wk": _dense_init(ks[1], d, (d, kv * hd), dt),
        "wv": _dense_init(ks[2], d, (d, kv * hd), dt),
        "wo": _dense_init(ks[3], h * hd, (h * hd, d), dt),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    del cross
    return p


def _project_qkv(p: Params, cfg: ModelConfig, xq: jax.Array, xkv: jax.Array):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if cfg.attn_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, Sq, h, hd)
    k = k.reshape(B, Skv, kv, hd)
    v = v.reshape(B, Skv, kv, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    return q, k, v


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    q_offset: jax.Array | int = 0,
    kv_valid_len: Optional[jax.Array] = None,
    chunk: int = 512,
    unroll: bool = False,
    remat_chunks: bool = False,
) -> jax.Array:
    """Online-softmax attention over KV chunks; never forms (Sq, Skv) scores.

    q (B, Sq, H, D); k/v (B, Skv, KV, D). GQA via head repetition logic.
    ``q_offset``: absolute position of q[0] (decode: current position).
    ``kv_valid_len``: if given, keys at index >= valid_len are masked.
    """
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = 1.0 / math.sqrt(D)
    nchunk = max(1, (Skv + chunk - 1) // chunk)
    pad = nchunk * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunk, chunk, KV, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunk, chunk, KV, D).transpose(1, 0, 2, 3, 4)

    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # (B,H,Sq,D)
    qf = qf.reshape(B, KV, rep, Sq, D)

    q_pos = jnp.asarray(q_offset) + jnp.arange(Sq)  # (Sq,)

    def step(carry, inp):
        m, l, acc = carry
        kci, vci, cidx = inp
        kv_pos = cidx * chunk + jnp.arange(chunk)  # (chunk,)
        kf = kci.astype(jnp.float32).transpose(0, 2, 1, 3)  # (B,KV,chunk,D)
        s = jnp.einsum("bgrqd,bgcd->bgrqc", qf, kf)  # (B,KV,rep,Sq,chunk)
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        mask &= (kv_pos < Skv)[None, :]
        if kv_valid_len is not None:
            mask &= (kv_pos < kv_valid_len)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p_ = jnp.exp(s - m_safe[..., None])
        p_ = jnp.where(mask[None, None, None], p_, 0.0)
        corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + jnp.sum(p_, axis=-1)
        vf = vci.astype(jnp.float32).transpose(0, 2, 1, 3)  # (B,KV,chunk,D)
        acc_new = acc * corr[..., None] + jnp.einsum("bgrqc,bgcd->bgrqd", p_, vf)
        return (m_new, l_new, acc_new), ()

    m0 = jnp.full((B, KV, rep, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, rep, Sq, D), jnp.float32)
    if remat_chunks:
        # flash-style backward: recompute chunk scores instead of saving the
        # stacked (nchunk, ..., Sq, chunk) probs = the full S x S matrix
        step = jax.checkpoint(step)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, jnp.arange(nchunk)),
                                  unroll=nchunk if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


def direct_decode_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, valid_len: jax.Array,
    *, window: Optional[int] = None,
) -> jax.Array:
    """Single-query attention over the full cache, no chunk scan.

    q (B, 1, H, D); k/v (B, S, KV, D). Scores (B, KV, rep, S) stay sharded
    along whatever axes shard S; the softmax reductions contract over S so
    GSPMD emits small stat all-reduces rather than cache gathers.
    """
    B, Sq, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = 1.0 / math.sqrt(D)
    qf = (q.astype(jnp.float32) * scale).reshape(B, KV, rep, D)
    # read k/v in their storage dtype (no materialized f32 cache copy);
    # accumulate in f32 via preferred_element_type
    s = jnp.einsum("bgrd,bsgd->bgrs", qf, k,
                   preferred_element_type=jnp.float32)  # (B, KV, rep, S)
    pos = jnp.arange(S)
    mask = pos < valid_len
    if window is not None:
        mask &= pos > valid_len - 1 - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p_ = jnp.exp(s - m)
    p_ = jnp.where(mask[None, None, None], p_, 0.0)
    denom = jnp.maximum(p_.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bgrs,bsgd->bgrd", p_ / denom, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def train_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, cfg: ModelConfig,
    *, causal: bool = True, window: Optional[int] = None,
) -> jax.Array:
    """Full-sequence attention for train/prefill, routed by ``cfg.attn_impl``:

    * ``"chunked"`` — the pure-jnp online-softmax scan above (also the exact
      CPU fallback, so configs carrying ``"flash"`` stay portable);
    * ``"flash"``   — the Pallas fwd+bwd kernel
      (``repro.kernels.flash_attention``); differentiable via its
      custom_vjp, so the transformer LocalUpdate and GI differentiating
      through it both hit the kernel.
    """
    if cfg.attn_impl not in ("chunked", "flash"):
        raise ValueError(f"unknown attn_impl {cfg.attn_impl!r}")
    if cfg.attn_impl == "flash" and jax.default_backend() != "cpu":
        from repro.kernels.flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             chunk=cfg.attn_chunk, unroll=cfg.probe_unroll,
                             remat_chunks=cfg.remat_attn_chunks)


def attention_fwd(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    rope_cs: Optional[Tuple[jax.Array, jax.Array]] = None,
    causal: bool = True,
    window: Optional[int] = None,
    cache: Optional[Params] = None,
    cache_pos: Optional[jax.Array] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """Self or cross attention. Returns (out, updated_cache).

    Train/prefill: cache is None, full-sequence chunked attention.
    Decode: x is (B, 1, d); cache holds (B, S_max, KV, D) k/v; cache_pos is
    the current write index (scalar int32).
    """
    B, Sq, _ = x.shape
    if cross_kv is not None:
        k, v = cross_kv
        q = x @ p["wq"]
        if cfg.attn_bias:
            q = q + p["bq"]
        q = q.reshape(B, Sq, cfg.n_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = rms_head_norm(p["q_norm"], q)
        out = train_attention(q, k, v, cfg, causal=False, window=None)
        new_cache = cache
    else:
        q, k, v = _project_qkv(p, cfg, x, x)
        if rope_cs is not None:
            q = apply_rope(q, *rope_cs)
            k = apply_rope(k, *rope_cs)
        if cache is not None:
            # decode: write new k/v at cache_pos, attend over the cache
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
            new_cache = {"k": ck, "v": cv}
            if cfg.decode_direct_attn and Sq == 1:
                # single-query: one masked-softmax einsum over the (possibly
                # seq-sharded) cache — GSPMD reduces the softmax stats with
                # tiny all-reduces instead of gathering cache chunks
                out = direct_decode_attention(
                    q, ck, cv, cache_pos + Sq, window=window)
            else:
                out = chunked_attention(
                    q, ck, cv,
                    causal=True, window=window,
                    q_offset=cache_pos, kv_valid_len=cache_pos + Sq,
                    chunk=min(2048, ck.shape[1]), unroll=cfg.probe_unroll,
                )
        else:
            new_cache = None
            out = train_attention(q, k, v, cfg, causal=causal, window=window)
    out = out.reshape(B, Sq, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"], new_cache


def project_cross_kv(p: Params, cfg: ModelConfig, enc_out: jax.Array):
    """Precompute cross-attention K/V from encoder output (whisper decode)."""
    B, S, _ = enc_out.shape
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if cfg.attn_bias:
        k = k + p["bk"]
        v = v + p["bv"]
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = rms_head_norm(p["k_norm"], k)
    return k, v


# --------------------------------------------------------------------------- #
# Dense FFN
# --------------------------------------------------------------------------- #


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.param_dtype
    ks = jax.random.split(key, 3)
    if cfg.act == "silu_glu":
        return {
            "w_gate": _dense_init(ks[0], d, (d, f), dt),
            "w_up": _dense_init(ks[1], d, (d, f), dt),
            "w_down": _dense_init(ks[2], f, (f, d), dt),
        }
    return {
        "w_up": _dense_init(ks[0], d, (d, f), dt),
        "w_down": _dense_init(ks[1], f, (f, d), dt),
        "b_up": jnp.zeros((f,), dt),
        "b_down": jnp.zeros((d,), dt),
    }


def mlp_fwd(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "silu_glu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    return h @ p["w_down"] + p["b_down"]


# --------------------------------------------------------------------------- #
# Mixture of Experts (sort-based dispatch, static capacity)
# --------------------------------------------------------------------------- #


def init_moe(key, cfg: ModelConfig) -> Params:
    mc = cfg.moe
    d = cfg.d_model
    fe = mc.d_expert or cfg.d_ff
    dt = cfg.param_dtype
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], d, (d, mc.n_experts), jnp.float32),
        "w_gate": _dense_init(ks[1], d, (mc.n_experts, d, fe), dt),
        "w_up": _dense_init(ks[2], d, (mc.n_experts, d, fe), dt),
        "w_down": _dense_init(ks[3], fe, (mc.n_experts, fe, d), dt),
    }
    if mc.n_shared:
        sub = jax.random.split(ks[4], 3)
        fs = fe * mc.n_shared
        p["shared"] = {
            "w_gate": _dense_init(sub[0], d, (d, fs), dt),
            "w_up": _dense_init(sub[1], d, (d, fs), dt),
            "w_down": _dense_init(sub[2], fs, (fs, d), dt),
        }
    return p


def moe_fwd(
    p: Params, cfg: ModelConfig, x: jax.Array, capacity_factor: float = 1.25
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out, aux_loss). Sort-based dispatch with static capacity.

    All heavy data movement is expressed as GATHERS driven by small int32
    index maps (scatters only touch index vectors): GSPMD shards gathers
    over the expert axis cleanly, while an (E*C, d) scatter would be
    replicated per device. ``cfg.moe_expert_axis`` pins the expert-parallel
    axis of the (E, C, d) dispatch buffers.
    """
    mc = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = mc.n_experts, mc.top_k
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32)) @ p["router"]           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)           # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=1), axis=0
    )
    aux = mc.router_aux_coef * E * jnp.sum(me * ce)

    C = max(1, int(math.ceil(T * K / E * capacity_factor)))
    if cfg.moe_capacity_axes is not None:
        C = ((C + 127) // 128) * 128   # keep C divisible by the capacity axes
    flat_eid = expert_ids.reshape(T * K)
    flat_gate = gate_vals.reshape(T * K)
    flat_tok = jnp.repeat(jnp.arange(T), K)

    order = jnp.argsort(flat_eid)                             # stable
    s_eid = flat_eid[order]
    s_tok = flat_tok[order]
    # position within expert group: arange - start_of_run
    same = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            (s_eid[1:] == s_eid[:-1]).astype(jnp.int32)])
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(same == 0, jnp.arange(T * K), 0)
    )
    idx_in_group = jnp.arange(T * K) - run_start
    keep = idx_in_group < C
    # overflow entries point past the buffer and are dropped by the scatter
    slot = jnp.where(keep, s_eid * C + idx_in_group, E * C)

    # index maps (int32 vectors only — cheap scatters)
    src = jnp.full((E * C,), T, jnp.int32)                    # T -> zero row
    src = src.at[slot].set(s_tok.astype(jnp.int32), mode="drop")
    slot_of = jnp.full((T * K,), E * C, jnp.int32)            # E*C -> zero row
    slot_of = slot_of.at[order].set(slot.astype(jnp.int32))

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])
    eb = xt_pad[src].reshape(E, C, d)                         # dispatch gather
    if cfg.moe_expert_axis is not None or cfg.moe_capacity_axes is not None:
        from jax.sharding import PartitionSpec as P
        eb = jax.lax.with_sharding_constraint(
            eb, P(cfg.moe_expert_axis, cfg.moe_capacity_axes, None))

    h = jnp.einsum("ecd,edf->ecf", eb, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", eb, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"])
    y_pad = jnp.concatenate([y.reshape(E * C, d),
                             jnp.zeros((1, d), y.dtype)])

    per_k = y_pad[slot_of.reshape(T, K)]                      # combine gather
    # dropped (token, k) pairs point at the zero row, so no gate masking
    # is needed — their contribution is exactly zero
    out = jnp.einsum("tkd,tk->td", per_k,
                     gate_vals.astype(per_k.dtype)).astype(x.dtype)

    if mc.n_shared:
        sp = p["shared"]
        out = out + (jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_up"])) @ sp["w_down"]
    return out.reshape(B, S, d), aux


# --------------------------------------------------------------------------- #
# shard_map MoE (expert-parallel, local dispatch — EXPERIMENTS.md §Perf)
# --------------------------------------------------------------------------- #

_MOE_MESH = None


def set_moe_mesh(mesh) -> None:
    """Registers the mesh used by the shard_map MoE path (set by the
    launcher/dry-run before lowering; None disables the path)."""
    global _MOE_MESH
    _MOE_MESH = mesh


def _local_moe_block(cfg: ModelConfig, capacity_factor: float,
                     model_ax: str, dp_axes):
    """Per-shard body: tokens local to the data shard (replicated over the
    model axis), experts local to the model shard; combine via one psum."""
    mc = cfg.moe

    def block(xt, router, w_gate, w_up, w_down, shared):
        T, d = xt.shape
        E, K = mc.n_experts, mc.top_k
        E_local = w_gate.shape[0]
        m_idx = jax.lax.axis_index(model_ax)
        lo = m_idx * E_local

        logits = xt.astype(jnp.float32) @ router                  # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                            1e-9)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(expert_ids, E,
                                             dtype=jnp.float32), axis=1),
                      axis=0)
        aux = mc.router_aux_coef * E * jnp.sum(me * ce)
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)

        # ---- dispatch to LOCAL experts only (rest handled by peers) ----- #
        rel = expert_ids - lo                                     # (T, K)
        valid = (rel >= 0) & (rel < E_local)
        C = max(1, int(math.ceil(T * K / E * capacity_factor)))
        C = ((C + 7) // 8) * 8
        flat_rel = jnp.where(valid, rel, E_local).reshape(T * K)  # overflow bkt
        flat_tok = jnp.repeat(jnp.arange(T), K)
        order = jnp.argsort(flat_rel)
        s_rel = flat_rel[order]
        s_tok = flat_tok[order]
        same = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                (s_rel[1:] == s_rel[:-1]).astype(jnp.int32)])
        run_start = jax.lax.associative_scan(
            jnp.maximum, jnp.where(same == 0, jnp.arange(T * K), 0))
        idx_in_group = jnp.arange(T * K) - run_start
        keep = (idx_in_group < C) & (s_rel < E_local)
        slot = jnp.where(keep, s_rel * C + idx_in_group, E_local * C)

        src = jnp.full((E_local * C,), T, jnp.int32)
        src = src.at[slot].set(s_tok.astype(jnp.int32), mode="drop")
        slot_of = jnp.full((T * K,), E_local * C, jnp.int32)
        slot_of = slot_of.at[order].set(slot.astype(jnp.int32))

        xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])
        eb = xt_pad[src].reshape(E_local, C, d)
        h = jnp.einsum("ecd,edf->ecf", eb, w_gate)
        u = jnp.einsum("ecd,edf->ecf", eb, w_up)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w_down)
        y_pad = jnp.concatenate([y.reshape(E_local * C, d),
                                 jnp.zeros((1, d), y.dtype)])
        per_k = y_pad[slot_of.reshape(T, K)]
        out = jnp.einsum("tkd,tk->td", per_k,
                         gate_vals.astype(per_k.dtype)).astype(jnp.float32)

        if shared is not None:
            sg, su, sd = shared
            out = out + ((jax.nn.silu(xt @ sg) * (xt @ su)) @ sd
                         ).astype(jnp.float32)
        out = jax.lax.psum(out, model_ax)
        return out.astype(xt.dtype), aux

    return block


def moe_fwd_shardmap(p: Params, cfg: ModelConfig, x: jax.Array,
                     capacity_factor: float = 1.25
                     ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map: per-data-shard local dispatch, one
    psum over the model axis. Collective cost per layer ~= one activation
    all-gather in + one psum out (vs global token-indexed gathers in the
    GSPMD path). Falls back to ``moe_fwd`` when no mesh is registered or the
    expert count does not divide the model axis."""
    from jax.sharding import PartitionSpec as P
    mesh = _MOE_MESH
    mc = cfg.moe
    model_ax = cfg.moe_expert_axis or "model"
    dp_axes = cfg.act_batch_axes or ("data",)
    if mesh is None or model_ax not in mesh.axis_names:
        return moe_fwd(p, cfg, x, capacity_factor)
    msize = dict(mesh.shape)[model_ax]
    if mc.n_experts % msize or (mc.d_expert or cfg.d_ff) % msize:
        return moe_fwd(p, cfg, x, capacity_factor)

    B, S, d = x.shape
    # decode with tiny batch: replicate tokens over the data axes instead of
    # sharding an indivisible batch dim
    dp_size = 1
    for a in dp_axes:
        dp_size *= dict(mesh.shape).get(a, 1)
    if B % dp_size:
        dp_axes = ()
    block = _local_moe_block(cfg, capacity_factor, model_ax, dp_axes)

    def body(x3, router, w_gate, w_up, w_down, *shared):
        xt = x3.reshape(-1, d)
        out, aux = block(xt, router, w_gate, w_up, w_down,
                         shared if shared else None)
        return out.reshape(x3.shape), aux

    b_entry = dp_axes if dp_axes else None
    in_specs = [
        P(b_entry, None, None),        # x: batch on data, replicated model
        P(None, None),                 # router replicated
        P(model_ax, None, None),       # experts on model
        P(model_ax, None, None),
        P(model_ax, None, None),
    ]
    args = [x, p["router"], p["w_gate"], p["w_up"], p["w_down"]]
    if mc.n_shared:
        sp = p["shared"]
        in_specs += [P(None, model_ax), P(None, model_ax), P(model_ax, None)]
        args += [sp["w_gate"], sp["w_up"], sp["w_down"]]
    out, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(b_entry, None, None), P()),
    )(*args)
    return out, aux


# --------------------------------------------------------------------------- #
# RWKV6 (Finch) — token-shift, data-dependent decay, WKV recurrence
# --------------------------------------------------------------------------- #


def init_rwkv6(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    N = cfg.rwkv_head_size
    H = d // N
    dt = cfg.param_dtype
    ks = jax.random.split(key, 12)
    lora_r = max(8, d // 32)
    p = {
        # token-shift interpolation factors
        "mu_r": jnp.full((d,), 0.5, dt), "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt), "mu_w": jnp.full((d,), 0.5, dt),
        "mu_g": jnp.full((d,), 0.5, dt),
        "wr": _dense_init(ks[0], d, (d, d), dt),
        "wk": _dense_init(ks[1], d, (d, d), dt),
        "wv": _dense_init(ks[2], d, (d, d), dt),
        "wg": _dense_init(ks[3], d, (d, d), dt),
        "wo": _dense_init(ks[4], d, (d, d), dt),
        # data-dependent decay LoRA (the Finch contribution)
        "w0": jnp.full((d,), -6.0, dt),
        "w_lora_a": _dense_init(ks[5], d, (d, lora_r), dt),
        "w_lora_b": _dense_init(ks[6], lora_r, (lora_r, d), dt),
        "u": _dense_init(ks[7], N, (H, N), dt),   # per-head bonus
        "ln_x": jnp.ones((d,), dt),               # group-norm scale on output
        # channel mix
        "cm_mu_k": jnp.full((d,), 0.5, dt), "cm_mu_r": jnp.full((d,), 0.5, dt),
        "cm_wk": _dense_init(ks[8], d, (d, cfg.d_ff), dt),
        "cm_wv": _dense_init(ks[9], cfg.d_ff, (cfg.d_ff, d), dt),
        "cm_wr": _dense_init(ks[10], d, (d, d), dt),
    }
    return p


def _token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """Shift sequence right by one; ``prev`` supplies x[-1] for decode."""
    if x.shape[1] == 1 and prev is not None:
        return prev[:, None, :]
    pad = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def wkv6_scan(r, k, v, w, u):
    """WKV recurrence.  r,k,v,w: (B, T, H, N); u: (H, N).

    S_t in R^{H x N x N};  out_t = r_t @ (S_t + u * k_t v_t^T);
    S_{t+1} = diag(w_t) S_t + k_t v_t^T.
    Returns (out (B,T,H,N), final_state (B,H,N,N)).
    """
    B, T, H, N = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                       # (B,H,N)
        a = kt[..., :, None] * vt[..., None, :]    # (B,H,N,N) outer
        out = jnp.einsum("bhn,bhnm->bhm", rt, S + uf[None, :, :, None] * a)
        S = wt[..., :, None] * S + a
        return S, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    S0 = jnp.zeros((B, H, N, N), jnp.float32)
    S, outs = jax.lax.scan(step, S0, xs)
    out = jnp.moveaxis(outs, 0, 1)                 # (B,T,H,N)
    return out, S


def rwkv6_time_mix(
    p: Params, cfg: ModelConfig, x: jax.Array,
    state: Optional[Params] = None,
) -> Tuple[jax.Array, Params]:
    """RWKV6 time-mix. state = {"x_prev": (B,d), "S": (B,H,N,N)} for decode."""
    B, T, d = x.shape
    N = cfg.rwkv_head_size
    H = d // N
    xs = _token_shift(x, None if state is None else state["x_prev"])

    def lerp(mu):
        return x + (xs - x) * mu

    r = lerp(p["mu_r"]) @ p["wr"]
    k = lerp(p["mu_k"]) @ p["wk"]
    v = lerp(p["mu_v"]) @ p["wv"]
    g = jax.nn.silu(lerp(p["mu_g"]) @ p["wg"])
    ww = lerp(p["mu_w"])
    dd = jnp.tanh(ww @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp((p["w0"] + dd).astype(jnp.float32)))  # (B,T,d) in (0,1)

    rh = r.reshape(B, T, H, N)
    kh = k.reshape(B, T, H, N)
    vh = v.reshape(B, T, H, N)
    wh = w.reshape(B, T, H, N)

    if state is not None and T == 1:
        # O(1) decode step
        S = state["S"]
        a = kh[:, 0, :, :, None] * vh[:, 0, :, None, :]
        out = jnp.einsum("bhn,bhnm->bhm", rh[:, 0].astype(jnp.float32),
                         S + p["u"].astype(jnp.float32)[None, :, :, None] * a)
        S_new = wh[:, 0, :, :, None].astype(jnp.float32) * S + a
        out = out[:, None]  # (B,1,H,N)
    elif cfg.wkv_impl == "pallas" and jax.default_backend() != "cpu":
        # Pallas chunked kernel (repro.kernels.rwkv6_wkv) for the sequence
        # outputs; its recompute-vjp makes this path differentiable. The
        # kernel does not carry the final state out, but S_T has a closed
        # form — sum_t (prod_{s>t} w_s) k_t v_t^T — so prefill-for-decode
        # still hands decode a correct state.
        from repro.kernels.rwkv6_wkv import wkv6
        out = wkv6(rh, kh, vh, wh, p["u"]).astype(jnp.float32)
        wf = jnp.flip(wh.astype(jnp.float32), axis=1)
        cp = jnp.cumprod(wf, axis=1)
        decay = jnp.flip(jnp.concatenate(
            [jnp.ones_like(cp[:, :1]), cp[:, :-1]], axis=1), axis=1)
        S_new = jnp.einsum("bthn,bthm->bhnm",
                           kh.astype(jnp.float32) * decay,
                           vh.astype(jnp.float32))
    else:
        if cfg.wkv_impl not in ("scan", "pallas"):
            raise ValueError(f"unknown wkv_impl {cfg.wkv_impl!r}")
        out, S_new = wkv6_scan(rh, kh, vh, wh, p["u"])

    out = out.reshape(B, T, d)
    # group norm per head (simplified: rms over head dims)
    out = out.reshape(B, T, H, N)
    out = out * jax.lax.rsqrt(jnp.mean(jnp.square(out), axis=-1, keepdims=True) + 1e-5)
    out = out.reshape(B, T, d) * p["ln_x"].astype(jnp.float32)
    out = (out.astype(x.dtype) * g) @ p["wo"]
    new_state = {"x_prev": x[:, -1, :], "S": S_new}
    return out, new_state


def rwkv6_channel_mix(
    p: Params, cfg: ModelConfig, x: jax.Array,
    prev: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    xs = _token_shift(x, prev)
    xk = x + (xs - x) * p["cm_mu_k"]
    xr = x + (xs - x) * p["cm_mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    return jax.nn.sigmoid(xr @ p["cm_wr"]) * (k @ p["cm_wv"]), x[:, -1, :]


# --------------------------------------------------------------------------- #
# S6 / Mamba head (Hymba hybrid)
# --------------------------------------------------------------------------- #


def init_mamba(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    dt = cfg.param_dtype
    ks = jax.random.split(key, 7)
    dt_rank = max(8, d // 16)
    return {
        "w_in": _dense_init(ks[0], d, (d, 2 * di), dt),
        "conv_w": _dense_init(ks[1], 4, (4, di), dt),      # depthwise, kernel 4
        "conv_b": jnp.zeros((di,), dt),
        "w_bc": _dense_init(ks[2], di, (di, 2 * N), dt),
        "w_dt1": _dense_init(ks[3], di, (di, dt_rank), dt),
        "w_dt2": _dense_init(ks[4], dt_rank, (dt_rank, di), dt),
        "dt_bias": jnp.full((di,), -4.6, dt),              # softplus^-1(0.01)
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": _dense_init(ks[5], di, (di, d), dt),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                           state: Optional[jax.Array] = None):
    """x (B,T,C); w (K,C). Returns (y, new_state (B,K-1,C))."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):, :]
    return y + b, new_state


def mamba_fwd(
    p: Params, cfg: ModelConfig, x: jax.Array,
    state: Optional[Params] = None,
) -> Tuple[jax.Array, Params]:
    """Selective SSM. state = {"conv": (B,3,di), "h": (B,di,N)} for decode."""
    B, T, d = x.shape
    N = cfg.ssm_state
    xz = x @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)                      # (B,T,di)
    conv_state = None if state is None else state["conv"]
    xi, conv_new = _causal_depthwise_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    xi = jax.nn.silu(xi)

    bc = xi @ p["w_bc"]
    Bm, Cm = jnp.split(bc, 2, axis=-1)                     # (B,T,N)
    dt_ = jax.nn.softplus((xi @ p["w_dt1"]) @ p["w_dt2"] + p["dt_bias"])  # (B,T,di)
    A = -jnp.exp(p["A_log"])                               # (di,N)

    dtf = dt_.astype(jnp.float32)
    xif = xi.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    dA = jnp.exp(dtf[..., None] * A[None, None])           # (B,T,di,N)
    dBx = dtf[..., None] * Bf[:, :, None, :] * xif[..., None]  # (B,T,di,N)

    h0 = (jnp.zeros((B, xi.shape[-1], N), jnp.float32)
          if state is None else state["h"])
    if state is not None and T == 1:
        h = dA[:, 0] * h0 + dBx[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, Cf[:, 0])[:, None]
        h_new = h
    else:
        def step(h, inp):
            dAt, dBxt, Ct = inp
            h = dAt * h + dBxt
            return h, jnp.einsum("bdn,bn->bd", h, Ct)
        xs_ = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0), jnp.moveaxis(Cf, 1, 0))
        h_new, ys = jax.lax.scan(step, h0, xs_)
        y = jnp.moveaxis(ys, 0, 1)                         # (B,T,di)
    y = y + p["D"] * xif
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["w_out"]
    return out, {"conv": conv_new, "h": h_new}
