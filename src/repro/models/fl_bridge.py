"""SmallModel bridge: the FL server + GI stack on real transformer models.

``Server``/``GradientInverter`` speak the ``SmallModel`` contract —
``init(key) -> params``, ``apply(params, x) -> logits``, a continuous
``input_shape``, and ``n_classes`` — which gradient inversion exploits by
optimizing a *continuous* input. For language models the continuous
surrogate is the embedding space (the same relaxation
``examples/fl_llm_embedding_gi.py`` demonstrates): each reconstructed
example is a soft (seq_len, d_model) embedding sequence, labels are soft
distributions over the vocabulary, and the task is next-token prediction
at the last position.

``lm_fl_model`` wraps any ``ModelConfig`` family the transformer zoo
supports (dense/GQA attention, RWKV6, whisper-style encoder-decoder) in
that contract:

* inputs ``x`` are (batch, seq_len, d_model) fp32 soft embeddings; the
  forward casts them to ``cfg.param_dtype`` — set ``dtype="bfloat16"``
  for bf16-compute GI while the recon variables stay fp32;
* encoder-decoder configs close over a fixed deterministic bank of
  encoder frames (the stubbed audio frontend), so GI differentiates
  through the encoder cross-attention too;
* logits are the last-position next-token distribution, fp32 — exactly
  the (n, n_classes) shape ``soft_ce_loss`` and ``Server._eval_fn``
  already consume.

Remat/bf16/kernel knobs ride on the ``ModelConfig`` (``remat``,
``remat_attn_chunks``, ``dtype``, ``attn_impl``, ``wkv_impl``), so the GI
while_loop body and the multi-version cohort LocalUpdate inherit them with
no server-side changes. See docs/real_models.md.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.small import SmallModel


def make_frames(cfg: ModelConfig, seed: int = 0) -> jax.Array:
    """Deterministic (1, n_ctx, d_model) encoder-frame bank (audio stub)."""
    assert cfg.encoder is not None
    return 0.02 * jax.random.normal(
        jax.random.PRNGKey(seed), (1, cfg.encoder.n_ctx, cfg.d_model),
        jnp.float32)


def lm_fl_model(cfg: ModelConfig, *, seq_len: int,
                name: Optional[str] = None,
                frames_seed: int = 0) -> SmallModel:
    """Wrap ``cfg``'s transformer as a ``SmallModel`` for the FL server.

    ``input_shape`` is (seq_len, d_model) — continuous soft embeddings —
    and ``n_classes`` is the vocabulary, so ``GradientInverter.init_drec``
    produces embedding-space recon variables and soft vocab labels with no
    special-casing.
    """
    frames = make_frames(cfg, frames_seed) if cfg.is_encdec else None

    def init(key):
        return T.init_params(key, cfg)

    def apply(params, x):
        batch = {"input_embeds": x}
        if frames is not None:
            batch["frames"] = jnp.broadcast_to(
                frames, (x.shape[0],) + frames.shape[1:]).astype(
                    cfg.param_dtype)
        logits, _aux = T.forward(params, cfg, batch)
        return logits[:, -1, :].astype(jnp.float32)

    return SmallModel(name or f"fl_{cfg.name}", init, apply,
                      (seq_len, cfg.d_model), cfg.vocab_size, cfg=cfg)


def embed_dataset(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    """Token sequences (n, S) -> fp32 embedding-space inputs (n, S, d).

    The bridge's clients hold embedded data: FL clients train on their own
    (token) corpus, but the server-side recon variable lives in embedding
    space, so client datasets are embedded once up front with the *initial*
    embedding table (a fixed, known quantity server-side).
    """
    return T.embed_tokens(params, cfg, tokens).astype(jnp.float32)
