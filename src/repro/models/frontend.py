"""Modality frontend STUBS — the one allowed carve-out.

Per the assignment: audio (mel-spectrogram + conv feature extractor) and
vision (ViT/SigLIP + projector) frontends are not implemented; instead
``input_specs()`` provides precomputed frame/patch embeddings of the right
shape, and these helpers generate concrete embeddings (for smoke tests) or
ShapeDtypeStructs (for the dry-run).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

WHISPER_N_FRAMES = 1500          # 30 s of audio after the conv frontend
VLM_PATCHES_PER_IMAGE = 256      # one image worth of merged patch embeddings


def audio_frame_embeddings_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    n_ctx = cfg.encoder.n_ctx if cfg.encoder is not None else WHISPER_N_FRAMES
    return jax.ShapeDtypeStruct((batch, n_ctx, cfg.d_model), cfg.param_dtype)


def audio_frame_embeddings(key, cfg: ModelConfig, batch: int) -> jax.Array:
    spec = audio_frame_embeddings_spec(cfg, batch)
    return jax.random.normal(key, spec.shape, jnp.float32).astype(spec.dtype) * 0.02


def vlm_input_embeds_spec(cfg: ModelConfig, batch: int, seq: int) -> jax.ShapeDtypeStruct:
    """Merged text+patch embedding sequence the (stubbed) projector emits."""
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.param_dtype)


def vlm_input_embeds(key, cfg: ModelConfig, batch: int, seq: int) -> jax.Array:
    spec = vlm_input_embeds_spec(cfg, batch, seq)
    return jax.random.normal(key, spec.shape, jnp.float32).astype(spec.dtype) * 0.02


def mrope_positions(batch: int, seq: int, n_patches: int = VLM_PATCHES_PER_IMAGE,
                    grid: int = 16) -> jax.Array:
    """Qwen2-VL M-RoPE position ids (3, B, S): image patches get (t, h, w)
    grid positions; text tokens get equal t=h=w running positions."""
    n_patches = min(n_patches, seq)
    t = jnp.zeros((n_patches,), jnp.int32)
    h = (jnp.arange(n_patches) // grid).astype(jnp.int32)
    w = (jnp.arange(n_patches) % grid).astype(jnp.int32)
    text_start = jnp.maximum(jnp.max(h), jnp.max(w)) + 1 if n_patches else 0
    n_text = seq - n_patches
    text_pos = text_start + jnp.arange(n_text, dtype=jnp.int32)
    pos3 = jnp.stack([
        jnp.concatenate([t, text_pos]),
        jnp.concatenate([h, text_pos]),
        jnp.concatenate([w, text_pos]),
    ])  # (3, S)
    return jnp.broadcast_to(pos3[:, None, :], (3, batch, seq))
