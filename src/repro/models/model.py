"""Model driver: loss, train_step, serve_step — the functions the launcher,
FL runtime, and dry-run all lower.

``train_step`` is a plain function of (state, batch) so it can be jitted with
explicit in/out shardings by ``repro.launch``; the FL client reuses the same
loss through ``repro.core.client.LocalUpdate``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import Optimizer, apply_updates

Params = Dict[str, Any]


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  z_loss: float = 0.0) -> jax.Array:
    """Mean next-token cross entropy; logits (B,S,V), labels (B,S)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        return jnp.mean(nll)
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Causal LM loss: predict batch["labels"] (pre-shifted by the pipeline)."""
    logits, aux = T.forward(params, cfg, batch)
    ce = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


def init_train_state(key, cfg: ModelConfig, optimizer: Optimizer) -> Params:
    params = T.init_params(key, cfg)
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, n_micro: int = 1,
                    batch_axes=None):
    """Build the train step. ``n_micro > 1`` splits the global batch into
    microbatches scanned with gradient accumulation — required at production
    scale so (B, S, vocab) logits never materialize for the full batch.

    ``batch_axes``: mesh axis (or tuple) the batch dim is sharded over; the
    microbatch split re-constrains each slice's batch axis to it (without the
    constraint GSPMD can replicate the reshaped batch, blowing up remat
    buffers 8x — see EXPERIMENTS.md §Dry-run)."""

    def _grads(params, batch):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        (loss, metrics), grads = grad_fn(params, cfg, batch)
        return grads, metrics

    def _constrain(k, x):
        if batch_axes is None:
            return x
        from jax.sharding import PartitionSpec as P
        if k == "positions" and x.ndim == 4:       # (n_micro, 3, B, S)
            spec = P(None, None, batch_axes, None)
        else:                                      # (n_micro, B, ...)
            spec = P(None, batch_axes, *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(x, spec)

    def train_step(state: Params, batch: Dict[str, jax.Array]):
        params = state["params"]
        if n_micro == 1:
            grads, metrics = _grads(params, batch)
        else:
            def split(x):
                if x.ndim == 3 and x.shape[0] == 3:  # (3,B,S) mrope positions
                    return x.transpose(1, 0, 2).reshape(
                        n_micro, x.shape[1] // n_micro, 3, x.shape[2]
                    ).transpose(0, 2, 1, 3)
                if x.ndim >= 2 and x.shape[0] % n_micro == 0:
                    return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])
                raise ValueError(f"cannot microbatch shape {x.shape}")

            micro = {k: _constrain(k, split(v)) for k, v in batch.items()}

            def acc_step(carry, mb):
                g_acc = carry
                g, metrics = _grads(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32) / n_micro, g_acc, g)
                return g_acc, metrics

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(acc_step, g0, micro)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], ms)
        updates, new_opt = optimizer.update(grads, state["opt"], state["params"])
        new_params = apply_updates(state["params"], updates)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def make_prefill_logits_last(cfg: ModelConfig):
    """Prefill for serving: full-sequence forward, last-token logits only
    (production engines never materialize (B, S, V) prefill logits)."""

    def prefill(params: Params, batch: Dict[str, jax.Array]):
        from repro.models import transformer as TT
        if "input_embeds" in batch:
            x = batch["input_embeds"].astype(cfg.param_dtype)
            B, S = x.shape[:2]
        else:
            tokens = batch["tokens"]
            B, S = tokens.shape
            x = TT.embed_tokens(params, cfg, tokens)
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        rope_cs = TT.make_rope_cs(cfg, positions)
        cross_kv = None
        if cfg.is_encdec:
            x = x + TT.sinusoidal_pos(jnp.arange(S), cfg.d_model).astype(x.dtype)
            enc_out = TT.encode_audio(params, cfg, batch["frames"])
            import repro.models.layers as L
            cross_kv = jax.vmap(
                lambda lp: L.project_cross_kv(lp["cross_attn"], cfg, enc_out)
            )(params["layers"])
        x, _, _ = TT.run_stack(params["layers"], cfg, x, rope_cs=rope_cs,
                               causal=True, cross_kv=cross_kv)
        import repro.models.layers as L
        x = L.norm_fwd(params["final_norm"], cfg, x[:, -1:, :])
        return TT.unembed(params, cfg, x)[:, 0, :]

    return prefill


def make_prefill(cfg: ModelConfig):
    def prefill(params: Params, batch: Dict[str, jax.Array]):
        logits, _ = T.forward(params, cfg, batch)
        return logits

    return prefill


def make_serve_step(cfg: ModelConfig):
    def serve_step(params: Params, caches: Params, tokens: jax.Array,
                   cache_pos: jax.Array, cross_kv: Optional[Params] = None):
        return T.serve_step(params, cfg, caches, tokens, cache_pos, cross_kv)

    return serve_step
