"""Full-model assembly: embeddings, scanned layer stacks, heads, KV caches.

Families share one parameter layout::

    {"embed": (V, d),
     "layers": <stacked per-layer pytree, leading axis L>,
     "final_norm": {...},
     "lm_head": (d, V)            # absent when tie_embeddings
     "encoder": {...}}            # audio (whisper) only

Layers are initialized with ``jax.vmap`` over per-layer keys and applied with
``jax.lax.scan``, so the HLO is depth-independent (one layer body + loop).
Decode caches are stacked along the same leading L axis and scanned jointly.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# Per-layer init / apply
# --------------------------------------------------------------------------- #


def init_layer(key, cfg: ModelConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 8)
    if cfg.block_type == "rwkv6":
        return {
            "norm1": L.init_norm(cfg, cfg.d_model),
            "norm2": L.init_norm(cfg, cfg.d_model),
            "rwkv": L.init_rwkv6(ks[0], cfg),
        }
    p: Params = {
        "norm1": L.init_norm(cfg, cfg.d_model),
        "norm2": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(ks[0], cfg),
    }
    if cfg.block_type == "hybrid":
        p["mamba"] = L.init_mamba(ks[1], cfg)
    if cross:
        p["norm_cross"] = L.init_norm(cfg, cfg.d_model)
        p["cross_attn"] = L.init_attention(ks[2], cfg)
    if cfg.moe is not None:
        p["moe"] = L.init_moe(ks[3], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[3], cfg)
    return p


def layer_fwd(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    rope_cs=None,
    causal: bool = True,
    cache: Optional[Params] = None,
    cache_pos=None,
    cross_kv=None,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}

    if cfg.block_type == "rwkv6":
        tm_state = None if cache is None else {"x_prev": cache["tm_x"], "S": cache["S"]}
        h = L.norm_fwd(p["norm1"], cfg, x)
        o, tm_new = L.rwkv6_time_mix(p["rwkv"], cfg, h, tm_state)
        x = x + o
        h = L.norm_fwd(p["norm2"], cfg, x)
        cm_prev = None if cache is None else cache["cm_x"]
        o, cm_new = L.rwkv6_channel_mix(p["rwkv"], cfg, h, cm_prev)
        x = x + o
        if cache is not None:
            new_cache = {"tm_x": tm_new["x_prev"], "S": tm_new["S"], "cm_x": cm_new}
        return x, (new_cache or None), aux

    h = L.norm_fwd(p["norm1"], cfg, x)
    attn_cache = None
    if cache is not None and "k" in cache:
        attn_cache = {"k": cache["k"], "v": cache["v"]}
    o_attn, attn_new = L.attention_fwd(
        p["attn"], cfg, h,
        rope_cs=rope_cs, causal=causal, window=cfg.sliding_window,
        cache=attn_cache, cache_pos=cache_pos,
    )
    if cfg.block_type == "hybrid":
        m_state = None
        if cache is not None:
            m_state = {"conv": cache["conv"], "h": cache["h"]}
        o_mamba, m_new = L.mamba_fwd(p["mamba"], cfg, h, m_state)
        x = x + 0.5 * (o_attn + o_mamba)
        if cache is not None:
            new_cache.update({"conv": m_new["conv"], "h": m_new["h"]})
    else:
        x = x + o_attn
    if attn_new is not None:
        new_cache.update(attn_new)

    if cross_kv is not None:
        h = L.norm_fwd(p["norm_cross"], cfg, x)
        o, _ = L.attention_fwd(p["cross_attn"], cfg, h, cross_kv=cross_kv)
        x = x + o

    h = L.norm_fwd(p["norm2"], cfg, x)
    if cfg.moe is not None:
        moe = (L.moe_fwd_shardmap if cfg.moe_impl == "shard_map"
               else L.moe_fwd)
        o, aux = moe(p["moe"], cfg, h)
    else:
        o = L.mlp_fwd(p["mlp"], cfg, h)
    x = x + o
    return x, (new_cache or None), aux


# --------------------------------------------------------------------------- #
# Whole-model init
# --------------------------------------------------------------------------- #


def init_params(key, cfg: ModelConfig) -> Params:
    k_emb, k_layers, k_head, k_enc = jax.random.split(key, 4)
    dt = cfg.param_dtype
    params: Params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32)
                  * 0.02).astype(dt),
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }
    cross = cfg.is_encdec
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: init_layer(k, cfg, cross=cross))(layer_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(k_head, cfg.d_model, (cfg.d_model, cfg.vocab_size), dt)
    if cfg.is_encdec:
        enc_cfg = cfg
        ekeys = jax.random.split(k_enc, cfg.encoder.n_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: init_layer(k, enc_cfg, cross=False))(ekeys),
            "final_norm": L.init_norm(cfg, cfg.d_model),
        }
    return params


def param_count(params: Params) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))


# --------------------------------------------------------------------------- #
# Positional helpers
# --------------------------------------------------------------------------- #


def sinusoidal_pos(positions: jax.Array, d: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings; positions (..., S) -> (..., S, d)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def make_rope_cs(cfg: ModelConfig, positions: jax.Array):
    if cfg.rope == "none":
        return None
    if cfg.rope == "mrope":
        if positions.ndim == 2:  # (B,S) text-only: all three components equal
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return L.mrope_cos_sin(positions, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
    return L.rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)


# --------------------------------------------------------------------------- #
# Scanned stacks
# --------------------------------------------------------------------------- #


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def run_stack(
    stacked: Params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    rope_cs=None,
    causal: bool = True,
    caches: Optional[Params] = None,
    cache_pos=None,
    cross_kv=None,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Scan the layer stack. caches/cross_kv are stacked along axis 0 (L)."""

    def body(carry, xs):
        x, aux = carry
        lp = xs["p"]
        lc = xs.get("c")
        ckv = xs.get("x_kv")
        ckv = (ckv["k"], ckv["v"]) if ckv is not None else None
        if cfg.act_batch_axes is not None or cfg.act_seq_axis is not None:
            # pin the residual stream's batch sharding (GSPMD can otherwise
            # flip activations to d_model-sharded/batch-replicated, blowing
            # up remat buffers by the data-axis size); optionally shard the
            # seq dim on the model axis between layers (Megatron-style SP)
            from jax.sharding import PartitionSpec as P
            x = jax.lax.with_sharding_constraint(
                x, P(cfg.act_batch_axes, cfg.act_seq_axis, None))
        x, new_c, a = layer_fwd(
            lp, cfg, x, rope_cs=rope_cs, causal=causal,
            cache=lc, cache_pos=cache_pos, cross_kv=ckv,
        )
        return (x, aux + a), new_c

    xs: Dict[str, Any] = {"p": stacked}
    if caches is not None:
        xs["c"] = caches
    if cross_kv is not None:
        xs["x_kv"] = {"k": cross_kv[0], "v": cross_kv[1]}
    body = _maybe_remat(body, cfg)
    if cfg.probe_unroll:
        # roofline probe: explicit python loop so every layer's ops appear in
        # the HLO (cost_analysis does not multiply while-loop bodies)
        carry = (x, jnp.zeros((), jnp.float32))
        ys = []
        for li in range(cfg.n_layers):
            xsl = jax.tree_util.tree_map(lambda a: a[li], xs)
            carry, y = body(carry, xsl)
            ys.append(y)
        (x, aux) = carry
        new_caches = (jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
                      if ys and ys[0] is not None else None)
        return x, new_caches, aux
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_caches, aux


# --------------------------------------------------------------------------- #
# Forward passes
# --------------------------------------------------------------------------- #


def embed_tokens(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embed"], tokens, axis=0)


def unembed(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def encode_audio(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over (stubbed) frame embeddings (B, n_ctx, d)."""
    pos = jnp.arange(frames.shape[1])
    x = frames + sinusoidal_pos(pos, cfg.d_model).astype(frames.dtype)
    x, _, _ = run_stack(params["encoder"]["layers"], cfg, x, causal=False)
    return L.norm_fwd(params["encoder"]["final_norm"], cfg, x)


def forward(
    params: Params,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train / prefill). Returns (logits, aux_loss).

    batch keys: "tokens" (B,S) int32; optionally "input_embeds" (B,S,d) which
    *overrides* token embedding (vlm stub), "positions" ((B,S) or (3,B,S) for
    mrope), "frames" (B,n_ctx,d) for the audio encoder stub.
    """
    if "input_embeds" in batch:
        x = batch["input_embeds"].astype(cfg.param_dtype)
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_tokens(params, cfg, tokens)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    rope_cs = make_rope_cs(cfg, positions)
    if cfg.rope == "none" and not cfg.is_encdec:
        pass  # rwkv needs no positions
    if cfg.is_encdec:
        x = x + sinusoidal_pos(jnp.arange(S), cfg.d_model).astype(x.dtype)
        enc_out = encode_audio(params, cfg, batch["frames"])
        # per-layer cross K/V (stacked): vmap projection over layers
        cross_kv = jax.vmap(
            lambda lp: L.project_cross_kv(lp["cross_attn"], cfg, enc_out)
        )(params["layers"])
    else:
        cross_kv = None
    x, _, aux = run_stack(
        params["layers"], cfg, x, rope_cs=rope_cs, causal=True, cross_kv=cross_kv)
    x = L.norm_fwd(params["final_norm"], cfg, x)
    return unembed(params, cfg, x), aux


# --------------------------------------------------------------------------- #
# Decode (serve_step)
# --------------------------------------------------------------------------- #


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> Params:
    """Stacked (leading L axis) decode cache with correct per-family shapes."""
    Lx = cfg.n_layers
    dt = cfg.param_dtype
    if cfg.block_type == "rwkv6":
        H, N = cfg.n_rwkv_heads, cfg.rwkv_head_size
        return {
            "tm_x": jnp.zeros((Lx, batch_size, cfg.d_model), dt),
            "S": jnp.zeros((Lx, batch_size, H, N, N), jnp.float32),
            "cm_x": jnp.zeros((Lx, batch_size, cfg.d_model), dt),
        }
    kv_len = max_len if cfg.sliding_window is None else min(max_len, _window_cache_len(cfg, max_len))
    c = {
        "k": jnp.zeros((Lx, batch_size, kv_len, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((Lx, batch_size, kv_len, cfg.n_kv_heads, cfg.head_dim), dt),
    }
    if cfg.block_type == "hybrid":
        di = cfg.ssm_expand * cfg.d_model
        c["conv"] = jnp.zeros((Lx, batch_size, 3, di), dt)
        c["h"] = jnp.zeros((Lx, batch_size, di, cfg.ssm_state), jnp.float32)
    return c


def _window_cache_len(cfg: ModelConfig, max_len: int) -> int:
    # Baseline keeps the full-length cache (paper-faithful simplicity); the
    # windowed-cache optimization is applied in the perf pass via configs.
    return max_len


def constrain_cache(caches: Params, cfg: ModelConfig) -> Params:
    """Pin the k/v leaves' (L, B, S, KV, hd) sharding per cfg.cache_*_axes —
    GSPMD otherwise shards the stacked L dim and pays an involuntary full
    rematerialization on every per-layer slice inside the scan."""
    if cfg.cache_batch_axes is None and cfg.cache_seq_axes is None:
        return caches
    from jax.sharding import PartitionSpec as P

    def leaf(kp, v):
        name = str(kp[-1].key) if hasattr(kp[-1], "key") else ""
        if name in ("k", "v") and v.ndim == 5:
            return jax.lax.with_sharding_constraint(
                v, P(None, cfg.cache_batch_axes, cfg.cache_seq_axes,
                     None, None))
        return v

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf(kp, v) for kp, v in flat])


def serve_step(
    params: Params,
    cfg: ModelConfig,
    caches: Params,
    tokens: jax.Array,           # (B, 1)
    cache_pos: jax.Array,        # scalar int32: current position
    cross_kv: Optional[Params] = None,
) -> Tuple[jax.Array, Params]:
    """One decode step: embed token, run stack against the cache, unembed."""
    caches = constrain_cache(caches, cfg)
    x = embed_tokens(params, cfg, tokens)
    B = tokens.shape[0]
    positions = jnp.broadcast_to(cache_pos[None, None], (B, 1))
    rope_cs = make_rope_cs(cfg, positions)
    if cfg.is_encdec:
        x = x + sinusoidal_pos(positions, cfg.d_model).astype(x.dtype)
    ckv = None
    if cross_kv is not None:
        ckv = (cross_kv["k"], cross_kv["v"])
    x, new_caches, _ = run_stack(
        params["layers"], cfg, x, rope_cs=rope_cs, causal=True,
        caches=caches, cache_pos=cache_pos, cross_kv=ckv)
    new_caches = constrain_cache(new_caches, cfg)
    x = L.norm_fwd(params["final_norm"], cfg, x)
    logits = unembed(params, cfg, x)
    return logits, new_caches


def precompute_cross_kv(params: Params, cfg: ModelConfig, frames: jax.Array) -> Params:
    enc_out = encode_audio(params, cfg, frames)
    k, v = jax.vmap(
        lambda lp: L.project_cross_kv(lp["cross_attn"], cfg, enc_out)
    )(params["layers"])
    return {"k": k, "v": v}
