"""Pytree checkpointing (npz-based; the container has no orbax).

Saves any pytree of arrays by flattening with ``jax.tree_util`` key paths as
npz keys. Server state (round counter, metrics, switch monitor) rides along
as a JSON sidecar.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(path: str, tree: Any, meta: Optional[Dict] = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_key_str(kp): np.asarray(v) for kp, v in flat}
    np.savez(path, **arrays)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes must match)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, leaf in flat:
        arr = data[_key_str(kp)]
        assert arr.shape == tuple(leaf.shape), (_key_str(kp), arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_meta(path: str) -> Optional[Dict]:
    mp = path + ".meta.json"
    if os.path.exists(mp):
        with open(mp) as f:
            return json.load(f)
    return None
