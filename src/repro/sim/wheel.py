"""Bucketed time wheel: struct-of-arrays pending-event storage.

The heap engine pays O(log n) Python-object work per event push/pop. The
wheel replaces that with integer-bucketed array chunks: a dispatch wave of
100k jobs lands as ONE chunk append (split across the buckets its upload
times hash to), and draining a bucket is one concatenate + lexsort. Event
ordering — ``(time, seq)``, identical to the heap — is restored per bucket
by the sort, so wheel resolution ``dt`` is a pure throughput knob: any
``dt`` replays the exact same event sequence (``tests/test_sim_vec.py``
runs the equivalence suite at several resolutions).

Bucket occupancy is tracked by a lazy min-heap of bucket indices (a few
ints per *bucket*, not per event); chunks are parallel arrays
``(time, seq, kind, client, job, force)`` — ``job`` doubles as the generic
integer payload, ``force`` is only meaningful for dispatches.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

Chunk = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray,
              np.ndarray]


def _empty_chunk() -> Chunk:
    z = np.empty(0)
    return (z, np.empty(0, np.int64), np.empty(0, np.int8),
            np.empty(0, np.int64), np.empty(0, np.int64),
            np.empty(0, bool))


def concat_chunks(chunks: List[Chunk]) -> Chunk:
    if len(chunks) == 1:
        return chunks[0]
    return tuple(np.concatenate([c[i] for c in chunks])  # type: ignore
                 for i in range(6))


def _time_order(t: np.ndarray) -> np.ndarray:
    """Permutation realizing the ``(time, seq)`` order of a batch whose
    storage order is seq order (the engine's invariant: one globally
    monotone counter assigned in array order). A stable time sort is
    exactly that — but numpy's stable float sort (timsort) is ~2x slower
    than introsort, so try the unstable sort first: with no duplicate
    times the permutations coincide, and duplicates (zero-variance
    fleets, grid ticks) are caught by one equality pass and re-sorted
    stably."""
    order = np.argsort(t)
    ts = t[order]
    if bool((ts[1:] == ts[:-1]).any()):
        return np.argsort(t, kind="stable")
    return order


def sort_chunk(c: Chunk) -> Chunk:
    """Order by ``(time, seq)`` — the heap engine's exact tie-break.

    A single STABLE sort on time suffices: the engine's seq counter is
    globally monotone and assigned in array order within every push, so
    storage order is already seq order — stability preserves it for
    time-ties, which is exactly the ``(time, seq)`` lexsort."""
    t = c[0]
    if len(t) < 2 or bool(np.all(t[1:] >= t[:-1])):
        return c                   # already time-sorted (ties: storage
    order = _time_order(t)         # order IS seq order)
    return tuple(a[order] for a in c)  # type: ignore


def merge_chunks(a: Chunk, b: Chunk) -> Chunk:
    """Linear merge of two (time, seq)-sorted chunks where every seq in
    ``b`` exceeds every seq in ``a`` (b was pushed later) — time-ties land
    a-first, which is exactly the seq tie-break."""
    na, nb = len(a[0]), len(b[0])
    if na == 0:
        return b
    if nb == 0:
        return a
    pos_a = np.arange(na) + np.searchsorted(b[0], a[0], side="left")
    pos_b = np.arange(nb) + np.searchsorted(a[0], b[0], side="right")
    out = tuple(np.empty(na + nb, x.dtype) for x in a)
    for o, x, y in zip(out, a, b):
        o[pos_a] = x
        o[pos_b] = y
    return out  # type: ignore


class TimeWheel:
    """Integer-bucketed event store with batched push and bucket drain."""

    def __init__(self, dt: float = 1.0):
        assert dt > 0
        self.dt = float(dt)
        self._buckets: Dict[int, List[Chunk]] = {}
        self._order: List[int] = []          # lazy min-heap of bucket ids
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def push(self, time: np.ndarray, seq: np.ndarray, kind: np.ndarray,
             client: np.ndarray, job: np.ndarray,
             force: np.ndarray) -> None:
        """Append a batch of events (parallel arrays, any order)."""
        n = len(time)
        if n == 0:
            return
        self._n += n
        b = np.floor_divide(time, self.dt).astype(np.int64)
        chunk = (time, seq, kind, client, job, force)
        if n == 1 or b[0] == b[-1] and (b[0] == b).all():
            # chunks are stored pre-sorted so ``take`` can fold them with
            # a linear merge instead of re-sorting the concatenation
            self._add(int(b[0]), chunk if n == 1 else sort_chunk(chunk))
            return
        # sort the batch by TIME (stable, so storage order == seq order is
        # kept for ties): buckets become contiguous slices, and every slice
        # lands pre-sorted — draining it skips the sort entirely
        if not bool(np.all(time[1:] >= time[:-1])):
            order = _time_order(time)
            b = b[order]
            chunk = tuple(a[order] for a in chunk)
        cuts = np.flatnonzero(np.diff(b)) + 1
        for lo, hi in zip(np.r_[0, cuts], np.r_[cuts, n]):
            self._add(int(b[lo]), tuple(a[lo:hi] for a in chunk))

    def _add(self, b: int, chunk: Chunk) -> None:
        got = self._buckets.get(b)
        if got is None:
            self._buckets[b] = [chunk]
            heapq.heappush(self._order, b)
        else:
            got.append(chunk)

    def next_bucket(self) -> Optional[int]:
        """Smallest non-empty bucket id (None when drained)."""
        while self._order:
            b = self._order[0]
            if b in self._buckets:
                return b
            heapq.heappop(self._order)        # lazily drop consumed ids
        return None

    def take(self, b: int) -> Chunk:
        """Remove and return bucket ``b``'s events sorted by (time, seq).

        Every stored chunk is individually sorted (``push`` guarantees it)
        and the list is in push order — later chunks carry strictly larger
        seqs — so a left fold of ``merge_chunks`` reconstructs the exact
        ``(time, seq)`` order in linear time, no re-sort."""
        chunks = self._buckets.pop(b, None)
        if chunks is None:
            return _empty_chunk()
        out = chunks[0]
        for c in chunks[1:]:
            out = merge_chunks(out, c)
        self._n -= len(out[0])
        return out

    def has_new(self, b: int) -> bool:
        """Did anything land in bucket ``b`` since it was taken? (Handlers
        may schedule zero-delay events into the bucket being drained.)"""
        return b in self._buckets

    def scan_kind(self, code: int) -> bool:
        """Any pending event of this kind? (Resume-time timer checks.)"""
        return any((c[2] == code).any()
                   for chunks in self._buckets.values() for c in chunks)
