"""Named, seed-reproducible simulation scenarios.

A scenario is a builder ``fn(seed, horizon, **overrides) -> SimRun`` wiring
data + model + ``Server`` + device fleet + trigger policy into a ready
``SimEngine``. Register new ones with ``@register("name")`` — the CLI
(``python -m repro.sim``), the examples and the benchmarks all resolve
scenarios by name from this registry, so adding a workload is one decorated
function.

All stock scenarios share one small-scale FL setup (synthetic feature data,
Dirichlet label skew, MLP — seconds-scale on CPU) and differ only in device
models and trigger policy; device speed tiers are assigned to the top
holders of the target class so data and device heterogeneity stay
*intertwined* exactly as in the paper's schedule-based harness.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.core.client import LocalProgram
from repro.core.gradient_inversion import GIConfig
from repro.core.quantize import QuantConfig
from repro.core.server import FLConfig, Server
from repro.data.partition import (client_label_histograms, dirichlet_partition,
                                  pad_client_shards)
from repro.data.staleness import intertwined_schedule
from repro.data.synthetic import make_feature_dataset
from repro.models.small import mlp3
from repro.sim.bridge import RecordingAggregator, ServerBridge
from repro.sim.devices import (LatencyDist, fleet_from_schedule,
                               intertwined_fleet)
from repro.sim.engine import SimEngine
from repro.sim.engine_vec import VecEngine
from repro.sim.policies import FedBuffK, PureAsync, SemiSyncDeadline

N_CLASSES, N_FEATURES, TARGET = 5, 12, 2

# engine="vec" is the default (struct-of-arrays, batched waves); the heap
# engine stays available as the per-event oracle — the same
# oracle-behind-a-flag pattern as ``FLConfig(fused_step=False)``
ENGINES = {"heap": SimEngine, "vec": VecEngine}


@dataclasses.dataclass
class SimRun:
    name: str
    engine: Any                # SimEngine or VecEngine (same surface)
    server: Server
    meta: Dict[str, Any]

    def run(self) -> Dict[str, Any]:
        summary = self.engine.run()
        summary["final_acc"] = float(self.server.evaluate()[0])
        summary["scenario"] = self.name
        summary["realized_taus"] = {
            int(c): list(map(int, v))
            for c, v in sorted(self.engine.realized.items())}
        summary["server"] = self.server.summary()
        summary.update(self.meta)
        return summary


_REGISTRY: Dict[str, Callable[..., SimRun]] = {}
_DOCS: Dict[str, str] = {}


def register(name: str, doc: str = ""):
    def deco(fn):
        _REGISTRY[name] = fn
        _DOCS[name] = doc or (fn.__doc__ or "").strip().splitlines()[0]
        return fn
    return deco


def names() -> List[str]:
    return sorted(_REGISTRY)


def describe() -> Dict[str, str]:
    return {n: _DOCS[n] for n in names()}


def build(name: str, seed: int = 0, horizon: Optional[float] = None,
          **overrides) -> SimRun:
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; have {names()}")
    kw = dict(overrides)
    if horizon is not None:
        kw["horizon"] = horizon
    return _REGISTRY[name](seed=seed, **kw)


# --------------------------------------------------------------------------- #
# Shared small-scale FL setup
# --------------------------------------------------------------------------- #


def fl_setup(seed: int, strategy: str = "ours", n_clients: int = 10,
             n_slow: int = 3, tau=3, gi_iters: int = 8,
             eval_every: int = 5, mesh=None, segment_iters: int = 0,
             max_lanes: int = 0, fused_step: bool = True,
             quant_bits: int = 32):
    """``mesh`` is a (pod, data) cohort mesh from
    ``repro.launch.mesh.make_server_mesh``: the scenario's Server then runs
    its batched hot path sharded (every stock scenario accepts ``mesh=`` as
    an override, and ``repro.sweep`` passes it when fanning seeds).

    ``segment_iters``/``max_lanes`` select the segmented continuous-batching
    GI executor (the resident ``LanePool``) and ``fused_step=False`` the
    per-client loop oracle — ``repro.service`` builds both its streaming
    server and its bit-for-bit replay oracle through these overrides.

    ``quant_bits`` (32/8/4) selects the upload wire format
    (``core.quantize``; 32 = the exact fp32 identity) — ``repro.sweep
    --quant-bits`` fans this axis and every stock scenario forwards it."""
    x, y = make_feature_dataset(20, n_classes=N_CLASSES,
                                n_features=N_FEATURES, seed=seed)
    tx, ty = make_feature_dataset(8, n_classes=N_CLASSES,
                                  n_features=N_FEATURES, seed=seed + 99)
    idx = dirichlet_partition(y, n_clients, alpha=0.1, seed=seed)
    cx, cy, cm = pad_client_shards(x, y, idx, m=16)
    hist = client_label_histograms(y, idx, N_CLASSES)
    sched = intertwined_schedule(hist, TARGET, n_slow=n_slow, tau=tau)
    prog = LocalProgram(steps=5, lr=0.1, momentum=0.5)
    cfg = FLConfig(strategy=strategy, rounds=0,
                   gi=GIConfig(n_rec=8, iters=gi_iters, lr=0.1,
                               segment_iters=segment_iters,
                               max_lanes=max_lanes),
                   fused_step=fused_step,
                   eval_every=eval_every, seed=seed,
                   quant=QuantConfig(bits=int(quant_bits)))
    server = Server(mlp3(n_features=N_FEATURES, n_classes=N_CLASSES,
                         hidden=24),
                    prog, cfg, cx, cy, cm, sched, tx, ty, mesh=mesh)
    return server, hist, sched


# historic private name, kept for existing callers (benchmarks, tests)
_fl_setup = fl_setup


def _make_run(name, seed, server, fleet, policy, horizon, eval_every_time,
              eval_mode="server", engine="vec", **meta) -> SimRun:
    eng = ENGINES[engine](fleet, policy, ServerBridge(server, eval_mode),
                          seed=seed, horizon=horizon,
                          eval_every_time=eval_every_time)
    meta.update({"policy": policy.name, "seed": seed, "horizon": horizon,
                 "strategy": server.cfg.strategy, "engine": engine,
                 "mesh_shards": server._n_shards})
    return SimRun(name, eng, server, meta)


# per-scenario device fleets, shared by the full-FL builders below and the
# server-less ``engine_only`` path (equivalence tests, throughput benchmarks)


def _fleet_semi_sync(hist):
    return intertwined_fleet(
        hist, TARGET, n_slow=3,
        slow=LatencyDist("lognormal", 2.8, 0.35),
        fast=LatencyDist("lognormal", 0.45, 0.25),
        network=LatencyDist("lognormal", 0.05, 0.3),
        dropout_prob=0.01, downtime=LatencyDist("fixed", 2.0))


def _fleet_pure_async(hist):
    return intertwined_fleet(
        hist, TARGET, n_slow=3,
        slow=LatencyDist("pareto", 1.5, 0.6),
        fast=LatencyDist("pareto", 0.3, 0.3),
        network=LatencyDist("fixed", 0.02))


def _fleet_fedbuff(hist):
    return intertwined_fleet(
        hist, TARGET, n_slow=3,
        slow=LatencyDist("lognormal", 2.2, 0.5),
        fast=LatencyDist("lognormal", 0.4, 0.3),
        network=LatencyDist("lognormal", 0.05, 0.3),
        dropout_prob=0.02, downtime=LatencyDist("fixed", 1.5))


def _fleet_heavy_churn(hist):
    return intertwined_fleet(
        hist, TARGET, n_slow=3,
        slow=LatencyDist("lognormal", 2.0, 0.6),
        fast=LatencyDist("lognormal", 0.5, 0.4),
        dropout_prob=0.2, slow_dropout_prob=0.35,
        downtime=LatencyDist("lognormal", 1.0, 0.5))


# engine-only wiring per stock scenario: (fleet builder taking hist,
# policy factory, default horizon, eval interval divisor or None)
_ENGINE_PARTS = {
    "degenerate_sync": (None, lambda: SemiSyncDeadline(1.0, pipelined=True),
                        8.0, None),
    "semi_sync_deadline": (_fleet_semi_sync, lambda: SemiSyncDeadline(1.0),
                           12.0, 4),
    "pure_async": (_fleet_pure_async, PureAsync, 10.0, 4),
    "fedbuff_k4": (_fleet_fedbuff, lambda: FedBuffK(4), 12.0, 4),
    "heavy_churn": (_fleet_heavy_churn, lambda: FedBuffK(3), 12.0, 4),
}


def engine_only(name: str, seed: int = 0, horizon: Optional[float] = None,
                engine: str = "vec", policy_wrap: Optional[Callable] = None,
                **engine_kw):
    """A stock scenario's fleet + policy on a ``RecordingAggregator`` —
    the full event process without the FL data/model stack. This is what
    the heap-vs-vec equivalence tests and the events/sec benchmarks drive:
    identical trace digests here certify identical cohorts everywhere.

    ``policy_wrap`` decorates the trigger policy before the engine is
    built (the engine captures policy capability flags at construction, so
    wrapping after the fact would be unsound) — ``repro.service`` uses it
    to record the arrival process as a replayable upload log."""
    fleet_fn, policy_fn, default_h, eval_div = _ENGINE_PARTS[name]
    _, y = make_feature_dataset(20, n_classes=N_CLASSES,
                                n_features=N_FEATURES, seed=seed)
    idx = dirichlet_partition(y, 10, alpha=0.1, seed=seed)
    hist = client_label_histograms(y, idx, N_CLASSES)
    if fleet_fn is None:       # degenerate_sync: fleet from the schedule
        sched = intertwined_schedule(hist, TARGET, n_slow=3, tau=[2, 3, 2])
        fleet = fleet_from_schedule(sched.staleness, round_len=1.0)
    else:
        fleet = fleet_fn(hist)
    horizon = default_h if horizon is None else float(horizon)
    eval_every = None if eval_div is None else horizon / eval_div
    policy = policy_fn()
    if policy_wrap is not None:
        policy = policy_wrap(policy)
    return ENGINES[engine](fleet, policy, RecordingAggregator(),
                           seed=seed, horizon=horizon,
                           eval_every_time=eval_every, **engine_kw)


# --------------------------------------------------------------------------- #
# Stock scenarios
# --------------------------------------------------------------------------- #


@register("degenerate_sync",
          "zero-variance oracle: replays the round-synchronous Server")
def degenerate_sync(seed: int = 0, horizon: float = 8.0, strategy: str = "ours",
                    tau=None, engine: str = "vec", **kw) -> SimRun:
    """Deterministic latencies + pipelined deadline == the sync harness."""
    tau = tau if tau is not None else [2, 3, 2]
    server, hist, sched = _fl_setup(seed, strategy=strategy, tau=tau, **kw)
    fleet = fleet_from_schedule(sched.staleness, round_len=1.0)
    policy = SemiSyncDeadline(round_len=1.0, pipelined=True)
    return _make_run("degenerate_sync", seed, server, fleet, policy,
                     horizon, eval_every_time=None, engine=engine)


@register("semi_sync_deadline",
          "lognormal device tiers, aggregate at a fixed deadline")
def semi_sync_deadline(seed: int = 0, horizon: float = 12.0,
                       strategy: str = "ours", round_len: float = 1.0,
                       engine: str = "vec", **kw) -> SimRun:
    """Semi-synchronous FL: a deadline every round_len; stragglers arrive
    rounds late with lognormal jitter, slow tier correlated with the target
    class."""
    server, hist, _ = _fl_setup(seed, strategy=strategy, **kw)
    fleet = _fleet_semi_sync(hist)
    policy = SemiSyncDeadline(round_len=round_len)
    return _make_run("semi_sync_deadline", seed, server, fleet, policy,
                     horizon, eval_every_time=horizon / 4, engine=engine)


@register("pure_async",
          "Pareto-tail latencies, aggregate on every arrival (FedAsync-style)")
def pure_async(seed: int = 0, horizon: float = 10.0, strategy: str = "ours",
               engine: str = "vec", **kw) -> SimRun:
    """Pure async: unbounded Pareto tails make realized staleness unlimited —
    the regime the paper's title claims robustness to."""
    server, hist, _ = _fl_setup(seed, strategy=strategy, **kw)
    fleet = _fleet_pure_async(hist)
    return _make_run("pure_async", seed, server, fleet, PureAsync(),
                     horizon, eval_every_time=horizon / 4, engine=engine)


@register("fedbuff_k4",
          "buffered async: aggregate every K=4 arrivals (FedBuff-style)")
def fedbuff_k4(seed: int = 0, horizon: float = 12.0, strategy: str = "ours",
               k: int = 4, engine: str = "vec", **kw) -> SimRun:
    """Buffered async: arrivals accumulate; every K-th distinct client
    triggers aggregation, so each cohort mixes base versions."""
    server, hist, _ = _fl_setup(seed, strategy=strategy, **kw)
    fleet = _fleet_fedbuff(hist)
    return _make_run("fedbuff_k4", seed, server, fleet, FedBuffK(k),
                     horizon, eval_every_time=horizon / 4, engine=engine)


@register("heavy_churn",
          "high dropout/rejoin churn under a FedBuff trigger")
def heavy_churn(seed: int = 0, horizon: float = 12.0, strategy: str = "ours",
                engine: str = "vec", **kw) -> SimRun:
    """Stress the dropout/rejoin machinery: a fifth of jobs die mid-flight."""
    server, hist, _ = _fl_setup(seed, strategy=strategy, **kw)
    fleet = _fleet_heavy_churn(hist)
    return _make_run("heavy_churn", seed, server, fleet, FedBuffK(3),
                     horizon, eval_every_time=horizon / 4, engine=engine)
