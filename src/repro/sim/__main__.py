"""CLI for named simulation scenarios.

    PYTHONPATH=src python -m repro.sim --list
    PYTHONPATH=src python -m repro.sim --scenario fedbuff_k4 --seed 0
    PYTHONPATH=src python -m repro.sim --scenario pure_async --horizon 6 \
        --strategy unweighted --out /tmp/sim.json

Prints one JSON summary (event/aggregation counts, dropout bookkeeping,
realized staleness, eval curve, final accuracy, trace digest). The trace
digest is the replay fingerprint: same scenario + seed => same digest.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.sim import scenarios


def _gi_iters(v: str) -> int:
    iv = int(v)
    if iv < 1:
        raise argparse.ArgumentTypeError(
            "--gi-iters must be >= 1 (to skip inversion entirely use "
            "--strategy unweighted)")
    return iv


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sim")
    ap.add_argument("--scenario", help="named scenario (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--horizon", type=float, default=None,
                    help="virtual-clock end time (scenario default if unset)")
    ap.add_argument("--strategy", default=None,
                    help="FL server strategy override (default: scenario's)")
    ap.add_argument("--gi-iters", type=_gi_iters, default=None)
    ap.add_argument("--mesh", type=int, default=None,
                    help="shard the server hot path over the first N devices "
                         "((pod, data) cohort mesh; default: unsharded)")
    ap.add_argument("--engine", choices=("vec", "heap"), default=None,
                    help="event engine: vectorized time-wheel (default) or "
                         "the per-event heap oracle — same trace digest")
    ap.add_argument("--out", default=None, help="also write JSON here")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable tracing; write a Chrome trace-event JSON "
                         "(open in https://ui.perfetto.dev)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable tracing; write the obs-metrics-v1 JSONL "
                         "stream (input to python -m repro.obs.report)")
    args = ap.parse_args(argv)

    if args.list or not args.scenario:
        for name, doc in scenarios.describe().items():
            print(f"{name:20s} {doc}")
        return 0 if args.list else 2

    overrides = {}
    if args.strategy:
        overrides["strategy"] = args.strategy
    if args.gi_iters is not None:
        overrides["gi_iters"] = args.gi_iters
    if args.mesh is not None:
        from repro.launch.mesh import make_server_mesh
        overrides["mesh"] = make_server_mesh(args.mesh)
    if args.engine is not None:
        overrides["engine"] = args.engine
    tracing = args.trace is not None or args.metrics is not None
    if tracing:
        # enable BEFORE build so scenario/server construction spans record
        from repro import obs
        obs.configure(enabled=True, reset=True)
    run = scenarios.build(args.scenario, seed=args.seed,
                          horizon=args.horizon, **overrides)
    summary = run.run()
    summary["evals"] = [
        {"time": t, "version": v, "acc": a} for t, v, a in run.engine.evals]
    text = json.dumps(summary, indent=2, default=float)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if tracing:
        # status lines on stderr: stdout stays one parseable JSON document
        if args.trace:
            n = obs.write_chrome_trace(
                obs.tracer, args.trace,
                label=f"repro.sim {args.scenario} seed{args.seed}")
            print(f"wrote {args.trace} ({n} trace events)", file=sys.stderr)
        if args.metrics:
            n = obs.write_jsonl(obs.tracer.metrics, args.metrics)
            print(f"wrote {args.metrics} ({n} metric rows)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
