"""Server trigger policies: WHEN to aggregate and WHEN to hand out work.

Three families cover the async-FL design space the paper's baselines live in:

* ``SemiSyncDeadline`` — a wall-clock deadline every ``round_len``: aggregate
  whatever arrived, then dispatch. With ``pipelined=True`` every up client is
  re-dispatched at every tick even with jobs still in flight — the exact
  model of the round-synchronous ``Server`` (a slow client has tau
  concurrent jobs), which is what makes the degenerate zero-variance
  scenario reproduce it bit-for-bit.
* ``PureAsync`` — every arrival triggers an aggregation of that single
  update (FedAsync-style); the client is re-dispatched with the new model.
* ``FedBuffK`` — buffer arrivals and aggregate every K-th (FedBuff-style);
  clients are re-dispatched immediately on arrival, so the buffer mixes
  base versions.

A policy only talks to the engine through ``engine.aggregate()``,
``engine.request_dispatch()`` / ``dispatch_all()`` and ``engine.schedule()``
— all state lives in the engine, so policies stay stateless-ish and
replayable.
"""

from __future__ import annotations

from repro.sim.engine import Arrival, SimEngine


class TriggerPolicy:
    name = "abstract"

    def start(self, eng: SimEngine) -> None:
        """Initial dispatches / timers. Default: one job per client."""
        eng.dispatch_all()

    def on_upload(self, eng: SimEngine, arrival: Arrival) -> None:
        """An update arrived (already buffered). Decide whether to trigger."""

    def on_timer(self, eng: SimEngine, payload: dict) -> None:
        """A ``round`` event fired (only policies that schedule them)."""

    def on_rejoin(self, eng: SimEngine, client: int) -> None:
        """A client came back up. Default: give it work immediately."""
        eng.request_dispatch(client)


class SemiSyncDeadline(TriggerPolicy):
    def __init__(self, round_len: float = 1.0, pipelined: bool = False):
        assert round_len > 0
        self.round_len = float(round_len)
        self.pipelined = pipelined
        self.name = "semi_sync" + ("_pipelined" if pipelined else "")

    def start(self, eng: SimEngine) -> None:
        eng.dispatch_all(force=self.pipelined)
        if self.round_len <= eng.horizon:
            eng.schedule(self.round_len, "round")

    def on_timer(self, eng: SimEngine, payload: dict) -> None:
        eng.aggregate()                       # deadline: take what arrived
        eng.dispatch_all(force=self.pipelined)
        if eng.clock + self.round_len <= eng.horizon:
            eng.schedule(self.round_len, "round")

    def on_rejoin(self, eng: SimEngine, client: int) -> None:
        pass                                  # waits for the next tick


class PureAsync(TriggerPolicy):
    name = "pure_async"

    def on_upload(self, eng: SimEngine, arrival: Arrival) -> None:
        eng.aggregate()                       # cohort of exactly this arrival
        eng.request_dispatch(arrival.client)  # new model goes straight back


class FedBuffK(TriggerPolicy):
    def __init__(self, k: int = 4):
        assert k >= 1
        self.k = int(k)
        self.name = f"fedbuff_k{k}"

    def on_upload(self, eng: SimEngine, arrival: Arrival) -> None:
        if len(eng.buffer) >= self.k:
            eng.aggregate()
        eng.request_dispatch(arrival.client)


POLICIES = {
    "semi_sync": SemiSyncDeadline,
    "pure_async": PureAsync,
    "fedbuff": FedBuffK,
}
