"""Server trigger policies: WHEN to aggregate and WHEN to hand out work.

Three families cover the async-FL design space the paper's baselines live in:

* ``SemiSyncDeadline`` — a wall-clock deadline every ``round_len``: aggregate
  whatever arrived, then dispatch. With ``pipelined=True`` every up client is
  re-dispatched at every tick even with jobs still in flight — the exact
  model of the round-synchronous ``Server`` (a slow client has tau
  concurrent jobs), which is what makes the degenerate zero-variance
  scenario reproduce it bit-for-bit.
* ``PureAsync`` — every arrival triggers an aggregation of that single
  update (FedAsync-style); the client is re-dispatched with the new model.
* ``FedBuffK`` — buffer arrivals and aggregate every K-th (FedBuff-style);
  clients are re-dispatched immediately on arrival, so the buffer mixes
  base versions. By default the trigger counts *distinct* clients in the
  buffer: raw ``len(buffer)`` counts superseded duplicates from
  re-dispatched clients, so it can fire with fewer than K effective
  updates (the per-client dedup happens later, inside
  ``SimEngine.aggregate``, AFTER the trigger decision). The historic
  raw-count trigger stays available as ``FedBuffK(k, distinct=False)`` —
  golden digests recorded against it must be regenerated deliberately.

A policy only talks to the engine through ``engine.aggregate()``,
``engine.request_dispatch()`` / ``dispatch_all()``, ``engine.schedule()``
and ``engine.buffer_size()`` — all state lives in the engine, so policies
stay stateless-ish and replayable, and every policy runs unmodified on
both the heap oracle and the vectorized engine.

Vectorization hooks: the struct-of-arrays engine delivers arrival (and
rejoin) storms in batches. The defaults replay the per-event hooks in
event order — exact, Python-speed; the engine only forms cross-timestamp
batches when the policy declares them *passive* (``passive_uploads`` /
``passive_rejoins``: the hook neither aggregates, dispatches, nor
schedules, so nothing can reorder around a batched storm).
``SemiSyncDeadline`` is passive on both — which is what lets the
vectorized engine push whole deadline rounds through array ops.
"""

from __future__ import annotations

from repro.sim.engine import Arrival, SimEngine


class TriggerPolicy:
    name = "abstract"
    # passive_* = the corresponding hook has NO engine-visible side effects
    # (no aggregate / dispatch / schedule): the vectorized engine may then
    # process those event storms in cross-timestamp array batches
    passive_uploads = False
    passive_rejoins = False
    # uploads_noop = on_uploads is a PURE no-op (stronger than passive: the
    # hook body does nothing at all). On dropout-free fleets in fast mode
    # the vectorized engine then keeps upload events out of the wheel
    # entirely, committing them straight to the buffer in (time, seq) order
    # just before the next timer/eval event
    uploads_noop = False

    def start(self, eng: SimEngine) -> None:
        """Initial dispatches / timers. Default: one job per client."""
        eng.dispatch_all()

    def on_resume(self, eng: SimEngine) -> None:
        """``run(until=...)`` grew the horizon of a finished run. Re-arm any
        timer chain that died at the old horizon; never re-dispatch."""

    def on_upload(self, eng: SimEngine, arrival: Arrival) -> None:
        """An update arrived (already buffered). Decide whether to trigger."""

    def on_uploads(self, eng, batch) -> None:
        """Batched arrivals (vectorized engine; ``batch`` is an
        ``ArrivalBatch``). Only called when ``passive_uploads`` — override
        together with that flag."""
        raise NotImplementedError

    def on_timer(self, eng: SimEngine, payload: dict) -> None:
        """A ``round`` event fired (only policies that schedule them)."""

    def on_rejoin(self, eng: SimEngine, client: int) -> None:
        """A client came back up. Default: give it work immediately."""
        eng.request_dispatch(client)

    def on_rejoins(self, eng, clients) -> None:
        """Batched rejoins (vectorized engine). Only called when
        ``passive_rejoins``."""
        raise NotImplementedError


class SemiSyncDeadline(TriggerPolicy):
    passive_uploads = True                    # buffer-only between ticks
    passive_rejoins = True                    # rejoiners wait for the tick
    uploads_noop = True                       # on_uploads does nothing

    def __init__(self, round_len: float = 1.0, pipelined: bool = False):
        assert round_len > 0
        self.round_len = float(round_len)
        self.pipelined = pipelined
        self.name = "semi_sync" + ("_pipelined" if pipelined else "")

    def start(self, eng: SimEngine) -> None:
        eng.dispatch_all(force=self.pipelined)
        if self.round_len <= eng.horizon:
            eng.schedule(self.round_len, "round")

    def on_resume(self, eng: SimEngine) -> None:
        if eng.has_pending("round"):
            return                            # chain still alive
        nxt = (int(eng.clock / self.round_len) + 1) * self.round_len
        if nxt <= eng.clock:                  # clock exactly on a tick
            nxt += self.round_len
        if nxt <= eng.horizon:
            eng.schedule(nxt - eng.clock, "round")

    def on_uploads(self, eng, batch) -> None:
        pass                                  # deadline-driven: buffer only

    def on_timer(self, eng: SimEngine, payload: dict) -> None:
        eng.aggregate()                       # deadline: take what arrived
        eng.dispatch_all(force=self.pipelined)
        if eng.clock + self.round_len <= eng.horizon:
            eng.schedule(self.round_len, "round")

    def on_rejoin(self, eng: SimEngine, client: int) -> None:
        pass                                  # waits for the next tick

    def on_rejoins(self, eng, clients) -> None:
        pass


class PureAsync(TriggerPolicy):
    name = "pure_async"

    def on_upload(self, eng: SimEngine, arrival: Arrival) -> None:
        eng.aggregate()                       # cohort of exactly this arrival
        eng.request_dispatch(arrival.client)  # new model goes straight back


class FedBuffK(TriggerPolicy):
    def __init__(self, k: int = 4, distinct: bool = True):
        assert k >= 1
        self.k = int(k)
        self.distinct = bool(distinct)
        self.name = f"fedbuff_k{k}" + ("" if distinct else "_raw")

    def on_upload(self, eng: SimEngine, arrival: Arrival) -> None:
        if eng.buffer_size(distinct=self.distinct) >= self.k:
            eng.aggregate()
        eng.request_dispatch(arrival.client)


POLICIES = {
    "semi_sync": SemiSyncDeadline,
    "pure_async": PureAsync,
    "fedbuff": FedBuffK,
}
