"""Vectorized struct-of-arrays event engine (the fleet-scale simulator).

Same contract as the heap oracle (``repro.sim.engine.SimEngine``) — same
events, same policies, same aggregator interface, same counters, same
trace — but the hot path is array-shaped:

* **device sampling** — a dispatch wave of ``k`` jobs draws ONE Philox
  block (``repro.sim.rand.job_uniforms``) and pushes each latency family
  through one masked elementwise transform (``FleetArrays``), instead of
  ``k`` Python calls into per-client ``DeviceProfile`` objects;
* **event storage** — a bucketed time wheel (``repro.sim.wheel``) holding
  parallel arrays, instead of a binary heap of Python tuples;
* **event dispatch** — contiguous same-kind stretches of a bucket are
  handled as single batches (one ``np`` call sequence per batch), with the
  batching rules below guaranteeing the result is indistinguishable from
  per-event processing;
* **client state** — ``up`` / ``inflight_count`` / dropout epochs are flat
  numpy arrays, and job bookkeeping is an append-only struct-of-arrays
  table indexed by job id (dropout cancellation is an epoch comparison,
  not a set walk);
* **arrival buffering** — per-edge struct-of-arrays buffers: clients are
  partitioned into ``n_edges`` contiguous ranges ("edge aggregators"), a
  1M-device upload storm fans into E small edge buffers, and the root
  cohort is the concatenation of per-edge deduped cohorts — bitwise the
  cohort the flat engine produces, funnelled into the unchanged
  cohort-batched ``Server.step``.

**Exactness.** In strict mode (``record_trace=True``, the default) the
engine replays the heap oracle's event sequence bit-for-bit — identical
trace digests on the zero-variance oracle and every stock scenario
(``tests/test_sim_vec.py``). A batch is a maximal run of events sharing
``(kind, time)``; runs of uploads may additionally span timestamps when
the policy declares ``passive_uploads`` (the handler provably schedules
nothing, so nothing can interleave). Policies whose per-arrival hook reads
buffer state (FedBuff, pure-async) get singleton upload batches — exact by
construction, Python-speed by necessity.

In fast mode (``record_trace=False``) dispatch, dropout and rejoin runs
also batch across timestamps whenever every client in the run is distinct
(per-client state makes distinct-client runs order-free); summaries,
counters and cohorts still match the oracle — only the per-event trace is
unavailable. This is the mode the ``sim_scale`` benchmarks run: ~two
orders of magnitude past the heap engine at 100k+ devices.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Dict, List, Optional

import numpy as np

from repro.data.staleness import StalenessSchedule, observed_schedule
from repro.obs import tracer
from repro.sim.devices import DeviceFleet, FleetArrays
from repro.sim.engine import COUNTER_KEYS, EVENT_KINDS, Arrival, trace_digest
from repro.sim.rand import U_FRAC, job_uniforms
from repro.sim.wheel import TimeWheel, merge_chunks

KIND_CODE = {k: i for i, k in enumerate(EVENT_KINDS)}
(K_DISPATCH, K_UPLOAD, K_DROPOUT, K_REJOIN, K_ROUND,
 K_EVAL) = (KIND_CODE[k] for k in EVENT_KINDS)

_I8 = np.int64


class _Grow:
    """Append-only growable array (amortized-doubling)."""

    def __init__(self, dtype, cap: int = 1024):
        self.a = np.empty(cap, dtype)
        self.n = 0

    def append(self, vals: np.ndarray) -> None:
        k = len(vals)
        need = self.n + k
        if need > len(self.a):
            cap = max(len(self.a), 1)
            while cap < need:
                cap *= 2
            grown = np.empty(cap, self.a.dtype)
            grown[:self.n] = self.a[:self.n]
            self.a = grown
        self.a[self.n:need] = vals
        self.n = need

    def view(self) -> np.ndarray:
        return self.a[:self.n]


@dataclasses.dataclass
class ArrivalBatch:
    """A time-sorted slab of delivered updates (policy batch hook input)."""

    clients: np.ndarray
    bases: np.ndarray
    dispatch_times: np.ndarray
    times: np.ndarray
    jobs: np.ndarray

    def __len__(self) -> int:
        return len(self.clients)


class VecEngine:
    """Struct-of-arrays virtual-clock engine; API-compatible with
    ``SimEngine`` for policies, aggregators, scenarios and the sweep."""

    def __init__(self, fleet, policy: Any, aggregator: Any,
                 seed: int = 0, horizon: float = 100.0,
                 eval_every_time: Optional[float] = None,
                 max_events: int = 1_000_000,
                 wheel_dt: float = 1.0,
                 n_edges: int = 1,
                 record_trace: bool = True,
                 record_realized: bool = True,
                 collect_agg_log: bool = True):
        if isinstance(fleet, FleetArrays):
            self.fleet, self.arrays = None, fleet
        else:
            self.fleet, self.arrays = fleet, fleet.arrays()
        self.policy = policy
        self.aggregator = aggregator
        self.seed = int(seed)
        self.horizon = float(horizon)
        self.eval_every_time = eval_every_time
        self.max_events = max_events
        self.record_trace = bool(record_trace)
        self.record_realized = bool(record_realized)
        self.collect_agg_log = bool(collect_agg_log)

        n = len(self.arrays)
        self.n_clients = n
        self.n_edges = max(1, min(int(n_edges), n))
        # edge e owns clients [bounds[e], bounds[e+1])
        self._edge_bounds = np.linspace(0, n, self.n_edges + 1).astype(_I8)
        self.clock = 0.0
        self.version = 0
        self.up = np.ones(n, bool)
        self.inflight_count = np.zeros(n, _I8)
        self._epoch = np.zeros(n, _I8)         # bumped on job-killing dropout

        self._wheel = TimeWheel(wheel_dt)
        self._seq = 0
        self._job_seq = 0
        self._started = False
        self._eval_scheduled = False
        # dropout-free fleets skip all cancellation bookkeeping (epoch
        # gathers, downtime derivation) — values are bitwise unchanged
        # because every skipped quantity is only read on dropout events
        self._no_drop = bool(n == 0 or self.arrays.dropout_prob.max() == 0)
        # deferred-upload fast path: with no dropouts, a pure-no-op upload
        # hook and no trace to record, upload events never need the wheel —
        # they wait in pending arrays (with their real seqs) and commit in
        # exact (time, seq) order just before the next wheel event
        self._fast_uploads = (not self.record_trace and self._no_drop
                              and getattr(policy, "passive_uploads", False)
                              and getattr(policy, "passive_rejoins", False)
                              and getattr(policy, "uploads_noop", False))
        # pending (time, seq, client, job) upload waves, seq-ordered
        self._pend: List[tuple] = []

        # job table (index == job id): owner, base version, dispatch time,
        # owner epoch at dispatch, pre-derived downtime
        jcap = max(1024, 2 * n)
        self._job_client = _Grow(_I8, jcap)
        self._job_base = _Grow(_I8, jcap)
        self._job_t0 = _Grow(np.float64, jcap)
        self._job_epoch = _Grow(_I8, jcap)
        self._job_down = _Grow(np.float64, jcap)

        # per-edge arrival buffers (struct-of-arrays)
        bcap = max(1024, n // self.n_edges + 1)
        self._buf = [{"client": _Grow(_I8, bcap), "base": _Grow(_I8, bcap),
                      "t0": _Grow(np.float64, bcap),
                      "time": _Grow(np.float64, bcap),
                      "job": _Grow(_I8, bcap)} for _ in range(self.n_edges)]
        self._buf_total = 0

        # realized-staleness accumulators (always); full per-client lists
        # only when record_realized (the dict the scenarios serialize)
        self._tau_sum = np.zeros(n, np.float64)
        self._tau_cnt = np.zeros(n, _I8)
        self._tau_max = np.full(n, -1, _I8)
        self._tau_last = np.zeros(n, _I8)
        self.realized: Dict[int, List[int]] = defaultdict(list)

        self.trace: List[Any] = []
        self.evals: List[Any] = []
        self.agg_log: List[Dict[str, Any]] = []
        self.counters: Dict[str, int] = defaultdict(int)

    # ------------------------------------------------------------------ #
    # Scheduling primitives (SimEngine-compatible surface)
    # ------------------------------------------------------------------ #
    def _push(self, times, kinds, clients, jobs=None, force=None) -> None:
        """Append events; consumes len(times) seq numbers in array order."""
        k = len(times)
        seqs = np.arange(self._seq, self._seq + k, dtype=_I8)
        self._seq += k
        self._wheel.push(
            np.asarray(times, np.float64), seqs,
            np.asarray(kinds, np.int8),
            np.asarray(clients, _I8),
            np.zeros(k, _I8) if jobs is None else np.asarray(jobs, _I8),
            np.zeros(k, bool) if force is None else np.asarray(force, bool))

    def schedule(self, delay: float, kind: str, client: int = -1,
                 **payload) -> None:
        assert kind in EVENT_KINDS, kind
        extra = set(payload) - {"job", "force"}
        if extra:
            raise NotImplementedError(
                f"VecEngine events carry no custom payload (got {extra}); "
                f"use the heap SimEngine for payload-bearing round events")
        self._push(np.array([self.clock + float(delay)]),
                   np.array([KIND_CODE[kind]], np.int8),
                   np.array([client], _I8),
                   np.array([payload.get("job", 0)], _I8),
                   np.array([payload.get("force", False)], bool))

    def request_dispatch(self, client: int, delay: float = 0.0,
                         force: bool = False) -> None:
        self.schedule(delay, "dispatch", client, force=force)

    def dispatch_all(self, force: bool = False) -> None:
        n = self.n_clients
        self._push(np.full(n, self.clock), np.full(n, K_DISPATCH, np.int8),
                   np.arange(n, dtype=_I8), force=np.full(n, force))

    def has_pending(self, kind: str) -> bool:
        if kind == "upload" and self._pend:
            return True                       # deferred-upload fast path
        return self._wheel.scan_kind(KIND_CODE[kind])

    # ------------------------------------------------------------------ #
    # Buffer (per-edge struct-of-arrays)
    # ------------------------------------------------------------------ #
    def _edge_of(self, clients: np.ndarray) -> np.ndarray:
        return np.searchsorted(self._edge_bounds, clients, side="right") - 1

    def _buffer_append(self, clients, bases, t0s, times, jobs) -> None:
        self._buf_total += len(clients)
        if self.n_edges == 1:
            b = self._buf[0]
            b["client"].append(clients)
            b["base"].append(bases)
            b["t0"].append(t0s)
            b["time"].append(times)
            b["job"].append(jobs)
            return
        edges = self._edge_of(clients)
        order = np.argsort(edges, kind="stable")
        cuts = np.flatnonzero(np.diff(edges[order])) + 1
        for lo, hi in zip(np.r_[0, cuts], np.r_[cuts, len(clients)]):
            sl = order[lo:hi]
            b = self._buf[int(edges[sl[0]])]
            b["client"].append(clients[sl])
            b["base"].append(bases[sl])
            b["t0"].append(t0s[sl])
            b["time"].append(times[sl])
            b["job"].append(jobs[sl])

    def buffer_size(self, distinct: bool = False) -> int:
        if not distinct:
            return self._buf_total
        return sum(len(np.unique(b["client"].view())) for b in self._buf)

    @property
    def buffer(self) -> List[Arrival]:
        """Heap-compatible view (diagnostics / small-scale tests only)."""
        out = []
        for b in self._buf:
            out.extend(Arrival(int(c), int(v), float(t0), float(t), int(j))
                       for c, v, t0, t, j in zip(
                           b["client"].view(), b["base"].view(),
                           b["t0"].view(), b["time"].view(),
                           b["job"].view()))
        out.sort(key=lambda a: a.job_id)   # heap buffer is in arrival order
        return out

    # ------------------------------------------------------------------ #
    # Trace
    # ------------------------------------------------------------------ #
    def _trace_one(self, kind: str, client: int, info: str = "") -> None:
        if self.record_trace:
            self.trace.append((round(self.clock, 9), kind, client, info))

    def _trace_many(self, times, kind: str, clients, infos) -> None:
        if self.record_trace:
            self.trace.extend(
                (round(float(t), 9), kind, int(c), i)
                for t, c, i in zip(times, clients, infos))

    def trace_digest(self) -> str:
        if not self.record_trace:
            return "untraced"
        return trace_digest(self.trace)

    # ------------------------------------------------------------------ #
    # Aggregation (policy-callable)
    # ------------------------------------------------------------------ #
    def aggregate(self) -> Optional[Dict[str, Any]]:
        if self._buf_total == 0:
            self.counters["empty_triggers"] += 1
            self._trace_one("aggregate", -1, "empty")
            return None
        sel_cl: List[np.ndarray] = []
        sel_base: List[np.ndarray] = []
        for b in self._buf:
            m = b["client"].n
            if m == 0:
                continue
            cl, base = b["client"].view(), b["base"].view()
            at = b["time"].view()
            # per-client dedup: freshest (base, arrival) wins, first-in
            # wins exact ties — the heap engine's strict-> comparison.
            # Layout by counting sort (O(m + clients), no comparison sort):
            # singleton clients scatter straight to their rank; only the
            # (usually few) multi-entry clients go through the sort below.
            counts = np.bincount(cl)
            nz = counts > 0
            rank = np.cumsum(nz) - 1               # dense client rank
            n_keep = int(rank[-1]) + 1 if len(rank) else 0
            out_cl = np.flatnonzero(nz).astype(_I8)
            out_base = np.empty(n_keep, base.dtype)
            multi = counts[cl] > 1
            if multi.any():
                sub = np.flatnonzero(multi)        # ascending: keeps the
                scl, sbase, sat = cl[sub], base[sub], at[sub]  # index order
                if np.all(at[1:] >= at[:-1]):
                    # appends happen in event-time order, so within any
                    # (client, base) group arrival time is nondecreasing
                    # in index: a stable (base, client) sort puts the
                    # winner LAST in its client group — except exact
                    # arrival-time ties, where the earliest index wins
                    # (the shift-back loop; ~never taken)
                    order = np.lexsort((sbase, scl))
                    last = np.r_[np.flatnonzero(
                        np.diff(scl[order]) != 0), len(sub) - 1]
                    starts = np.r_[0, last[:-1] + 1]
                    pos = last
                    while True:
                        prev = pos - 1
                        shift = ((prev >= starts)
                                 & (sbase[order[prev]] == sbase[order[pos]])
                                 & (sat[order[prev]] == sat[order[pos]]))
                        if not shift.any():
                            break
                        pos = np.where(shift, prev, pos)
                    keep = order[pos]
                else:   # out-of-order appends: fall back to the full sort
                    order = np.lexsort((-np.arange(len(sub)), sat, sbase,
                                        scl))
                    keep = order[np.r_[np.flatnonzero(
                        np.diff(scl[order]) != 0), len(sub) - 1]]
                out_base[rank[scl[keep]]] = sbase[keep]
                single = ~multi
                out_base[rank[cl[single]]] = base[single]
            else:
                out_base[rank[cl]] = base
            sel_cl.append(out_cl)
            sel_base.append(out_base)
            b["client"].n = b["base"].n = 0
            b["t0"].n = b["time"].n = b["job"].n = 0
        cl = np.concatenate(sel_cl)      # edge ranges are contiguous ->
        base = np.concatenate(sel_base)  # concat is globally client-sorted
        self.counters["superseded"] += self._buf_total - len(cl)
        self._buf_total = 0

        taus = self.version - base
        np.add.at(self._tau_sum, cl, taus.astype(np.float64))
        np.add.at(self._tau_cnt, cl, 1)
        np.maximum.at(self._tau_max, cl, taus)
        self._tau_last[cl] = taus
        if self.record_realized:
            for c, t in zip(cl.tolist(), taus.tolist()):
                self.realized[c].append(t)

        fresh_m = taus == 0
        fresh = cl[fresh_m]
        stale_cl, stale_base = cl[~fresh_m], base[~fresh_m]
        self._trace_one("aggregate", -1,
                        f"v{self.version} fresh{len(fresh)} "
                        f"stale{len(stale_cl)}")
        with tracer.span("sim.aggregate") as _sp:
            _sp.arg("version", int(self.version))
            if getattr(self.aggregator, "wants_arrays", False):
                row = self.aggregator.aggregate(self.version, fresh,
                                                (stale_cl, stale_base)) or {}
            else:
                fresh_l = fresh.tolist()
                stale_l = list(zip(stale_cl.tolist(), stale_base.tolist()))
                row = self.aggregator.aggregate(self.version, fresh_l,
                                                stale_l) or {}
        if tracer.enabled:
            tracer.metric(
                "aggregation", time=float(self.clock),
                version=int(self.version), n_fresh=int(len(fresh)),
                n_stale=int(len(stale_cl)),
                n_base_rounds=int(len(np.unique(stale_base))),
                mean_tau=float(taus.mean()) if len(taus) else 0.0,
                tau_hist=np.bincount(taus).tolist() if len(taus) else [])
        if self.collect_agg_log:
            self.agg_log.append({
                "time": self.clock, "version": self.version,
                "fresh": fresh.tolist(),
                "stale": list(zip(stale_cl.tolist(), stale_base.tolist())),
                "taus": taus.tolist(), **row})
        self.version += 1
        self.counters["aggregations"] += 1
        return row

    # ------------------------------------------------------------------ #
    # Batched handlers
    # ------------------------------------------------------------------ #
    @staticmethod
    def _has_dup(cl: np.ndarray) -> bool:
        return bool(np.bincount(cl).max() > 1)

    def _do_dispatch(self, t, cl, force) -> None:
        if len(cl) > 1 and self._has_dup(cl):
            # duplicate clients in one run: replay per event so each
            # sees its predecessors' busy/up effects (rare; policy-made)
            for i in range(len(cl)):
                self.clock = float(t[i])
                self._do_dispatch(t[i:i + 1], cl[i:i + 1], force[i:i + 1])
            return
        up = self.up[cl]
        self.counters["skipped_down"] += int((~up).sum())
        busy = (self.inflight_count[cl] > 0) & ~force & up
        self.counters["skipped_busy"] += int(busy.sum())
        ok = up & ~busy
        if not ok.any():
            return
        ecl, et = cl[ok], t[ok]
        k = len(ecl)
        job0 = self._job_seq
        self._job_seq += k
        u = job_uniforms(self.seed, job0, k)
        lat = self.arrays.job_latency(ecl, u)
        self.counters["dispatches"] += k
        if tracer.enabled:
            tracer.metric("wave", wave="dispatch", time=float(self.clock),
                          n=int(k))
        self._job_client.append(ecl)
        self._job_base.append(np.full(k, self.version, _I8))
        self._job_t0.append(et)
        if self._no_drop:
            # every job survives: upload at et+lat (bitwise what the
            # all-False np.where below produces), no epoch/downtime rows
            when = et + lat
            kinds = None if self._fast_uploads else np.full(k, K_UPLOAD,
                                                            np.int8)
        else:
            drops = self.arrays.job_drops(ecl, u)
            self._job_epoch.append(self._epoch[ecl])
            self._job_down.append(self.arrays.downtime_of(ecl, u))
            when = np.where(drops, et + lat * u[:, U_FRAC], et + lat)
            kinds = np.where(drops, K_DROPOUT, K_UPLOAD).astype(np.int8)
        self.inflight_count[ecl] += 1
        if kinds is None:
            # deferred-upload fast path: park the wave with its real seqs;
            # _commit_uploads delivers it in exact (time, seq) order
            seqs = np.arange(self._seq, self._seq + k)
            self._seq += k
            self._pend.append((when, seqs, ecl,
                               np.arange(job0, job0 + k)))
        else:
            self._push(when, kinds, ecl, jobs=np.arange(job0, job0 + k))
        if self.record_trace:
            v = self.version
            if self._no_drop:
                self._trace_many(et, "dispatch", ecl,
                                 (f"v{v}" for _ in range(k)))
            else:
                self._trace_many(et, "dispatch", ecl,
                                 (f"v{v} doomed" if d else f"v{v}"
                                  for d in drops))

    def _commit_uploads(self, t: float, seq: Optional[int]) -> None:
        """Deferred-upload flush (fast path): deliver every pending upload
        that the heap would process before the wheel event ``(t, seq)`` —
        i.e. time < t, or time == t with a smaller seq. ``seq=None`` is the
        end-of-run flush: everything with time <= t goes. Pending storage
        order is seq order (waves append in dispatch order), so a stable
        time sort realizes the exact (time, seq) delivery order."""
        if len(self._pend) == 1:
            when, seqs, cl, jobs = self._pend[0]
        else:
            when = np.concatenate([p[0] for p in self._pend])
            seqs = np.concatenate([p[1] for p in self._pend])
            cl = np.concatenate([p[2] for p in self._pend])
            jobs = np.concatenate([p[3] for p in self._pend])
        if seq is None:
            m = when <= t
        else:
            m = when < t
            ties = when == t
            if ties.any():
                m |= ties & (seqs < seq)
        if not m.any():
            self._pend = [(when, seqs, cl, jobs)]
            return
        rest = ~m
        if rest.any():
            self._pend = [(when[rest], seqs[rest], cl[rest], jobs[rest])]
            when, cl, jobs = when[m], cl[m], jobs[m]
        else:
            self._pend = []
        order = np.argsort(when)
        ts = when[order]
        if bool((ts[1:] == ts[:-1]).any()):
            order = np.argsort(when, kind="stable")   # ties: seq order
            ts = when[order]
        cl, jobs = cl[order], jobs[order]
        k = len(cl)
        self.clock = float(ts[-1])
        self.counters["events"] += k
        if k * 16 < self.n_clients:
            np.subtract.at(self.inflight_count, cl, 1)
        else:
            self.inflight_count -= np.bincount(cl,
                                               minlength=self.n_clients)
        self._buffer_append(cl, self._job_base.a[jobs],
                            self._job_t0.a[jobs], ts, jobs)
        self.counters["arrivals"] += k
        if tracer.enabled:
            tracer.metric("wave", wave="upload", time=float(self.clock),
                          n=int(k))
        # policy.on_uploads is a declared pure no-op on this path

    def _do_upload_batch(self, t, cl, jobs) -> None:
        """Passive-policy path: buffer the whole storm, one batch hook."""
        if self._no_drop:                      # no dropouts -> no cancels
            lcl, lt, lj = cl, t, jobs
            bases = self._job_base.a[lj]
            if self.record_trace:
                self._trace_many(t, "upload", cl,
                                 (f"v{b}" for b in bases))
        else:
            dead = self._job_epoch.a[jobs] < self._epoch[cl]
            n_dead = int(dead.sum())
            self.counters["cancelled_uploads"] += n_dead
            live = ~dead
            lcl, lt, lj = cl[live], t[live], jobs[live]
            bases = self._job_base.a[lj]
            if self.record_trace:              # lines in event order
                infos = np.empty(len(cl), object)
                infos[dead] = "cancelled"
                infos[live] = [f"v{b}" for b in bases]
                self._trace_many(t, "upload", cl, infos)
        if len(lcl) == 0:
            return
        if len(lcl) * 16 < self.n_clients:       # small batch: sparse path
            np.subtract.at(self.inflight_count, lcl, 1)
        else:
            self.inflight_count -= np.bincount(lcl,
                                               minlength=self.n_clients)
        batch = ArrivalBatch(lcl, bases, self._job_t0.a[lj], lt, lj)
        self._buffer_append(lcl, bases, batch.dispatch_times, lt, lj)
        self.counters["arrivals"] += len(lcl)
        if tracer.enabled:
            tracer.metric("wave", wave="upload", time=float(self.clock),
                          n=int(len(lcl)))
        self.policy.on_uploads(self, batch)

    def _do_upload_one(self, t, cl, job) -> None:
        """Per-arrival path (FedBuff / pure-async: the hook reads buffer
        state and may aggregate + dispatch, so arrivals interleave)."""
        client, job = int(cl), int(job)
        if not self._no_drop and self._job_epoch.a[job] < self._epoch[client]:
            self.counters["cancelled_uploads"] += 1
            self._trace_one("upload", client, "cancelled")
            return
        self.inflight_count[client] -= 1
        base = int(self._job_base.a[job])
        arrival = Arrival(client, base, float(self._job_t0.a[job]),
                          float(t), job)
        self._buffer_append(np.array([client], _I8),
                            np.array([base], _I8),
                            np.array([arrival.dispatch_time]),
                            np.array([arrival.arrival_time]),
                            np.array([job], _I8))
        self.counters["arrivals"] += 1
        if tracer.enabled:
            tracer.metric("wave", wave="upload", time=float(t), n=1)
        self._trace_one("upload", client, f"v{base}")
        self.policy.on_upload(self, arrival)

    def _do_dropout(self, t, cl, jobs) -> None:
        if len(cl) > 1 and self._has_dup(cl):
            for i in range(len(cl)):
                self.clock = float(t[i])
                self._do_dropout(t[i:i + 1], cl[i:i + 1], jobs[i:i + 1])
            return
        dead = self._job_epoch.a[jobs] < self._epoch[cl]
        live = ~dead
        lcl, lt, lj = cl[live], t[live], jobs[live]
        lost = self.inflight_count[lcl]       # failing job + all pipelined
        was_up = self.up[lcl]
        down = self._job_down.a[lj]
        if self.record_trace:                 # lines in event order
            infos = np.empty(len(cl), object)
            infos[dead] = "cancelled"
            infos[live] = [
                f"lost{lo} down{dn:.3f}" if w else f"lost{lo} already-down"
                for lo, dn, w in zip(lost, down, was_up)]
            self._trace_many(t, "dropout", cl, infos)
        if len(lcl) == 0:
            return
        self.counters["lost_jobs"] += int(lost.sum())
        self._epoch[lcl] += 1                 # cancels every in-flight job
        self.inflight_count[lcl] = 0
        self.up[lcl] = False
        self.counters["dropouts"] += int(was_up.sum())
        if was_up.any():
            self._push(lt[was_up] + down[was_up],
                       np.full(int(was_up.sum()), K_REJOIN, np.int8),
                       lcl[was_up])

    def _do_rejoin(self, t, cl) -> None:
        down = ~self.up[cl]
        rcl, rt = cl[down], t[down]
        if len(rcl) == 0:
            return
        self.up[rcl] = True
        self.counters["rejoins"] += len(rcl)
        self._trace_many(rt, "rejoin", rcl, ("" for _ in range(len(rcl))))
        if self.policy.passive_rejoins:
            self.policy.on_rejoins(self, rcl)
        else:
            for time, c in zip(rt, rcl):      # singleton batches in strict
                self.clock = float(time)      # mode; exact in fast mode as
                self.policy.on_rejoin(self, int(c))   # dispatches carry t

    def _do_eval(self) -> None:
        acc = float(self.aggregator.evaluate())
        self.evals.append((self.clock, self.version, acc))
        self.counters["evals"] += 1
        self._trace_one("eval", -1, f"v{self.version}")
        self._eval_scheduled = False
        if self.eval_every_time:
            nxt = self.clock + self.eval_every_time
            if nxt <= self.horizon:
                self.schedule(self.eval_every_time, "eval")
                self._eval_scheduled = True

    def _arm_eval(self) -> None:
        if not self.eval_every_time or self._eval_scheduled:
            return
        k = int(np.floor(self.clock / self.eval_every_time)) + 1
        nxt = k * self.eval_every_time
        if nxt <= self.clock:
            nxt += self.eval_every_time
        if nxt <= self.horizon:
            self.schedule(nxt - self.clock, "eval")
            self._eval_scheduled = True

    # ------------------------------------------------------------------ #
    # Main loop
    # ------------------------------------------------------------------ #
    def _batch_end(self, kinds, times, i: int, n: int) -> int:
        """Largest j such that [i, j) is processable as one batch."""
        kind = kinds[i]
        nxt = np.flatnonzero(kinds[i:n] != kind)   # end of same-kind run
        j = i + int(nxt[0]) if len(nxt) else n
        if kind == K_UPLOAD:
            if self.policy.passive_uploads:
                return j                       # cross-time storm, no hooks
            return i + 1                       # hook per arrival
        if kind in (K_ROUND, K_EVAL):
            return i + 1
        if (not self.record_trace and self.policy.passive_uploads
                and self.policy.passive_rejoins):
            # fast mode + fully passive policy: no hook can schedule
            # events, so a cross-time run's own side-events (uploads,
            # dropouts, rejoins of its jobs) are the only interleavers —
            # and those only touch their own client's state, which the
            # distinct-client guard in the handlers makes order-free
            return j
        # strict mode: same-timestamp runs only (new events cannot sort
        # inside a same-(kind, time) prefix — their seqs are larger)
        return i + int(np.searchsorted(times[i:j], times[i], side="right"))

    def run(self, until: Optional[float] = None) -> Dict[str, Any]:
        if until is not None:
            self.horizon = float(until)
        if not self._started:
            self._started = True
            self.policy.start(self)
        else:
            self.policy.on_resume(self)
        self._arm_eval()

        with tracer.span("sim.run") as _run_sp:
            _run_sp.arg("engine", "vec")
            wheel = self._wheel
            while True:
                b = wheel.next_bucket()
                if b is None:
                    break
                frame = wheel.take(b)
                t_arr, seq_arr, k_arr, c_arr, j_arr, f_arr = frame
                i, n = 0, len(t_arr)
                stop = False
                while i < n:
                    if t_arr[i] > self.horizon:
                        # past the horizon: park the tail back in the wheel
                        # (a later run(until=...) resumes from it)
                        wheel.push(*(a[i:] for a in frame))
                        stop = True
                        break
                    if self._pend:
                        # fast path: flush deferred uploads the heap would
                        # process before this wheel event
                        self._commit_uploads(float(t_arr[i]),
                                             int(seq_arr[i]))
                    if self.counters["events"] >= self.max_events:
                        self._trace_one("halt", -1, "max_events")
                        wheel.push(*(a[i:] for a in frame))
                        stop = True
                        break
                    j = self._batch_end(k_arr, t_arr, i, n)
                    # clamp to horizon and event budget
                    j = i + int(np.searchsorted(t_arr[i:j], self.horizon,
                                                side="right"))
                    j = min(j, i + self.max_events
                            - self.counters["events"])
                    j = max(j, i + 1)
                    kind = k_arr[i]
                    self.clock = float(t_arr[j - 1])
                    self.counters["events"] += j - i
                    if kind == K_DISPATCH:
                        self._do_dispatch(t_arr[i:j], c_arr[i:j], f_arr[i:j])
                    elif kind == K_UPLOAD:
                        if j - i == 1 and not self.policy.passive_uploads:
                            self._do_upload_one(t_arr[i], c_arr[i], j_arr[i])
                        else:
                            self._do_upload_batch(t_arr[i:j], c_arr[i:j],
                                                  j_arr[i:j])
                    elif kind == K_DROPOUT:
                        self._do_dropout(t_arr[i:j], c_arr[i:j], j_arr[i:j])
                    elif kind == K_REJOIN:
                        self._do_rejoin(t_arr[i:j], c_arr[i:j])
                    elif kind == K_ROUND:
                        self.policy.on_timer(self, {})
                    elif kind == K_EVAL:
                        self._do_eval()
                    i = j
                    if wheel.has_new(b):
                        # zero-delay events landed in the bucket being
                        # drained: merge them into the unprocessed tail (the
                        # new chunk's seqs are all larger, so a linear merge
                        # is exact)
                        frame = merge_chunks(tuple(a[i:] for a in frame),
                                             wheel.take(b))
                        t_arr, seq_arr, k_arr, c_arr, j_arr, f_arr = frame
                        i, n = 0, len(t_arr)
                if stop:
                    break
            if self._pend:
                # wheel drained (or horizon hit): uploads due by the horizon
                # still deliver, exactly as the heap drains its queue
                self._commit_uploads(self.horizon, None)
        return self.summary()

    # ------------------------------------------------------------------ #
    # Reporting
    # ------------------------------------------------------------------ #
    def realized_schedule(self, reducer: str = "mean") -> StalenessSchedule:
        if self.record_realized:
            return observed_schedule(self.n_clients, self.realized, reducer)
        seen = self._tau_cnt > 0
        if reducer == "mean":
            vals = np.where(seen, self._tau_sum / np.maximum(self._tau_cnt,
                                                             1), 0.0)
        elif reducer == "max":
            vals = np.where(seen, self._tau_max, 0)
        elif reducer == "last":
            vals = np.where(seen, self._tau_last, 0)
        else:
            raise ValueError(f"unknown reducer {reducer!r}")
        obs = {int(i): [float(vals[i])] for i in np.flatnonzero(seen)}
        return observed_schedule(self.n_clients, obs, reducer)

    def summary(self) -> Dict[str, Any]:
        c = dict(self.counters)
        out = {k: c.get(k, 0) for k in COUNTER_KEYS}
        out.update(c)
        n_obs = int(self._tau_cnt.sum())
        out.update({
            "clock": self.clock,
            "version": self.version,
            "buffer_pending": self._buf_total,
            "inflight": (c.get("dispatches", 0) - c.get("arrivals", 0)
                         - c.get("lost_jobs", 0)),
            "clients_down": int((~self.up).sum()),
            "mean_realized_tau": (float(self._tau_sum.sum()) / n_obs
                                  if n_obs else 0.0),
            "max_realized_tau": (int(self._tau_max.max())
                                 if n_obs else 0),
            "trace_digest": self.trace_digest(),
            "n_evals": len(self.evals),
        })
        return out
