"""Adapter between the event engine and the FL server strategies.

The engine is model-agnostic: it hands an *aggregator* a cohort of
``(fresh_ids, stale_pairs)`` per aggregation event. ``ServerBridge`` routes
those cohorts into an existing ``repro.core.server.Server`` via its ``step``
API, so every strategy the round-synchronous harness supports — including
the batched-GI "ours" path, whose pow2-bucketed compiles absorb the
variable-size stale cohorts aggregation events produce — runs unmodified
under arbitrary arrival processes. Engine versions and ``Server.history``
indices stay aligned by construction: version ``v`` is ``history[v]``
(``history`` is the bounded ``repro.core.versions.VersionStore`` ring — old
versions spill to host exactly, so device memory stays capped at
``FLConfig.version_capacity`` rows however long the simulation runs).

Event-driven arrival processes are exactly where per-base-round delivery
grouping degenerates: a FedBuff or pure-async cohort routinely has every
client arriving from a *different* version. The server's fused aggregation
round (``FLConfig.fused_step``) runs that whole mixed-version cohort as ONE
multi-version LocalUpdate instead of B single-lane dispatches — the bridge
surfaces ``n_base_rounds`` per wall row so the scatter is visible in the
benchmark output.

``RecordingAggregator`` is the null model: it records cohorts and counts,
for engine unit tests and events/sec throughput benchmarks where spinning
up jax would drown the measurement.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Sequence, Tuple

from repro.core.server import Server
from repro.obs import tracer


class RecordingAggregator:
    """No-op aggregator: remembers every cohort, evaluates to 0."""

    def __init__(self):
        self.cohorts: List[Dict[str, Any]] = []

    def aggregate(self, version: int, fresh_ids: Sequence[int],
                  stale_pairs: Sequence[Tuple[int, int]]) -> Dict[str, Any]:
        self.cohorts.append({"version": version,
                             "fresh": list(fresh_ids),
                             "stale": list(stale_pairs)})
        return {}

    def evaluate(self) -> float:
        return 0.0


class NullAggregator:
    """Pure-throughput sink for fleet-scale benchmarks.

    ``wants_arrays=True`` tells the vectorized engine to hand cohorts over
    as numpy arrays — ``(version, fresh_ids, (stale_clients, stale_bases))``
    — skipping the list materialization that would otherwise dominate a
    100k-client aggregation. Only counts are kept."""

    wants_arrays = True

    def __init__(self):
        self.n_cohorts = 0
        self.n_updates = 0

    def aggregate(self, version: int, fresh_ids, stale_pairs):
        self.n_cohorts += 1
        if isinstance(stale_pairs, tuple) and len(stale_pairs) == 2:
            n_stale = len(stale_pairs[0])     # array form (vec engine)
        else:
            n_stale = len(stale_pairs)        # list form (heap engine)
        self.n_updates += len(fresh_ids) + n_stale
        return {}

    def evaluate(self) -> float:
        return 0.0


class ServerBridge:
    """Drives a real ``Server`` with externally-determined cohorts.

    Per aggregation event the bridge calls ``Server.step(version, fresh,
    stale_pairs)``: fresh clients train on the current global model, stale
    pairs are materialized lazily from ``history[base_version]`` with
    realized staleness ``version - base_version`` — exactly how the
    round-synchronous path computes deliveries, so a degenerate simulation
    (zero latency variance, pipelined deadline) reproduces ``Server.run``
    bit-for-bit.

    ``eval_mode``: "server" follows ``FLConfig.eval_every`` on the version
    counter (matches the sync harness — required by the oracle test);
    "never" defers accuracy entirely to the engine's wall-clock eval ticks,
    keeping eval cost off the aggregation path; "always" evaluates every
    aggregation.
    """

    def __init__(self, server: Server, eval_mode: str = "server"):
        assert eval_mode in ("server", "never", "always"), eval_mode
        self.server = server
        self.eval_mode = eval_mode
        # per-aggregation ``server_step`` rows (obs-metrics-v1): the
        # batched-GI hot path's cost per trigger, consumed by
        # ``benchmarks.run --only server``, the ``repro.sweep``
        # trajectories, and ``repro.obs.report``
        self.rows: List[Dict[str, Any]] = []

    def aggregate(self, version: int, fresh_ids: Sequence[int],
                  stale_pairs: Sequence[Tuple[int, int]]) -> Dict[str, Any]:
        assert version == len(self.server.history) - 1, \
            (version, len(self.server.history))
        eval_now = {"server": None, "never": False, "always": True}[self.eval_mode]
        mark = tracer.mark()
        t0 = time.perf_counter()
        row = self.server.step(version, fresh_ids, stale_pairs,
                               eval_now=eval_now)
        mrow = {"kind": "server_step", "version": version,
                "n_fresh": len(fresh_ids), "n_stale": len(stale_pairs),
                # distinct base versions in the stale cohort: the
                # dispatch count the pre-fused grouped path would
                # have paid (the fused round always pays one)
                "n_base_rounds": len({b for _, b in stale_pairs}),
                "wall_s": time.perf_counter() - t0,
                "gi_iters": row.get("gi_iters", 0),
                # GI executor occupancy (None when no GI ran this
                # aggregation): how much of the paid lane-iter
                # budget advanced real clients — the quantity the
                # segmented executor exists to push toward 1.0
                "gi_occupancy": row.get("gi_occupancy")}
        if tracer.enabled:
            spans = tracer.span_totals(mark)
            if spans:
                mrow["spans"] = spans
            tracer.metric(**mrow)       # copy onto the stream, stamps ts_s
        self.rows.append(mrow)
        return row

    def evaluate(self) -> float:
        return self.server.evaluate()[0]
