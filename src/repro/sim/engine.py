"""Virtual-clock event engine for asynchronous FL simulation.

The engine owns a heap of typed events — ``dispatch``, ``upload``,
``dropout``, ``rejoin``, ``round`` (policy deadline tick), ``eval`` — ordered
by ``(time, seq)`` so simultaneous events resolve in scheduling order and a
(scenario, seed) pair replays *identically*: same event trace, same realized
staleness, same final model. All randomness flows through one seeded
``numpy.random.Generator``.

Division of labour:

* the **engine** runs mechanics — the clock, job lifecycles (dispatch →
  upload-arrival, or loss via device dropout), the arrival buffer, dropout /
  rejoin bookkeeping, eval ticks, and the trace;
* the **policy** (``repro.sim.policies``) decides *when to aggregate* and
  *when to hand out work*;
* the **aggregator** (``repro.sim.bridge``) turns an aggregation cohort into
  a model update — normally a real ``repro.core.server.Server`` via
  ``ServerBridge``, or a ``RecordingAggregator`` for engine-only tests and
  throughput benchmarks.

Model versions count aggregations: a job dispatched at version ``v`` and
consumed at version ``v'`` has *realized staleness* ``v' - v`` — zero means
the update is fresh (nothing was aggregated while it trained), matching the
round-synchronous server's fast path.

This heap engine is the per-event ORACLE: randomness is counter-based
(``repro.sim.rand`` — each job's latency/dropout/downtime come from a
Philox block keyed on ``(seed, job_id)``), so the vectorized
struct-of-arrays engine (``repro.sim.engine_vec``) reproduces its event
trace bit-for-bit while sampling whole dispatch waves at once. Scenarios
select the engine via ``engine="vec" | "heap"`` (vectorized by default,
heap behind the flag — the same oracle-behind-a-flag pattern as
``FLConfig(fused_step=False)``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import heapq
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.data.staleness import StalenessSchedule, observed_schedule
from repro.obs import tracer
from repro.sim.devices import DeviceFleet
from repro.sim.rand import U_FRAC, JobRandoms

EVENT_KINDS = ("dispatch", "upload", "dropout", "rejoin", "round", "eval")

# every counter the engine writes; summary() reports each one (plus any
# non-canonical key a policy may add) — tests/test_sim.py asserts no
# counter can silently drop out of the summary again
COUNTER_KEYS = ("events", "aggregations", "dispatches", "arrivals",
                "lost_jobs", "dropouts", "rejoins", "superseded",
                "empty_triggers", "skipped_down", "skipped_busy",
                "cancelled_uploads", "evals")


def trace_digest(trace: List[Tuple[float, str, int, str]]) -> str:
    """Fingerprint of an event trace — the cross-engine equivalence oracle
    (identical digests ⇒ identical event sequences)."""
    lines = "\n".join(f"{t:.9f}|{k}|{c}|{i}" for t, k, c, i in trace)
    return hashlib.sha256(lines.encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One delivered client update, buffered until the policy aggregates."""
    client: int
    base_version: int          # model version the client trained from
    dispatch_time: float
    arrival_time: float
    job_id: int


class SimEngine:
    def __init__(self, fleet: DeviceFleet, policy: Any, aggregator: Any,
                 seed: int = 0, horizon: float = 100.0,
                 eval_every_time: Optional[float] = None,
                 max_events: int = 1_000_000):
        self.fleet = fleet
        self.policy = policy
        self.aggregator = aggregator
        self.seed = int(seed)
        self._randoms = JobRandoms(seed)
        self.horizon = float(horizon)
        self.eval_every_time = eval_every_time
        self.max_events = max_events
        self._started = False
        self._eval_scheduled = False

        n = len(fleet)
        self.n_clients = n
        self.clock = 0.0
        self.version = 0
        self.up = [True] * n
        self.inflight_count = [0] * n

        self._heap: List[Tuple[float, int, str, int, dict]] = []
        self._seq = 0
        self._job_seq = 0
        self._inflight: Dict[int, Tuple[int, int, float]] = {}  # job -> (client, base, t0)
        self._doomed: Dict[int, int] = {}        # failing job -> client
        self._cancelled: set = set()
        self.buffer: List[Arrival] = []

        self.realized: Dict[int, List[int]] = defaultdict(list)
        self.trace: List[Tuple[float, str, int, str]] = []
        self.evals: List[Tuple[float, int, float]] = []
        self.agg_log: List[Dict[str, Any]] = []
        self.counters: Dict[str, int] = defaultdict(int)

    # ------------------------------------------------------------------ #
    # Scheduling primitives
    # ------------------------------------------------------------------ #
    def schedule(self, delay: float, kind: str, client: int = -1,
                 **payload) -> None:
        assert kind in EVENT_KINDS, kind
        heapq.heappush(self._heap,
                       (self.clock + float(delay), self._seq, kind, client,
                        payload))
        self._seq += 1

    def request_dispatch(self, client: int, delay: float = 0.0,
                         force: bool = False) -> None:
        """Queue a dispatch event; ``force`` allows pipelined dispatch (a new
        job even while previous ones are in flight — the round-synchronous
        model dispatches every client every round)."""
        self.schedule(delay, "dispatch", client, force=force)

    def dispatch_all(self, force: bool = False) -> None:
        for i in range(self.n_clients):
            self.request_dispatch(i, force=force)

    def has_pending(self, kind: str) -> bool:
        """Is an event of ``kind`` still scheduled? (Policies use this on
        resume to decide whether their timer chain needs re-arming.)"""
        return any(k == kind for _, _, k, _, _ in self._heap)

    def buffer_size(self, distinct: bool = False) -> int:
        """Arrival-buffer occupancy; ``distinct=True`` counts distinct
        clients (superseded duplicates from re-dispatched clients are
        deduped at aggregation time, so a trigger that counts raw arrivals
        can fire with fewer than K effective updates)."""
        if not distinct:
            return len(self.buffer)
        return len({a.client for a in self.buffer})

    # ------------------------------------------------------------------ #
    # Event handlers
    # ------------------------------------------------------------------ #
    def _trace(self, kind: str, client: int, info: str = "") -> None:
        self.trace.append((round(self.clock, 9), kind, client, info))

    def _handle_dispatch(self, client: int, force: bool = False) -> None:
        if not self.up[client]:
            self.counters["skipped_down"] += 1
            return
        if self.inflight_count[client] > 0 and not force:
            self.counters["skipped_busy"] += 1
            return
        job_id = self._job_seq
        self._job_seq += 1
        u = self._randoms.block(job_id)
        latency = self.fleet.job_latency_from_block(client, u)
        self.counters["dispatches"] += 1
        if self.fleet.job_drops_from_block(client, u):
            # the job dies partway through: the device goes down at a random
            # fraction of the would-be latency and the upload never happens
            frac = float(u[U_FRAC])
            self._doomed[job_id] = client
            self.inflight_count[client] += 1
            self.schedule(latency * frac, "dropout", client, job=job_id)
            self._trace("dispatch", client, f"v{self.version} doomed")
        else:
            self._inflight[job_id] = (client, self.version, self.clock)
            self.inflight_count[client] += 1
            self.schedule(latency, "upload", client, job=job_id)
            self._trace("dispatch", client, f"v{self.version}")

    def _handle_upload(self, client: int, job: int) -> None:
        if job in self._cancelled:
            self._cancelled.discard(job)
            self.counters["cancelled_uploads"] += 1
            self._trace("upload", client, "cancelled")
            return
        _, base, t0 = self._inflight.pop(job)
        self.inflight_count[client] -= 1
        arrival = Arrival(client, base, t0, self.clock, job)
        self.buffer.append(arrival)
        self.counters["arrivals"] += 1
        self._trace("upload", client, f"v{base}")
        self.policy.on_upload(self, arrival)

    def _handle_dropout(self, client: int, job: int) -> None:
        if job in self._cancelled:             # killed by an earlier dropout
            self._cancelled.discard(job)
            self._trace("dropout", client, "cancelled")
            return
        self._doomed.pop(job, None)
        lost = 1                               # the job that failed
        for jid, (c, _, _) in list(self._inflight.items()):
            if c == client:                    # concurrent jobs die with it
                del self._inflight[jid]
                self._cancelled.add(jid)
                lost += 1
        for jid, c in list(self._doomed.items()):
            if c == client:
                del self._doomed[jid]
                self._cancelled.add(jid)
                lost += 1
        self.inflight_count[client] = 0
        self.counters["lost_jobs"] += lost
        if self.up[client]:
            self.up[client] = False
            self.counters["dropouts"] += 1
            # downtime comes from the FAILING job's counter block, so it is
            # order-free: both engines derive it from (seed, job) alone
            down = self.fleet.downtime_from_block(client,
                                                  self._randoms.block(job))
            self.schedule(down, "rejoin", client)
            self._trace("dropout", client, f"lost{lost} down{down:.3f}")
        else:
            self._trace("dropout", client, f"lost{lost} already-down")

    def _handle_rejoin(self, client: int) -> None:
        if not self.up[client]:
            self.up[client] = True
            self.counters["rejoins"] += 1
            self._trace("rejoin", client)
            self.policy.on_rejoin(self, client)

    def _handle_eval(self) -> None:
        acc = float(self.aggregator.evaluate())
        self.evals.append((self.clock, self.version, acc))
        self.counters["evals"] += 1
        # accuracy deliberately stays OUT of the trace: the trace fingerprints
        # the event process, which must be identical across server strategies
        self._trace("eval", -1, f"v{self.version}")
        self._eval_scheduled = False
        if self.eval_every_time:
            nxt = self.clock + self.eval_every_time
            if nxt <= self.horizon:
                self.schedule(self.eval_every_time, "eval")
                self._eval_scheduled = True

    def _arm_eval(self) -> None:
        """(Re-)arm the eval chain up to the current horizon. The chain dies
        whenever the next tick would overshoot the horizon, so extending a
        finished run (``run(until=...)`` with a larger horizon) must re-seed
        it from the eval grid — not only the first ``run`` call."""
        if not self.eval_every_time or self._eval_scheduled:
            return
        k = int(np.floor(self.clock / self.eval_every_time)) + 1
        nxt = k * self.eval_every_time
        if nxt <= self.clock:              # clock exactly on a fired tick
            nxt += self.eval_every_time
        if nxt <= self.horizon:
            self.schedule(nxt - self.clock, "eval")
            self._eval_scheduled = True

    # ------------------------------------------------------------------ #
    # Aggregation
    # ------------------------------------------------------------------ #
    def aggregate(self) -> Optional[Dict[str, Any]]:
        """Flush the arrival buffer through the aggregator as one cohort.

        Arrivals are deduped per client (freshest base version wins; the
        superseded count is tracked) and sorted by client index so cohort
        order is deterministic and matches the round-synchronous server's
        (ascending-index) ordering. Realized staleness is measured against
        the CURRENT version, at consumption time.
        """
        if not self.buffer:
            self.counters["empty_triggers"] += 1
            self._trace("aggregate", -1, "empty")
            return None
        best: Dict[int, Arrival] = {}
        for a in self.buffer:
            b = best.get(a.client)
            if b is None or (a.base_version, a.arrival_time) > \
                    (b.base_version, b.arrival_time):
                best[a.client] = a
        self.counters["superseded"] += len(self.buffer) - len(best)
        self.buffer = []
        cohort = sorted(best.values(), key=lambda a: a.client)

        fresh: List[int] = []
        stale: List[Tuple[int, int]] = []
        taus = []
        for a in cohort:
            tau = self.version - a.base_version
            self.realized[a.client].append(tau)
            taus.append(tau)
            if tau == 0:
                fresh.append(a.client)
            else:
                stale.append((a.client, a.base_version))
        self._trace("aggregate", -1,
                    f"v{self.version} fresh{len(fresh)} stale{len(stale)}")
        with tracer.span("sim.aggregate") as _sp:
            _sp.arg("version", self.version)
            row = self.aggregator.aggregate(self.version, fresh, stale) or {}
        if tracer.enabled:
            tracer.metric(
                "aggregation", time=float(self.clock),
                version=int(self.version), n_fresh=len(fresh),
                n_stale=len(stale),
                n_base_rounds=len({b for _, b in stale}),
                mean_tau=float(sum(taus) / len(taus)) if taus else 0.0,
                tau_hist=np.bincount(taus).tolist() if taus else [])
        self.agg_log.append({"time": self.clock, "version": self.version,
                             "fresh": fresh, "stale": stale,
                             "taus": taus, **row})
        self.version += 1
        self.counters["aggregations"] += 1
        return row

    # ------------------------------------------------------------------ #
    # Main loop
    # ------------------------------------------------------------------ #
    def run(self, until: Optional[float] = None) -> Dict[str, Any]:
        if until is not None:
            self.horizon = float(until)
        if not self._started:
            self._started = True
            self.policy.start(self)
        else:
            # extending a finished run: the policy may need its timer chain
            # re-armed (it dies at the old horizon), but must NOT re-run
            # start() — that would double-dispatch the whole fleet
            self.policy.on_resume(self)
        self._arm_eval()
        with tracer.span("sim.run") as _sp:
            _sp.arg("engine", "heap")
            while self._heap:
                if self.counters["events"] >= self.max_events:
                    self._trace("halt", -1, "max_events")
                    break
                t, _, kind, client, payload = self._heap[0]
                if t > self.horizon:
                    break
                heapq.heappop(self._heap)
                self.clock = t
                self.counters["events"] += 1
                if kind == "dispatch":
                    self._handle_dispatch(client, payload.get("force", False))
                elif kind == "upload":
                    self._handle_upload(client, payload["job"])
                elif kind == "dropout":
                    self._handle_dropout(client, payload["job"])
                elif kind == "rejoin":
                    self._handle_rejoin(client)
                elif kind == "round":
                    self.policy.on_timer(self, payload)
                elif kind == "eval":
                    self._handle_eval()
        return self.summary()

    # ------------------------------------------------------------------ #
    # Reporting
    # ------------------------------------------------------------------ #
    def trace_digest(self) -> str:
        return trace_digest(self.trace)

    def realized_schedule(self, reducer: str = "mean") -> StalenessSchedule:
        """Observed-staleness view compatible with schedule consumers."""
        return observed_schedule(self.n_clients, self.realized, reducer)

    def summary(self) -> Dict[str, Any]:
        all_taus = [t for v in self.realized.values() for t in v]
        # snapshot first: reading a missing key off the defaultdict would
        # insert it, i.e. summary() would mutate the counters it reports
        c = dict(self.counters)
        out = {k: c.get(k, 0) for k in COUNTER_KEYS}
        out.update(c)      # any non-canonical counter is reported verbatim
        out.update({
            "clock": self.clock,
            "version": self.version,
            "buffer_pending": len(self.buffer),
            "inflight": len(self._inflight) + len(self._doomed),
            "clients_down": sum(1 for u in self.up if not u),
            "mean_realized_tau": (float(sum(all_taus) / len(all_taus))
                                  if all_taus else 0.0),
            "max_realized_tau": max(all_taus) if all_taus else 0,
            "trace_digest": self.trace_digest(),
            "n_evals": len(self.evals),
        })
        return out
