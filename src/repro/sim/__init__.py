"""Event-driven asynchronous FL simulation (see docs/async_simulator.md).

Layers: ``engine`` (virtual-clock event queue) · ``devices`` (stochastic
latency/dropout models) · ``policies`` (aggregation triggers) · ``bridge``
(adapter into ``repro.core.server.Server``) · ``scenarios`` (named,
seed-reproducible workloads; CLI via ``python -m repro.sim``).
"""

from repro.sim.bridge import RecordingAggregator, ServerBridge
from repro.sim.devices import (DeviceFleet, DeviceProfile, LatencyDist,
                               fleet_from_schedule, homogeneous_fleet,
                               intertwined_fleet)
from repro.sim.engine import Arrival, SimEngine
from repro.sim.policies import (FedBuffK, PureAsync, SemiSyncDeadline,
                                TriggerPolicy)
from repro.sim.scenarios import SimRun, build, describe, names, register

__all__ = [
    "Arrival", "DeviceFleet", "DeviceProfile", "FedBuffK", "LatencyDist",
    "PureAsync", "RecordingAggregator", "SemiSyncDeadline", "ServerBridge",
    "SimEngine", "SimRun", "TriggerPolicy", "build", "describe",
    "fleet_from_schedule", "homogeneous_fleet", "intertwined_fleet", "names",
    "register",
]
