"""Event-driven asynchronous FL simulation (see docs/async_simulator.md).

Layers: ``engine`` (virtual-clock event queue) · ``devices`` (stochastic
latency/dropout models) · ``policies`` (aggregation triggers) · ``bridge``
(adapter into ``repro.core.server.Server``) · ``scenarios`` (named,
seed-reproducible workloads; CLI via ``python -m repro.sim``).
"""

from repro.sim.bridge import (NullAggregator, RecordingAggregator,
                              ServerBridge)
from repro.sim.devices import (DeviceFleet, DeviceProfile, FleetArrays,
                               LatencyDist, fleet_from_schedule,
                               homogeneous_fleet, intertwined_fleet,
                               trace_fleet)
from repro.sim.engine import Arrival, SimEngine, trace_digest
from repro.sim.engine_vec import VecEngine
from repro.sim.policies import (FedBuffK, PureAsync, SemiSyncDeadline,
                                TriggerPolicy)
from repro.sim.scenarios import (SimRun, build, describe, engine_only, names,
                                 register)
from repro.sim.wheel import TimeWheel

__all__ = [
    "Arrival", "DeviceFleet", "DeviceProfile", "FedBuffK", "FleetArrays",
    "LatencyDist", "NullAggregator", "PureAsync", "RecordingAggregator",
    "SemiSyncDeadline", "ServerBridge", "SimEngine", "SimRun", "TimeWheel",
    "TriggerPolicy", "VecEngine", "build", "describe", "engine_only",
    "fleet_from_schedule", "homogeneous_fleet", "intertwined_fleet", "names",
    "register", "trace_digest", "trace_fleet",
]
