"""Stochastic device models for the event-driven simulator.

A client device is a ``DeviceProfile``: a compute-latency distribution, a
network-latency distribution, and an optional dropout process (per-job
failure probability + downtime distribution). A ``DeviceFleet`` holds one
profile per client; ``FleetArrays`` is the same fleet flattened to
struct-of-arrays form, which is what the vectorized engine samples — one
batched transform over a whole dispatch wave instead of one Python call
per job.

Randomness is counter-based (``repro.sim.rand``): every job owns a fixed
block of uniforms derived from ``(seed, job_id)``, and both engines map the
SAME block through the SAME elementwise transforms — so the heap oracle
(one job at a time) and the vectorized engine (one wave at a time) produce
bitwise-identical latencies, dropout decisions and downtimes.

Heavy-tail latency is the regime the paper targets (*unlimited* staleness):
``lognormal`` models the bulk of mobile-device variability, ``pareto`` the
stragglers whose delay has no useful upper bound (FedASMU / FedBuff device
models use the same two families), and ``trace`` replays an empirical
latency table (inverse empirical CDF) — the large-scale smartphone study
(arxiv 2006.06983) shows realistic fleets are best described by measured
per-device latency distributions rather than any parametric family.

``intertwined_fleet`` keeps the paper's core coupling: device speed tiers
are assigned to the top holders of a target class, so data heterogeneity
and device heterogeneity stay correlated exactly as
``repro.data.staleness.intertwined_schedule`` couples them for the
round-synchronous server.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.data.staleness import top_holders
from repro.sim.rand import (U_COMPUTE, U_COMPUTE2, U_DOWN, U_DOWN2, U_DROP,
                            U_NET, U_NET2, lognormal_from_uniforms,
                            pareto_from_uniforms, trace_from_uniforms)

LATENCY_KINDS = ("fixed", "lognormal", "pareto", "trace")
KIND_CODES = {k: i for i, k in enumerate(LATENCY_KINDS)}


@dataclasses.dataclass(frozen=True)
class LatencyDist:
    """One-parameter-family latency distribution.

    kind="fixed":     always ``loc`` (zero variance — the degenerate oracle).
    kind="lognormal": median ``loc``, log-space sigma ``spread``.
    kind="pareto":    scale ``loc``, tail index ``alpha = 1/spread``
                      (smaller spread = lighter tail; spread >= 1 means
                      infinite mean — genuinely unlimited staleness).
    kind="trace":     empirical inverse CDF over ``table`` (a tuple of
                      measured latencies, sorted at construction), scaled
                      by ``loc`` — the trace-derived device model.
    """

    kind: str = "fixed"
    loc: float = 1.0
    spread: float = 0.0
    table: Tuple[float, ...] = ()

    def __post_init__(self):
        if self.kind not in LATENCY_KINDS:
            raise ValueError(f"unknown latency kind: {self.kind}")
        if self.loc < 0 or self.spread < 0:
            raise ValueError(f"latency params must be >= 0: {self}")
        if self.kind == "trace":
            if len(self.table) == 0:
                raise ValueError("trace latency needs a non-empty table")
            if any(v < 0 for v in self.table):
                raise ValueError("trace table entries must be >= 0")
            object.__setattr__(self, "table",
                               tuple(sorted(float(v) for v in self.table)))
        elif self.table:
            raise ValueError(f"table only applies to kind='trace': {self}")
        # cached ndarray view of the quantile table (not a dataclass field:
        # equality/hash stay on the tuple)
        object.__setattr__(self, "_table_np",
                           np.asarray(self.table, dtype=np.float64))

    def from_uniforms(self, u1: float, u2: float = 0.0) -> float:
        """Map this job's uniform pair to a latency (scalar; bitwise equal
        to the vectorized ``FleetArrays`` path on the same uniforms)."""
        if self.kind == "trace":
            return float(trace_from_uniforms(self.loc, self._table_np, u1))
        if self.kind == "fixed" or self.spread == 0.0:
            return float(self.loc)
        if self.kind == "lognormal":
            return float(lognormal_from_uniforms(self.loc, self.spread,
                                                 u1, u2))
        return float(pareto_from_uniforms(self.loc, self.spread, u1))

    def sample(self, rng: np.random.Generator) -> float:
        """Draw from a free-running generator (diagnostics / tests; the
        engines themselves use per-job counter blocks)."""
        if self.kind == "fixed" or (self.spread == 0.0
                                    and self.kind != "trace"):
            return float(self.loc)           # draw-free, like the engines
        u = rng.random(2)
        return self.from_uniforms(u[0], u[1])


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    compute: LatencyDist = dataclasses.field(
        default_factory=lambda: LatencyDist("fixed", 1.0))
    network: LatencyDist = dataclasses.field(
        default_factory=lambda: LatencyDist("fixed", 0.0))
    dropout_prob: float = 0.0      # per-job probability the job is lost
    downtime: LatencyDist = dataclasses.field(
        default_factory=lambda: LatencyDist("fixed", 5.0))

    def job_latency(self, rng: np.random.Generator) -> float:
        return self.compute.sample(rng) + self.network.sample(rng)


class DeviceFleet:
    """One ``DeviceProfile`` per client."""

    def __init__(self, profiles: Sequence[DeviceProfile]):
        self.profiles: List[DeviceProfile] = list(profiles)
        self._arrays: Optional["FleetArrays"] = None

    def __len__(self) -> int:
        return len(self.profiles)

    # ---- per-job counter-block accessors (the heap oracle's path) ---- #
    def job_latency_from_block(self, client: int, u: np.ndarray) -> float:
        p = self.profiles[client]
        return (p.compute.from_uniforms(u[U_COMPUTE], u[U_COMPUTE2])
                + p.network.from_uniforms(u[U_NET], u[U_NET2]))

    def job_drops_from_block(self, client: int, u: np.ndarray) -> bool:
        return bool(u[U_DROP] < self.profiles[client].dropout_prob)

    def downtime_from_block(self, client: int, u: np.ndarray) -> float:
        return self.profiles[client].downtime.from_uniforms(u[U_DOWN],
                                                            u[U_DOWN2])

    # ---- free-running accessors (diagnostics / scenario summaries) ---- #
    def job_latency(self, rng: np.random.Generator, client: int) -> float:
        return self.profiles[client].job_latency(rng)

    def job_drops(self, rng: np.random.Generator, client: int) -> bool:
        p = self.profiles[client].dropout_prob
        return bool(p > 0.0 and rng.random() < p)

    def downtime(self, rng: np.random.Generator, client: int) -> float:
        return self.profiles[client].downtime.sample(rng)

    def mean_latency(self, client: int, n: int = 256, seed: int = 0) -> float:
        """Monte-Carlo mean job latency (diagnostics / scenario summaries)."""
        rng = np.random.default_rng(seed)
        return float(np.mean(
            [self.job_latency(rng, client) for _ in range(n)]))

    def arrays(self) -> "FleetArrays":
        """Struct-of-arrays view (cached) for the vectorized engine."""
        if self._arrays is None:
            self._arrays = FleetArrays.from_profiles(self.profiles)
        return self._arrays


# --------------------------------------------------------------------------- #
# Struct-of-arrays fleet
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class _FamilyArrays:
    """One latency family (compute / network / downtime) over all clients."""

    kind: np.ndarray          # int8 KIND_CODES
    loc: np.ndarray           # float64
    spread: np.ndarray        # float64
    table_idx: np.ndarray     # int32, -1 when not kind='trace'
    tables: List[np.ndarray]  # unique sorted quantile tables

    @classmethod
    def from_dists(cls, dists: Sequence[LatencyDist]) -> "_FamilyArrays":
        tables: List[np.ndarray] = []
        index: dict = {}
        kind = np.empty(len(dists), np.int8)
        loc = np.empty(len(dists), np.float64)
        spread = np.empty(len(dists), np.float64)
        tidx = np.full(len(dists), -1, np.int32)
        for i, d in enumerate(dists):
            kind[i] = KIND_CODES[d.kind]
            loc[i] = d.loc
            spread[i] = d.spread
            if d.kind == "trace":
                if d.table not in index:
                    index[d.table] = len(tables)
                    tables.append(np.asarray(d.table, np.float64))
                tidx[i] = index[d.table]
        return cls(kind, loc, spread, tidx, tables)

    @classmethod
    def broadcast(cls, dist: LatencyDist, n: int) -> "_FamilyArrays":
        tables = ([np.asarray(dist.table, np.float64)]
                  if dist.kind == "trace" else [])
        return cls(np.full(n, KIND_CODES[dist.kind], np.int8),
                   np.full(n, dist.loc, np.float64),
                   np.full(n, dist.spread, np.float64),
                   np.full(n, 0 if tables else -1, np.int32), tables)

    def sample(self, cl: np.ndarray, u1: np.ndarray,
               u2: np.ndarray) -> np.ndarray:
        """Latencies for clients ``cl`` from their jobs' uniform columns —
        one masked elementwise transform per family present in the wave."""
        kind, loc, spread = self.kind[cl], self.loc[cl], self.spread[cl]
        m = (kind == KIND_CODES["lognormal"]) & (spread > 0.0)
        if m.all():                            # single-family wave: no
            return lognormal_from_uniforms(loc, spread, u1, u2)  # scatter
        out = loc.copy()                       # fixed / spread==0: just loc
        if m.any():
            out[m] = lognormal_from_uniforms(loc[m], spread[m], u1[m], u2[m])
        m = (kind == KIND_CODES["pareto"]) & (spread > 0.0)
        if m.any():
            out[m] = pareto_from_uniforms(loc[m], spread[m], u1[m])
        m = kind == KIND_CODES["trace"]
        if m.any():
            tidx = self.table_idx[cl]
            for ti in np.unique(tidx[m]):
                mm = m & (tidx == ti)
                out[mm] = trace_from_uniforms(loc[mm], self.tables[ti],
                                              u1[mm])
        return out


@dataclasses.dataclass
class FleetArrays:
    """A whole fleet as parallel per-client arrays.

    The vectorized engine's device model: a dispatch wave of ``k`` jobs
    costs O(1) Python calls — gather the wave's uniform blocks, push each
    latency family through one masked transform, compare one column against
    ``dropout_prob``. Construct from profiles (``DeviceFleet.arrays()``)
    or directly via ``FleetArrays.homogeneous`` when materializing millions
    of ``DeviceProfile`` objects would itself be the bottleneck.
    """

    compute: _FamilyArrays
    network: _FamilyArrays
    dropout_prob: np.ndarray
    downtime: _FamilyArrays

    def __len__(self) -> int:
        return len(self.dropout_prob)

    @classmethod
    def from_profiles(cls, profiles: Sequence[DeviceProfile]) -> "FleetArrays":
        return cls(
            _FamilyArrays.from_dists([p.compute for p in profiles]),
            _FamilyArrays.from_dists([p.network for p in profiles]),
            np.asarray([p.dropout_prob for p in profiles], np.float64),
            _FamilyArrays.from_dists([p.downtime for p in profiles]))

    @classmethod
    def homogeneous(cls, n_clients: int, compute: LatencyDist,
                    network: Optional[LatencyDist] = None,
                    dropout_prob: float = 0.0,
                    downtime: Optional[LatencyDist] = None) -> "FleetArrays":
        """Broadcast one profile to ``n_clients`` without building objects."""
        return cls(
            _FamilyArrays.broadcast(compute, n_clients),
            _FamilyArrays.broadcast(network or LatencyDist("fixed", 0.0),
                                    n_clients),
            np.full(n_clients, float(dropout_prob), np.float64),
            _FamilyArrays.broadcast(downtime or LatencyDist("fixed", 5.0),
                                    n_clients))

    def job_latency(self, cl: np.ndarray, u: np.ndarray) -> np.ndarray:
        """compute + network latency for a wave (``u`` is ``(k, N_U)``)."""
        return (self.compute.sample(cl, u[:, U_COMPUTE], u[:, U_COMPUTE2])
                + self.network.sample(cl, u[:, U_NET], u[:, U_NET2]))

    def job_drops(self, cl: np.ndarray, u: np.ndarray) -> np.ndarray:
        return u[:, U_DROP] < self.dropout_prob[cl]

    def downtime_of(self, cl: np.ndarray, u: np.ndarray) -> np.ndarray:
        return self.downtime.sample(cl, u[:, U_DOWN], u[:, U_DOWN2])


# --------------------------------------------------------------------------- #
# Fleet constructors
# --------------------------------------------------------------------------- #


def homogeneous_fleet(n_clients: int, latency: LatencyDist,
                      network: Optional[LatencyDist] = None,
                      dropout_prob: float = 0.0,
                      downtime: Optional[LatencyDist] = None) -> DeviceFleet:
    prof = DeviceProfile(
        compute=latency,
        network=network or LatencyDist("fixed", 0.0),
        dropout_prob=dropout_prob,
        downtime=downtime or LatencyDist("fixed", 5.0))
    return DeviceFleet([prof] * n_clients)


def intertwined_fleet(label_histograms: np.ndarray, target_class: int,
                      n_slow: int, slow: LatencyDist, fast: LatencyDist,
                      network: Optional[LatencyDist] = None,
                      dropout_prob: float = 0.0,
                      slow_dropout_prob: Optional[float] = None,
                      downtime: Optional[LatencyDist] = None) -> DeviceFleet:
    """Device tiers correlated with label skew (the paper's coupling).

    The top-``n_slow`` holders of ``target_class`` get the ``slow`` compute
    distribution (and optionally a higher dropout rate); everyone else gets
    ``fast``. Selection goes through ``repro.data.staleness.top_holders`` —
    the same helper ``intertwined_schedule`` uses — so a fleet and a
    schedule built from the same histograms pick the same clients.
    """
    slow_ids = set(
        top_holders(label_histograms, target_class, n_slow).tolist())
    network = network or LatencyDist("fixed", 0.0)
    downtime = downtime or LatencyDist("fixed", 5.0)
    if slow_dropout_prob is None:
        slow_dropout_prob = dropout_prob
    profiles = []
    for i in range(label_histograms.shape[0]):
        is_slow = i in slow_ids
        profiles.append(DeviceProfile(
            compute=slow if is_slow else fast,
            network=network,
            dropout_prob=slow_dropout_prob if is_slow else dropout_prob,
            downtime=downtime))
    return DeviceFleet(profiles)


def fleet_from_schedule(staleness: Sequence[int],
                        round_len: float = 1.0) -> DeviceFleet:
    """The degenerate zero-variance fleet that replays a static schedule.

    Under a pipelined semi-sync deadline policy (dispatch every client at
    every round tick, aggregate at every tick), a client with scheduled tau
    must land its update in the aggregation window ``(s + tau*L, s + (tau+1)*L]``
    when dispatched at tick ``s`` — fixed latency ``(tau + 0.5) * round_len``
    puts it mid-window, away from tick-boundary ties. Fast clients (tau=0)
    get ``0.5 * round_len`` and arrive within their own round. This is the
    bit-for-bit oracle mapping used by ``tests/test_sim.py``.
    """
    return DeviceFleet([
        DeviceProfile(compute=LatencyDist(
            "fixed", (float(tau) + 0.5) * round_len))
        for tau in staleness])


def trace_fleet(n_clients: int, table: Sequence[float],
                loc_spread: float = 0.0, seed: int = 0,
                network: Optional[LatencyDist] = None,
                dropout_prob: float = 0.0,
                downtime: Optional[LatencyDist] = None) -> DeviceFleet:
    """Trace-derived fleet: every client replays the empirical latency
    ``table``; ``loc_spread > 0`` additionally scatters per-client scale
    factors ``lognormal(1, loc_spread)`` (deterministic in ``seed``), the
    standard device-speed spread on top of a shared measured distribution.
    """
    table = tuple(float(v) for v in table)
    rng = np.random.default_rng(seed)
    network = network or LatencyDist("fixed", 0.0)
    downtime = downtime or LatencyDist("fixed", 5.0)
    profiles = []
    for _ in range(n_clients):
        loc = (float(np.exp(loc_spread * rng.standard_normal()))
               if loc_spread > 0 else 1.0)
        profiles.append(DeviceProfile(
            compute=LatencyDist("trace", loc, table=table),
            network=network, dropout_prob=dropout_prob, downtime=downtime))
    return DeviceFleet(profiles)
