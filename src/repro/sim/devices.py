"""Stochastic device models for the event-driven simulator.

A client device is a ``DeviceProfile``: a compute-latency distribution, a
network-latency distribution, and an optional dropout process (per-job
failure probability + downtime distribution). A ``DeviceFleet`` holds one
profile per client and is the engine's single source of randomness for
device behaviour — every sample goes through the engine's seeded
``numpy.random.Generator``, so a (scenario, seed) pair replays exactly.

Heavy-tail latency is the regime the paper targets (*unlimited* staleness):
``lognormal`` models the bulk of mobile-device variability, ``pareto`` the
stragglers whose delay has no useful upper bound (FedASMU / FedBuff device
models use the same two families).

``intertwined_fleet`` keeps the paper's core coupling: device speed tiers
are assigned to the top holders of a target class, so data heterogeneity
and device heterogeneity stay correlated exactly as
``repro.data.staleness.intertwined_schedule`` couples them for the
round-synchronous server.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.data.staleness import top_holders


@dataclasses.dataclass(frozen=True)
class LatencyDist:
    """One-parameter-family latency distribution.

    kind="fixed":     always ``loc`` (zero variance — the degenerate oracle).
    kind="lognormal": median ``loc``, log-space sigma ``spread``.
    kind="pareto":    scale ``loc``, tail index ``alpha = 1/spread``
                      (smaller spread = lighter tail; spread >= 1 means
                      infinite mean — genuinely unlimited staleness).
    """

    kind: str = "fixed"
    loc: float = 1.0
    spread: float = 0.0

    def __post_init__(self):
        if self.kind not in ("fixed", "lognormal", "pareto"):
            raise ValueError(f"unknown latency kind: {self.kind}")
        if self.loc < 0 or self.spread < 0:
            raise ValueError(f"latency params must be >= 0: {self}")

    def sample(self, rng: np.random.Generator) -> float:
        if self.kind == "fixed" or self.spread == 0.0:
            return float(self.loc)
        if self.kind == "lognormal":
            return float(self.loc * np.exp(self.spread * rng.standard_normal()))
        # pareto: inverse-CDF on the open interval so the tail is unbounded
        u = rng.random()
        return float(self.loc * (1.0 - u) ** (-self.spread))


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    compute: LatencyDist = dataclasses.field(
        default_factory=lambda: LatencyDist("fixed", 1.0))
    network: LatencyDist = dataclasses.field(
        default_factory=lambda: LatencyDist("fixed", 0.0))
    dropout_prob: float = 0.0      # per-job probability the job is lost
    downtime: LatencyDist = dataclasses.field(
        default_factory=lambda: LatencyDist("fixed", 5.0))

    def job_latency(self, rng: np.random.Generator) -> float:
        return self.compute.sample(rng) + self.network.sample(rng)


class DeviceFleet:
    """One ``DeviceProfile`` per client."""

    def __init__(self, profiles: Sequence[DeviceProfile]):
        self.profiles: List[DeviceProfile] = list(profiles)

    def __len__(self) -> int:
        return len(self.profiles)

    def job_latency(self, rng: np.random.Generator, client: int) -> float:
        return self.profiles[client].job_latency(rng)

    def job_drops(self, rng: np.random.Generator, client: int) -> bool:
        p = self.profiles[client].dropout_prob
        return bool(p > 0.0 and rng.random() < p)

    def downtime(self, rng: np.random.Generator, client: int) -> float:
        return self.profiles[client].downtime.sample(rng)

    def mean_latency(self, client: int, n: int = 256, seed: int = 0) -> float:
        """Monte-Carlo mean job latency (diagnostics / scenario summaries)."""
        rng = np.random.default_rng(seed)
        return float(np.mean(
            [self.job_latency(rng, client) for _ in range(n)]))


# --------------------------------------------------------------------------- #
# Fleet constructors
# --------------------------------------------------------------------------- #


def homogeneous_fleet(n_clients: int, latency: LatencyDist,
                      network: Optional[LatencyDist] = None,
                      dropout_prob: float = 0.0,
                      downtime: Optional[LatencyDist] = None) -> DeviceFleet:
    prof = DeviceProfile(
        compute=latency,
        network=network or LatencyDist("fixed", 0.0),
        dropout_prob=dropout_prob,
        downtime=downtime or LatencyDist("fixed", 5.0))
    return DeviceFleet([prof] * n_clients)


def intertwined_fleet(label_histograms: np.ndarray, target_class: int,
                      n_slow: int, slow: LatencyDist, fast: LatencyDist,
                      network: Optional[LatencyDist] = None,
                      dropout_prob: float = 0.0,
                      slow_dropout_prob: Optional[float] = None,
                      downtime: Optional[LatencyDist] = None) -> DeviceFleet:
    """Device tiers correlated with label skew (the paper's coupling).

    The top-``n_slow`` holders of ``target_class`` get the ``slow`` compute
    distribution (and optionally a higher dropout rate); everyone else gets
    ``fast``. Selection goes through ``repro.data.staleness.top_holders`` —
    the same helper ``intertwined_schedule`` uses — so a fleet and a
    schedule built from the same histograms pick the same clients.
    """
    slow_ids = set(
        top_holders(label_histograms, target_class, n_slow).tolist())
    network = network or LatencyDist("fixed", 0.0)
    downtime = downtime or LatencyDist("fixed", 5.0)
    if slow_dropout_prob is None:
        slow_dropout_prob = dropout_prob
    profiles = []
    for i in range(label_histograms.shape[0]):
        is_slow = i in slow_ids
        profiles.append(DeviceProfile(
            compute=slow if is_slow else fast,
            network=network,
            dropout_prob=slow_dropout_prob if is_slow else dropout_prob,
            downtime=downtime))
    return DeviceFleet(profiles)


def fleet_from_schedule(staleness: Sequence[int],
                        round_len: float = 1.0) -> DeviceFleet:
    """The degenerate zero-variance fleet that replays a static schedule.

    Under a pipelined semi-sync deadline policy (dispatch every client at
    every round tick, aggregate at every tick), a client with scheduled tau
    must land its update in the aggregation window ``(s + tau*L, s + (tau+1)*L]``
    when dispatched at tick ``s`` — fixed latency ``(tau + 0.5) * round_len``
    puts it mid-window, away from tick-boundary ties. Fast clients (tau=0)
    get ``0.5 * round_len`` and arrive within their own round. This is the
    bit-for-bit oracle mapping used by ``tests/test_sim.py``.
    """
    return DeviceFleet([
        DeviceProfile(compute=LatencyDist(
            "fixed", (float(tau) + 0.5) * round_len))
        for tau in staleness])
