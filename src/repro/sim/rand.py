"""Counter-based per-job randomness shared by both simulation engines.

Every dispatched job ``j`` owns a fixed block of ``N_U`` uniforms derived
from a Philox counter generator keyed on the engine seed with the counter
pinned to the job id. Because Philox is counter-based there is no shared
sequential stream to keep aligned: the heap oracle can materialize one
job's block at a time while the vectorized engine draws a whole dispatch
wave (consecutive job ids) as ONE ``Generator.random`` call — and the two
are bitwise identical (``tests/test_sim_vec.py`` pins this).

Block layout (``U_*`` indices): compute latency (2 uniforms), network
latency (2), the per-job dropout Bernoulli draw (1), the doomed-job failure
fraction (1), and the post-dropout downtime (2). Latency families consume
their uniforms through the elementwise transforms below; families that need
fewer than two uniforms simply ignore the rest of their slot — skipping a
draw never desynchronizes anything, which is what makes the zero-variance
oracle free of RNG cost on both engines.

The transforms deliberately avoid ``np.power`` (whose SIMD and scalar
paths differ in the last ulp on some numpy builds): everything routes
through ``log1p``/``exp``/``sqrt``/``cos``, which produce bitwise-equal
results for the same float64 input whether called on a 100k-element wave
or one scalar at a time.
"""

from __future__ import annotations

import numpy as np

N_U = 8                    # uniforms per job block
_BLOCKS_PER_JOB = 2        # 8 doubles == 2 Philox 4x64 counter blocks

(U_COMPUTE, U_COMPUTE2, U_NET, U_NET2,
 U_DROP, U_FRAC, U_DOWN, U_DOWN2) = range(N_U)


def job_uniforms(seed: int, job0: int, n: int = 1) -> np.ndarray:
    """``(n, N_U)`` float64 uniforms for jobs ``job0 .. job0+n-1``.

    One Philox construction + one ``random`` call per wave; slicing a
    bigger wave and drawing a sub-wave at the right counter offset give
    bitwise-identical blocks.
    """
    bg = np.random.Philox(key=int(seed), counter=_BLOCKS_PER_JOB * int(job0))
    return np.random.Generator(bg).random(int(n) * N_U).reshape(int(n), N_U)


def gauss_from_uniforms(u1, u2):
    """Box-Muller: exactly two uniforms per normal deviate (elementwise).

    Unlike the ziggurat behind ``Generator.standard_normal`` this consumes
    a FIXED number of uniforms, so a job's stream position is a pure
    function of its job id. The array branch runs the same IEEE op
    sequence in-place on fresh temporaries (multiplication commutes
    bitwise), halving the allocations on a 100k-job wave.
    """
    if isinstance(u1, np.ndarray) and u1.ndim:
        r = np.log1p(np.negative(u1))
        r *= -2.0
        np.sqrt(r, out=r)
        c = u2 * (2.0 * np.pi)
        np.cos(c, out=c)
        r *= c
        return r
    return np.sqrt(-2.0 * np.log1p(-u1)) * np.cos(2.0 * np.pi * u2)


def lognormal_from_uniforms(loc, spread, u1, u2):
    """Median ``loc``, log-space sigma ``spread`` (elementwise)."""
    g = gauss_from_uniforms(u1, u2)
    if isinstance(g, np.ndarray) and g.ndim:
        g *= spread
        np.exp(g, out=g)
        g *= loc
        return g
    return loc * np.exp(spread * g)


def pareto_from_uniforms(loc, spread, u1):
    """Scale ``loc``, tail index ``1/spread`` via inverse CDF on the open
    interval (elementwise; ``(1-u)**-s`` spelled as ``exp``/``log1p`` so
    scalar and SIMD evaluations agree bitwise)."""
    return loc * np.exp(-spread * np.log1p(-u1))


def trace_from_uniforms(loc, table: np.ndarray, u1):
    """Empirical inverse CDF: ``u`` indexes the sorted latency table
    (step-function quantile), scaled by ``loc`` (elementwise)."""
    n = len(table)
    idx = np.minimum((np.asarray(u1) * n).astype(np.int64), n - 1)
    return loc * table[idx]


class JobRandoms:
    """Chunk-cached accessor for the heap oracle's one-job-at-a-time path.

    Materializes ``job_uniforms`` in aligned chunks so the per-event engine
    does not pay a Philox construction per job; values are bitwise the same
    as any other slicing of the counter stream.
    """

    CHUNK = 256

    def __init__(self, seed: int):
        self.seed = int(seed)
        self._chunk0 = -1
        self._chunk: np.ndarray | None = None

    def block(self, job_id: int) -> np.ndarray:
        c0 = (job_id // self.CHUNK) * self.CHUNK
        if c0 != self._chunk0:
            self._chunk0 = c0
            self._chunk = job_uniforms(self.seed, c0, self.CHUNK)
        return self._chunk[job_id - c0]
