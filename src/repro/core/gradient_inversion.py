"""Gradient inversion engine (paper §3.1) — the core contribution.

Given a stale update ``w_i^{t-tau}`` computed from the outdated global model
``w_global^{t-tau}``, recover a synthetic dataset ``D_rec = (x', y')`` by
minimizing (Eq. 6)::

    Disparity[ LocalUpdate(w_global^{t-tau}; D_rec),  w_i^{t-tau} ]

with gradient descent on (x', y'). Differences vs classic gradient inversion
(Zhu et al.) that the paper introduces, all implemented here:

* the *multi-step local training program* replaces the single gradient — we
  differentiate through the scanned LocalUpdate;
* the metric is **L1-norm** of the weight change, not cosine (Appendix D),
  because D_rec is large (default |D_rec| = |D_i| / 2);
* optional **top-K sparsification** of the objective (§3.3a);
* optional **warm start** from the previous round's D_rec (§3.3b);
* labels are recovered as unconstrained *soft logits* — the server never
  obtains hard labels (§3.4).

The unstale estimate is then ``w_hat_i^t = LocalUpdate(w_global^t; D_rec)``.

Two execution engines:

* ``invert`` — the sequential reference: a Python loop of jitted Adam steps,
  one client at a time (the seed implementation, kept as the oracle for the
  batched path's equivalence tests and for benchmarking).
* ``invert_batch`` — the production engine: the whole optimization is a
  ``lax.while_loop`` inside ONE jitted call (early stop via the loop
  predicate, loss history written into a fixed-size buffer), ``vmap``-ed over
  all unique stale clients delivering in a round. Stacked
  ``(w_base, w_stale, mask, drec_init)`` pytrees in, stacked ``D_rec`` out —
  no per-iteration or per-client Python dispatch. Batch sizes are padded to
  the next power of two so recompiles are O(log B) instead of O(#distinct B).

Passing ``mesh=`` (a ``(pod, data)`` mesh from
``repro.launch.mesh.make_server_mesh``) shards the batched engine over
devices with ``shard_map``: the cohort axis splits across shards, each shard
runs its own vmapped while_loop (so a shard whose lanes all early-stop
finishes independently — no cross-device lockstep), and the pow2 compile
buckets become *per-shard* buckets. A 1-device mesh dispatches to the
unsharded engine and is therefore bit-for-bit identical to ``mesh=None``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.client import LocalProgram, make_local_update
from repro.core.disparity import (l1_disparity, tree_pad_leading, tree_sub,
                                  tree_take_leading, tree_to_vector)
from repro.launch.mesh import mesh_shard_count, shard_map_compat
from repro.launch.sharding import cohort_spec, replicated_spec, shard_bucket
from repro.optim import adam, apply_updates


@dataclasses.dataclass(frozen=True)
class GIConfig:
    n_rec: int = 32                 # |D_rec| (paper: ~ |D_i| / 2, App. D)
    iters: int = 200                # GI iterations per round
    lr: float = 0.1                 # Adam lr on (x', y')
    keep_fraction: float = 1.0      # 1.0 = no sparsification; 0.05 = top-5%
    metric: str = "l1"              # l1 (paper App. D) | cosine
    init_scale: float = 0.1
    tol: float = 0.0                # early-stop threshold on the GI loss
    warm_start: bool = True


# kept under their historic names for the module's internal call sites
_pad_leading = tree_pad_leading
_take_leading = tree_take_leading


class GradientInverter:
    """Builds and runs the jitted GI optimization for a given small model."""

    def __init__(self, apply_fn: Callable, input_shape: Tuple[int, ...],
                 n_classes: int, program: LocalProgram, cfg: GIConfig,
                 mesh: Optional[jax.sharding.Mesh] = None):
        self.apply_fn = apply_fn
        self.input_shape = tuple(input_shape)
        self.n_classes = n_classes
        self.program = program
        self.cfg = cfg
        # (pod, data) cohort mesh; >1 shard routes the batched engine
        # through shard_map (a 1-shard mesh is bit-for-bit the plain engine)
        self.mesh = mesh
        self.n_shards = mesh_shard_count(mesh)
        self.local_update = make_local_update(apply_fn, program)
        self._step = jax.jit(self._make_step())
        # single-compile engines (cached jits; satellite: no per-call re-jit)
        self._estimate_one = jax.jit(
            lambda w, x, y: self.local_update(w, x, y)[0])
        self._estimate_many = jax.jit(jax.vmap(
            lambda w, x, y: self.local_update(w, x, y)[0],
            in_axes=(None, 0, 0)))
        self._init_many = jax.jit(jax.vmap(self.init_drec))
        # vmapped whole-optimization inversion, one compiled fn per static
        # max_iters (normally just cfg.iters) — every dynamic per-client
        # iteration budget <= max_iters reuses the same executable
        self._invert_many_cache: Dict[int, Callable] = {}
        # sharded variants, keyed by (max_iters, has_mask)
        self._invert_sharded_cache: Dict[Tuple[int, bool], Callable] = {}
        self._estimate_sharded: Optional[Callable] = None

    def _get_invert_many(self, max_iters: int) -> Callable:
        fn = self._invert_many_cache.get(max_iters)
        if fn is None:
            core = partial(self._invert_core, max_iters=max_iters)
            fn = jax.jit(jax.vmap(core, in_axes=(0, 0, 0, 0, 0)))
            self._invert_many_cache[max_iters] = fn
        return fn

    def _get_invert_many_sharded(self, max_iters: int, has_mask: bool
                                 ) -> Callable:
        """shard_map over the cohort axis: each shard runs the same vmapped
        while_loop on its local pow2 bucket. All operands are stacked on the
        batch axis, so there is no cross-shard communication — shards with
        early-stopping lanes finish independently instead of waiting for the
        slowest lane of the whole cohort. Always built over ``self.mesh``
        (the cache key assumes it)."""
        mesh = self.mesh
        key = (max_iters, has_mask)
        fn = self._invert_sharded_cache.get(key)
        if fn is None:
            core = partial(self._invert_core, max_iters=max_iters)
            vm = jax.vmap(core, in_axes=(0, 0, 0, 0, 0))
            ax = cohort_spec(mesh)
            if has_mask:
                body, n_in = vm, 5
            else:
                body = lambda wg, tgt, d0, ni: vm(wg, tgt, None, d0, ni)  # noqa: E731
                n_in = 4
            fn = jax.jit(shard_map_compat(
                body, mesh, in_specs=(ax,) * n_in, out_specs=ax))
            self._invert_sharded_cache[key] = fn
        return fn

    # ------------------------------------------------------------------ #
    def init_drec(self, key: jax.Array) -> Tuple[jax.Array, jax.Array]:
        kx, ky = jax.random.split(key)
        x = jax.random.normal(kx, (self.cfg.n_rec, *self.input_shape),
                              jnp.float32) * self.cfg.init_scale
        y = jax.random.normal(ky, (self.cfg.n_rec, self.n_classes),
                              jnp.float32) * self.cfg.init_scale
        return x, y

    def _gi_loss(self, drec, w_global_stale, target_update, mask):
        x, y = drec
        w_trained, _ = self.local_update(w_global_stale, x, y)
        est_update = tree_sub(w_trained, w_global_stale)
        if self.cfg.metric == "l1":
            return l1_disparity(est_update, target_update, mask)
        ve = tree_to_vector(est_update)
        vt = tree_to_vector(target_update)
        if mask is not None:
            m = mask.astype(jnp.float32)
            ve, vt = ve * m, vt * m
        return 1.0 - jnp.dot(ve, vt) / jnp.maximum(
            jnp.linalg.norm(ve) * jnp.linalg.norm(vt), 1e-12)

    def _make_step(self):
        opt = adam(self.cfg.lr)

        def step(drec, opt_state, w_global_stale, target_update, mask):
            loss, grads = jax.value_and_grad(self._gi_loss)(
                drec, w_global_stale, target_update, mask)
            updates, opt_state = opt.update(grads, opt_state, drec)
            drec = apply_updates(drec, updates)
            return drec, opt_state, loss

        return step

    # ------------------------------------------------------------------ #
    def _invert_core(self, w_global_stale, target_update, mask, drec0,
                     n_iters, *, max_iters: int):
        """One client's full GI optimization as a single ``lax.while_loop``.

        ``n_iters`` is a dynamic iteration budget (<= static ``max_iters``);
        early stopping on ``cfg.tol`` is part of the loop predicate — checked
        after iterations 0, 10, 20, ... exactly like the sequential seed
        path, so tol-enabled configs keep the batched==sequential
        equivalence. The per-iteration loss history is written into a fixed
        ``(max_iters,)`` buffer (NaN beyond the iterations actually used).
        vmap lifts the while_loop to run until every lane has stopped.
        """
        opt = adam(self.cfg.lr)
        tol = self.cfg.tol

        def cond(carry):
            i, _, _, _, loss = carry
            not_done = i < n_iters
            if tol:
                # i iterations completed; the last one had index i-1. Match
                # the seed's cadence: break only when that index % 10 == 0.
                at_check = (i > 0) & ((i - 1) % 10 == 0)
                not_done = not_done & ~(at_check & (loss < tol))
            return not_done

        def body(carry):
            i, drec, opt_state, losses, _ = carry
            loss, grads = jax.value_and_grad(self._gi_loss)(
                drec, w_global_stale, target_update, mask)
            updates, opt_state = opt.update(grads, opt_state, drec)
            drec = apply_updates(drec, updates)
            losses = losses.at[i].set(loss)
            return i + 1, drec, opt_state, losses, loss

        carry0 = (jnp.zeros((), jnp.int32), drec0, opt.init(drec0),
                  jnp.full((max_iters,), jnp.nan, jnp.float32),
                  jnp.full((), jnp.inf, jnp.float32))
        used, drec, _, losses, final_loss = jax.lax.while_loop(
            cond, body, carry0)
        return drec, losses, final_loss, used

    def invert_batch(
        self,
        w_global_stale: Any,
        w_stale: Any,
        keys: jax.Array,
        masks: Optional[jax.Array] = None,
        inits: Optional[Tuple[jax.Array, jax.Array]] = None,
        init_flags: Optional[jax.Array] = None,
        iters: Optional[Any] = None,
    ) -> Tuple[Tuple[jax.Array, jax.Array], Dict[str, Any]]:
        """Batched inversion of B stale clients in ONE jitted call.

        Args:
          w_global_stale / w_stale: pytrees stacked on a leading (B,) axis —
            each client may come from a *different* base round.
          keys: (B, 2) PRNG keys for cold-start D_rec initialization.
          masks: optional (B, n_params) boolean sparsification masks.
          inits: optional stacked warm-start D_rec ``(x (B, n_rec, ...),
            y (B, n_rec, C))`` — used where ``init_flags`` is True.
          init_flags: (B,) bool; False rows fall back to the fresh random init.
          iters: scalar or (B,) per-client iteration budgets (default
            ``cfg.iters``). Budgets <= ``cfg.iters`` reuse one compiled
            executable; a budget above it raises the static loop bound and
            costs a fresh compile.

        Returns ``((x', y') stacked, info)`` with per-client ``losses``
        (B, max_iters; NaN past the used prefix), ``final_loss`` and
        ``iters_used`` arrays.

        With a multi-shard ``mesh``, the batch is padded to ``n_shards``
        equal per-shard pow2 buckets and run through the shard_map engine;
        on a 1-shard mesh (or ``mesh=None``) the bucket reduces to the
        global pow2 bucket and the plain vmapped engine runs — the same
        computation, bit for bit.
        """
        B = jax.tree_util.tree_leaves(w_stale)[0].shape[0]
        target = tree_sub(w_stale, w_global_stale)

        max_iters = int(self.cfg.iters)
        if iters is None:
            n_iters = jnp.full((B,), max_iters, jnp.int32)
        else:
            n_arr = jnp.asarray(iters, jnp.int32)
            max_iters = max(max_iters, int(jnp.max(n_arr)))
            n_iters = jnp.broadcast_to(n_arr, (B,))

        # pad the batch to per-shard pow2 buckets (global pow2 when
        # unsharded): one compile per bucket, padded lanes get n_iters=0 so
        # the vmapped while_loop masks them out
        Bp = shard_bucket(B, self.n_shards)
        pad = Bp - B

        # cold-start inits are padded BEFORE blending so warm starts may
        # arrive either unpadded (B) or already bucketed (Bp, e.g. from
        # ``WarmStartCache.gather_sharded``); padded lanes always run from
        # the repeated fresh row and are discarded
        fresh = _pad_leading(self._init_many(keys), pad)
        if inits is not None:
            Bi = jax.tree_util.tree_leaves(inits)[0].shape[0]
            if Bi == B:
                inits = _pad_leading(inits, pad)
            elif Bi != Bp:
                raise ValueError(f"inits leading dim {Bi} is neither the "
                                 f"cohort size {B} nor its bucket {Bp}")
            if init_flags is None:
                drec0 = inits
            else:
                flags = jnp.concatenate(
                    [jnp.asarray(init_flags, bool),
                     jnp.zeros((Bp - init_flags.shape[0],), bool)])
                drec0 = jax.tree_util.tree_map(
                    lambda w, c: jnp.where(
                        flags.reshape((Bp,) + (1,) * (w.ndim - 1)), w, c),
                    inits, fresh)
        else:
            drec0 = fresh

        args = (_pad_leading(w_global_stale, pad), _pad_leading(target, pad),
                None if masks is None else _pad_leading(masks, pad),
                drec0,
                jnp.concatenate([n_iters, jnp.zeros((pad,), jnp.int32)]))
        if self.n_shards > 1:
            fn = self._get_invert_many_sharded(max_iters, masks is not None)
            args = args[:2] + args[3:] if masks is None else args
            drec, losses, final_loss, used = fn(*args)
        else:
            drec, losses, final_loss, used = \
                self._get_invert_many(max_iters)(*args)
        drec = _take_leading(drec, B)
        info = {"losses": losses[:B], "final_loss": final_loss[:B],
                "iters_used": used[:B], "batch": B, "padded_to": Bp,
                "n_shards": self.n_shards}
        return drec, info

    # ------------------------------------------------------------------ #
    def invert(
        self,
        w_global_stale: Any,
        w_stale: Any,
        key: jax.Array,
        mask: Optional[jax.Array] = None,
        init: Optional[Tuple[jax.Array, jax.Array]] = None,
        iters: Optional[int] = None,
    ) -> Tuple[Tuple[jax.Array, jax.Array], Dict[str, Any]]:
        """Sequential reference path: recover D_rec from one stale update.

        Kept as the seed implementation (Python-dispatched jitted steps) so
        the batched engine has an oracle to be tested against; the server's
        hot path uses ``invert_batch``. Returns ((x', y'), info).
        """
        target_update = tree_sub(w_stale, w_global_stale)
        drec = init if init is not None else self.init_drec(key)
        opt_state = adam(self.cfg.lr).init(drec)
        n_iters = iters if iters is not None else self.cfg.iters
        losses = []
        used = 0
        for i in range(n_iters):
            drec, opt_state, loss = self._step(
                drec, opt_state, w_global_stale, target_update, mask)
            used += 1
            if i % 10 == 0 or i == n_iters - 1:
                losses.append(float(loss))
                if self.cfg.tol and losses[-1] < self.cfg.tol:
                    break
        info = {"losses": losses, "final_loss": losses[-1] if losses else None,
                "iters_used": used}
        return drec, info

    # ------------------------------------------------------------------ #
    def estimate_unstale(self, w_global_now: Any,
                         drec: Tuple[jax.Array, jax.Array]) -> Any:
        """w_hat_i^t = LocalUpdate(w_global^t; D_rec) (paper Fig. 2)."""
        x, y = drec
        return self._estimate_one(w_global_now, x, y)

    def estimate_unstale_batch(self, w_global_now: Any,
                               drec: Tuple[jax.Array, jax.Array]) -> Any:
        """Stacked w_hat for a batch of D_rec (one jitted vmap call).

        On a multi-shard mesh the D_rec batch shards on the cohort axis and
        ``w_global_now`` replicates (it is the one cohort-invariant
        operand); a 1-shard mesh uses the plain vmap bit-for-bit.
        """
        x, y = drec
        if self.n_shards <= 1:
            return self._estimate_many(w_global_now, x, y)
        if self._estimate_sharded is None:
            ax = cohort_spec(self.mesh)
            self._estimate_sharded = jax.jit(shard_map_compat(
                jax.vmap(lambda w, xx, yy: self.local_update(w, xx, yy)[0],
                         in_axes=(None, 0, 0)),
                self.mesh,
                in_specs=(replicated_spec(), ax, ax), out_specs=ax))
        B = x.shape[0]
        Bp = shard_bucket(B, self.n_shards)
        w_hat = self._estimate_sharded(
            w_global_now, _pad_leading(x, Bp - B), _pad_leading(y, Bp - B))
        return _take_leading(w_hat, B)
