"""Gradient inversion engine (paper §3.1) — the core contribution.

Given a stale update ``w_i^{t-tau}`` computed from the outdated global model
``w_global^{t-tau}``, recover a synthetic dataset ``D_rec = (x', y')`` by
minimizing (Eq. 6)::

    Disparity[ LocalUpdate(w_global^{t-tau}; D_rec),  w_i^{t-tau} ]

with gradient descent on (x', y'). Differences vs classic gradient inversion
(Zhu et al.) that the paper introduces, all implemented here:

* the *multi-step local training program* replaces the single gradient — we
  differentiate through the scanned LocalUpdate;
* the metric is **L1-norm** of the weight change, not cosine (Appendix D),
  because D_rec is large (default |D_rec| = |D_i| / 2);
* optional **top-K sparsification** of the objective (§3.3a);
* optional **warm start** from the previous round's D_rec (§3.3b);
* labels are recovered as unconstrained *soft logits* — the server never
  obtains hard labels (§3.4).

The unstale estimate is then ``w_hat_i^t = LocalUpdate(w_global^t; D_rec)``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.client import LocalProgram, make_local_update
from repro.core.disparity import l1_disparity, tree_sub, tree_to_vector
from repro.optim import adam, apply_updates


@dataclasses.dataclass(frozen=True)
class GIConfig:
    n_rec: int = 32                 # |D_rec| (paper: ~ |D_i| / 2, App. D)
    iters: int = 200                # GI iterations per round
    lr: float = 0.1                 # Adam lr on (x', y')
    keep_fraction: float = 1.0      # 1.0 = no sparsification; 0.05 = top-5%
    metric: str = "l1"              # l1 (paper App. D) | cosine
    init_scale: float = 0.1
    tol: float = 0.0                # early-stop threshold on the GI loss
    warm_start: bool = True


class GradientInverter:
    """Builds and runs the jitted GI optimization for a given small model."""

    def __init__(self, apply_fn: Callable, input_shape: Tuple[int, ...],
                 n_classes: int, program: LocalProgram, cfg: GIConfig):
        self.apply_fn = apply_fn
        self.input_shape = tuple(input_shape)
        self.n_classes = n_classes
        self.program = program
        self.cfg = cfg
        self.local_update = make_local_update(apply_fn, program)
        self._step = jax.jit(self._make_step())

    # ------------------------------------------------------------------ #
    def init_drec(self, key: jax.Array) -> Tuple[jax.Array, jax.Array]:
        kx, ky = jax.random.split(key)
        x = jax.random.normal(kx, (self.cfg.n_rec, *self.input_shape),
                              jnp.float32) * self.cfg.init_scale
        y = jax.random.normal(ky, (self.cfg.n_rec, self.n_classes),
                              jnp.float32) * self.cfg.init_scale
        return x, y

    def _gi_loss(self, drec, w_global_stale, target_update, mask):
        x, y = drec
        w_trained, _ = self.local_update(w_global_stale, x, y)
        est_update = tree_sub(w_trained, w_global_stale)
        if self.cfg.metric == "l1":
            return l1_disparity(est_update, target_update, mask)
        ve = tree_to_vector(est_update)
        vt = tree_to_vector(target_update)
        if mask is not None:
            m = mask.astype(jnp.float32)
            ve, vt = ve * m, vt * m
        return 1.0 - jnp.dot(ve, vt) / jnp.maximum(
            jnp.linalg.norm(ve) * jnp.linalg.norm(vt), 1e-12)

    def _make_step(self):
        opt = adam(self.cfg.lr)

        def step(drec, opt_state, w_global_stale, target_update, mask):
            loss, grads = jax.value_and_grad(self._gi_loss)(
                drec, w_global_stale, target_update, mask)
            updates, opt_state = opt.update(grads, opt_state, drec)
            drec = apply_updates(drec, updates)
            return drec, opt_state, loss

        return step

    # ------------------------------------------------------------------ #
    def invert(
        self,
        w_global_stale: Any,
        w_stale: Any,
        key: jax.Array,
        mask: Optional[jax.Array] = None,
        init: Optional[Tuple[jax.Array, jax.Array]] = None,
        iters: Optional[int] = None,
    ) -> Tuple[Tuple[jax.Array, jax.Array], Dict[str, Any]]:
        """Recover D_rec from the stale update. Returns ((x', y'), info)."""
        target_update = tree_sub(w_stale, w_global_stale)
        drec = init if init is not None else self.init_drec(key)
        opt_state = adam(self.cfg.lr).init(drec)
        n_iters = iters if iters is not None else self.cfg.iters
        losses = []
        used = 0
        for i in range(n_iters):
            drec, opt_state, loss = self._step(
                drec, opt_state, w_global_stale, target_update, mask)
            used += 1
            if i % 10 == 0 or i == n_iters - 1:
                losses.append(float(loss))
                if self.cfg.tol and losses[-1] < self.cfg.tol:
                    break
        info = {"losses": losses, "final_loss": losses[-1] if losses else None,
                "iters_used": used}
        return drec, info

    # ------------------------------------------------------------------ #
    def estimate_unstale(self, w_global_now: Any,
                         drec: Tuple[jax.Array, jax.Array]) -> Any:
        """w_hat_i^t = LocalUpdate(w_global^t; D_rec) (paper Fig. 2)."""
        x, y = drec
        w_hat, _ = jax.jit(self.local_update)(w_global_now, x, y)
        return w_hat
