"""Gradient inversion engine (paper §3.1) — the core contribution.

Given a stale update ``w_i^{t-tau}`` computed from the outdated global model
``w_global^{t-tau}``, recover a synthetic dataset ``D_rec = (x', y')`` by
minimizing (Eq. 6)::

    Disparity[ LocalUpdate(w_global^{t-tau}; D_rec),  w_i^{t-tau} ]

with gradient descent on (x', y'). Differences vs classic gradient inversion
(Zhu et al.) that the paper introduces, all implemented here:

* the *multi-step local training program* replaces the single gradient — we
  differentiate through the scanned LocalUpdate;
* the metric is **L1-norm** of the weight change, not cosine (Appendix D),
  because D_rec is large (default |D_rec| = |D_i| / 2);
* optional **top-K sparsification** of the objective (§3.3a);
* optional **warm start** from the previous round's D_rec (§3.3b);
* labels are recovered as unconstrained *soft logits* — the server never
  obtains hard labels (§3.4).

The unstale estimate is then ``w_hat_i^t = LocalUpdate(w_global^t; D_rec)``.

Three execution engines:

* ``invert`` — the sequential reference: a Python loop of jitted Adam steps,
  one client at a time (the seed implementation, kept as the oracle for the
  batched path's equivalence tests and for benchmarking).
* ``invert_batch`` — the one-shot batched engine: the whole optimization is
  a ``lax.while_loop`` inside ONE jitted call (early stop via the loop
  predicate, loss history written into a fixed-size buffer), ``vmap``-ed over
  all unique stale clients delivering in a round. Stacked
  ``(w_base, w_stale, mask, drec_init)`` pytrees in, stacked ``D_rec`` out —
  no per-iteration or per-client Python dispatch. Batch sizes are padded to
  the next power of two so recompiles are O(log B) instead of O(#distinct B).
* ``invert_batch`` with ``GIConfig.segment_iters > 0`` — the segmented
  continuous-batching executor: GI runs as fixed-length K-iteration jitted
  segments with donated carries (one compile per pow2 bucket x K), and
  between segments the host compacts finished lanes out, shrinks the
  resident bucket down the pow2 ladder, and refills free lanes from a
  pending-client queue (``GIConfig.max_lanes`` caps residency). Under
  intertwined heterogeneity — tol early-stops, warm starts, per-client
  budgets — the one-shot engine keeps every lane of the bucket resident
  until its *slowest* lane stops; the segmented executor drains the same
  cohort at near-full occupancy. Per-lane math is carried state through the
  identical loop body, so the two engines agree bit for bit; ``info`` gains
  ``occupancy`` / ``wasted_lane_iters`` telemetry.

Passing ``mesh=`` (a ``(pod, data)`` mesh from
``repro.launch.mesh.make_server_mesh``) shards the batched engine over
devices with ``shard_map``: the cohort axis splits across shards, each shard
runs its own vmapped while_loop (so a shard whose lanes all early-stop
finishes independently — no cross-device lockstep), and the pow2 compile
buckets become *per-shard* buckets. A 1-device mesh dispatches to the
unsharded engine and is therefore bit-for-bit identical to ``mesh=None``.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import LocalProgram, make_local_update
from repro.core.disparity import (l1_disparity, masked_cosine_distance,
                                  tree_pad_leading, tree_sub,
                                  tree_take_leading)
from repro.launch.mesh import mesh_shard_count, shard_map_compat
from repro.launch.sharding import (cohort_spec, constrain, model_axis_size,
                                   replicated_spec, segment_bucket,
                                   shard_bucket, stack_specs, to_named)
from repro.obs import tracer
from repro.optim import adam, apply_updates


@dataclasses.dataclass(frozen=True)
class GIConfig:
    n_rec: int = 32                 # |D_rec| (paper: ~ |D_i| / 2, App. D)
    iters: int = 200                # GI iterations per round
    lr: float = 0.1                 # Adam lr on (x', y')
    keep_fraction: float = 1.0      # 1.0 = no sparsification; 0.05 = top-5%
    metric: str = "l1"              # l1 (paper App. D) | cosine
    init_scale: float = 0.1
    tol: float = 0.0                # early-stop threshold on the GI loss
    warm_start: bool = True
    # segmented continuous-batching executor: >0 runs GI as K-iteration
    # jitted segments with finished lanes compacted out (and free lanes
    # refilled from the pending queue) between segments; 0 keeps the
    # one-shot whole-cohort while_loop. Per-lane math is identical, so the
    # two engines agree bit for bit.
    segment_iters: int = 0
    # cap on concurrently-resident GI lanes (0 = the whole cohort); extra
    # clients wait in the executor's pending queue and stream into lanes as
    # earlier clients finish — how the server hands the executor the union
    # of all deliverable stale lanes without scaling device memory with
    # the cohort.
    max_lanes: int = 0
    # remat the LocalUpdate steps inside the GI while_loop body
    # (jax.checkpoint on the scanned optimizer step): the body's
    # value_and_grad recomputes each local step's forward instead of
    # holding `program.steps` sets of model activations per lane — the
    # memory lever that makes transformer-scale GI fit. Value-neutral, so
    # the batched==sequential and segmented==one-shot bitwise contracts
    # are unaffected (all engines share the same rematted local_update).
    remat: bool = False


# kept under their historic names for the module's internal call sites
_pad_leading = tree_pad_leading
_take_leading = tree_take_leading


class LanePool:
    """Resident lane pool for the segmented continuous-batching executor.

    Owns the executor's mutable lane machinery — the pending-client queue,
    the per-lane client map, the carried survivor state and the host-side
    iteration counters — as *instance* state instead of per-call locals, so
    a long-running service (``repro.service``) pays the pool's construction
    exactly once per :class:`GradientInverter` and every aggregation trigger
    reuses the same warm object. ``run_cohort`` drains one stale cohort to
    completion through the K-iteration segments; between cohorts the pool is
    idle (no resident lanes, empty queue) but its identity, compiled-segment
    cache (held by the inverter) and lifetime occupancy accounting persist.

    Lifetime counters (``stats``): ``cohorts``, ``segments``,
    ``useful_lane_iters``, ``lane_iter_cost``, ``peak_lanes``. They
    accumulate across every cohort the pool ever drains — the service layer
    surfaces them as ``obs`` counters.
    """

    def __init__(self, inverter: "GradientInverter"):
        self.inv = inverter
        # persistent pending-client queue: client rows waiting for a lane
        self.pending: deque = deque()
        self.lane_client: List[int] = []   # client row per resident lane
        self.surv_state: Optional[Dict[str, Any]] = None
        self.i_host = np.zeros((0,), np.int32)
        self.stats: Dict[str, int] = {
            "cohorts": 0, "segments": 0, "useful_lane_iters": 0,
            "lane_iter_cost": 0, "peak_lanes": 0}

    def idle(self) -> bool:
        return not self.lane_client and not self.pending

    def run_cohort(self, w_global_stale, target, masks, drec0,
                   n_host: np.ndarray, max_iters: int, seg_iters: int,
                   max_lanes: int
                   ) -> Tuple[Tuple[jax.Array, jax.Array], Dict[str, Any]]:
        """Drain a stale-client cohort through K-iteration jitted segments.

        Between segments the host compacts finished lanes out (their D_rec /
        loss rows land in per-client result buffers), shrinks the resident
        bucket down the pow2 ladder, and refills free lanes from the pending
        queue — so a skewed cohort runs at near-full occupancy instead of
        every lane waiting for the slowest. Per-lane math is carried state
        through ``GradientInverter._segment_core``, so the recovered D_rec
        is bit-for-bit the one-shot engine's.
        """
        if not self.idle():
            raise RuntimeError("LanePool.run_cohort on a non-idle pool "
                               f"({len(self.lane_client)} resident lanes, "
                               f"{len(self.pending)} pending)")
        inv = self.inv
        B = jax.tree_util.tree_leaves(drec0)[0].shape[0]
        ns = inv.n_shards
        has_mask = masks is not None
        seg_fn = inv._get_segment_fn(seg_iters, has_mask)

        x0, y0 = drec0
        out_x = np.zeros(x0.shape, x0.dtype)
        out_y = np.zeros(y0.shape, y0.dtype)
        losses_out = np.full((B, max_iters), np.nan, np.float32)
        final_out = np.full((B,), np.inf, np.float32)
        used_out = np.zeros((B,), np.int32)

        self.pending.extend(range(B))
        queue = self.pending
        useful = 0
        cost = 0
        segments = 0
        buckets: List[int] = []

        packed = None        # (state, n_res, C) ready to run without repack
        while self.lane_client or queue:
            if packed is not None:
                state, n_res, C = packed
                packed = None
            else:
                n_res, C = segment_bucket(
                    len(self.lane_client) + len(queue), ns, max_lanes)
                refill = [queue.popleft()
                          for _ in range(n_res - len(self.lane_client))]
                parts = [self.surv_state]
                if refill:
                    parts.append(inv._fresh_lane_state(
                        np.asarray(refill, np.int64), w_global_stale, target,
                        masks, drec0, n_host, max_iters))
                    self.lane_client = self.lane_client + refill
                    self.i_host = np.concatenate(
                        [self.i_host, np.zeros(len(refill), np.int32)])
                state = inv._cat_lane_states(parts)
                pad = C - n_res
                if pad:
                    # padded lanes replicate row 0 with a zero budget —
                    # done immediately, never read back (the one-shot
                    # bucket trick)
                    state = {
                        k: (None if v is None else (
                            jnp.concatenate(
                                [v, jnp.zeros((pad,), jnp.int32)])
                            if k == "n" else tree_pad_leading(v, pad)))
                        for k, v in state.items()}
            args = (state["w"], state["t"]) \
                + ((state["m"],) if has_mask else ()) \
                + (state["n"], state["i"], state["drec"], state["opt"],
                   state["losses"], state["last"])
            with tracer.span("gi.segment") as _sp:
                _sp.arg("bucket", int(C))
                _sp.arg("resident", int(n_res))
                i_new, drec_s, opt_s, losses_s, last_s, done = seg_fn(*args)
                _sp.fence(i_new)
            segments += 1
            buckets.append(C)

            i_h = np.asarray(i_new[:n_res])          # the one host sync
            done_h = np.asarray(done[:n_res])
            steps = i_h - self.i_host
            useful += int(steps.sum())
            cost += C * int(steps.max())

            new_state = {"i": i_new, "drec": drec_s, "opt": opt_s,
                         "losses": losses_s, "last": last_s,
                         "w": state["w"], "t": state["t"],
                         "m": state["m"], "n": state["n"]}
            fin = np.flatnonzero(done_h)
            if fin.size == 0:
                # no lane finished => no compaction, no freed lane to
                # refill, same bucket: hand the carried state straight to
                # the next segment (zero gathers)
                self.i_host = i_h
                packed = (new_state, n_res, C)
                continue
            idx = jnp.asarray(fin)
            fx = np.asarray(drec_s[0][idx])
            fy = np.asarray(drec_s[1][idx])
            fl = np.asarray(losses_s[idx])
            flast = np.asarray(last_s[idx])
            for j, l in enumerate(fin):
                ci = self.lane_client[l]
                out_x[ci] = fx[j]
                out_y[ci] = fy[j]
                losses_out[ci] = fl[j]
                final_out[ci] = flast[j]
                used_out[ci] = i_h[l]
            surv = np.flatnonzero(~done_h)
            self.lane_client = [self.lane_client[l] for l in surv]
            self.i_host = i_h[surv]
            self.surv_state = (inv._take_lane_state(new_state, surv)
                               if len(self.lane_client) else None)

        self.surv_state = None
        self.i_host = np.zeros((0,), np.int32)
        self.stats["cohorts"] += 1
        self.stats["segments"] += segments
        self.stats["useful_lane_iters"] += useful
        self.stats["lane_iter_cost"] += cost
        if buckets:
            self.stats["peak_lanes"] = max(self.stats["peak_lanes"],
                                           max(buckets))

        occupancy = float(useful / cost) if cost else 1.0
        drec = (jnp.asarray(out_x), jnp.asarray(out_y))
        info = {"losses": jnp.asarray(losses_out),
                "final_loss": jnp.asarray(final_out),
                "iters_used": jnp.asarray(used_out),
                "batch": B, "padded_to": buckets[0] if buckets else 0,
                "n_shards": ns, "engine": "segmented",
                "segment_iters": seg_iters, "segments": segments,
                "buckets": buckets, "max_lanes": int(max_lanes),
                "useful_lane_iters": int(useful),
                "wasted_lane_iters": int(cost - useful),
                "lane_iter_cost": int(cost),
                "budgets": np.asarray(n_host),
                "occupancy": occupancy}
        return drec, info


class GradientInverter:
    """Builds and runs the jitted GI optimization for a given small model."""

    def __init__(self, apply_fn: Callable, input_shape: Tuple[int, ...],
                 n_classes: int, program: LocalProgram, cfg: GIConfig,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 param_spec: Optional[Any] = None):
        self.apply_fn = apply_fn
        self.input_shape = tuple(input_shape)
        self.n_classes = n_classes
        self.cfg = cfg
        # (pod, data) cohort mesh; >1 shard routes the batched engine
        # through shard_map (a 1-shard mesh is bit-for-bit the plain engine).
        # ``param_spec`` (a PartitionSpec tree for ONE unstacked weight
        # pytree, model-axis placements only — fl_param_specs) activates the
        # GSPMD route on meshes with a model axis: the batched engines build
        # as jit + NamedSharding so the compiler partitions weight dims on
        # `model` while the cohort axis stays on (pod, data). shard_map
        # cannot express this (its lane bodies have no collectives).
        self.mesh = mesh
        self.n_shards = mesh_shard_count(mesh)
        self.param_spec = (param_spec
                           if model_axis_size(mesh) > 1 else None)
        if cfg.remat and not program.remat:
            # GI-side remat without forcing it on the fresh/stale cohort
            # updates: rebuild the inner LocalUpdate with step-level
            # jax.checkpoint (value-neutral; see GIConfig.remat)
            program = dataclasses.replace(program, remat=True)
        self.program = program
        self.local_update = make_local_update(apply_fn, program)
        self._step = jax.jit(self._make_step())
        # single-compile engines (cached jits; satellite: no per-call re-jit)
        self._estimate_one = jax.jit(
            lambda w, x, y: self.local_update(w, x, y)[0])
        self._estimate_many = jax.jit(jax.vmap(
            lambda w, x, y: self.local_update(w, x, y)[0],
            in_axes=(None, 0, 0)))
        self._init_many = jax.jit(jax.vmap(self.init_drec))
        # vmapped whole-optimization inversion, one compiled fn per static
        # max_iters (normally just cfg.iters) — every dynamic per-client
        # iteration budget <= max_iters reuses the same executable
        self._invert_many_cache: Dict[int, Callable] = {}
        # sharded variants, keyed by (max_iters, has_mask)
        self._invert_sharded_cache: Dict[Tuple[int, bool], Callable] = {}
        self._estimate_sharded: Optional[Callable] = None
        # segmented continuous-batching executor: one traced fn per
        # (seg_iters, has_mask); XLA re-specializes it per (bucket, losses
        # buffer) shape, i.e. one compile per pow2 bucket x K
        self._segment_cache: Dict[Tuple[int, bool], Callable] = {}
        # the resident lane pool — built once, reused by every segmented
        # cohort this inverter ever drains (repro.service relies on this
        # object surviving across aggregation triggers)
        self.pool = LanePool(self)

    def _get_invert_many(self, max_iters: int) -> Callable:
        fn = self._invert_many_cache.get(max_iters)
        if fn is None:
            core = partial(self._invert_core, max_iters=max_iters)
            fn = jax.jit(jax.vmap(core, in_axes=(0, 0, 0, 0, 0)))
            self._invert_many_cache[max_iters] = fn
        return fn

    def _get_invert_many_sharded(self, max_iters: int, has_mask: bool
                                 ) -> Callable:
        """shard_map over the cohort axis: each shard runs the same vmapped
        while_loop on its local pow2 bucket. All operands are stacked on the
        batch axis, so there is no cross-shard communication — shards with
        early-stopping lanes finish independently instead of waiting for the
        slowest lane of the whole cohort. Always built over ``self.mesh``
        (the cache key assumes it)."""
        mesh = self.mesh
        key = (max_iters, has_mask)
        fn = self._invert_sharded_cache.get(key)
        if fn is None:
            core = partial(self._invert_core, max_iters=max_iters)
            vm = jax.vmap(core, in_axes=(0, 0, 0, 0, 0))
            ax = cohort_spec(mesh)
            if has_mask:
                body, n_in = vm, 5
            else:
                body = lambda wg, tgt, d0, ni: vm(wg, tgt, None, d0, ni)  # noqa: E731
                n_in = 4
            if self.param_spec is not None:
                # GSPMD: the two stacked weight trees pin to (cohort on
                # (pod, data), weight dims on model) inside the body so the
                # while_loop math partitions over `model`; D_rec / budgets /
                # masks and every output keep cohort-only layouts at the
                # boundary (see sharding.constrain)
                wst = stack_specs(self.param_spec, mesh)
                inner = body

                def body(wg, tgt, *rest):
                    return inner(constrain(wg, wst, mesh),
                                 constrain(tgt, wst, mesh),
                                 *(constrain(r, ax, mesh) for r in rest))

                fn = jax.jit(body, out_shardings=to_named(ax, mesh))
            else:
                fn = jax.jit(shard_map_compat(
                    body, mesh, in_specs=(ax,) * n_in, out_specs=ax))
            self._invert_sharded_cache[key] = fn
        return fn

    # ------------------------------------------------------------------ #
    def init_drec(self, key: jax.Array) -> Tuple[jax.Array, jax.Array]:
        kx, ky = jax.random.split(key)
        x = jax.random.normal(kx, (self.cfg.n_rec, *self.input_shape),
                              jnp.float32) * self.cfg.init_scale
        y = jax.random.normal(ky, (self.cfg.n_rec, self.n_classes),
                              jnp.float32) * self.cfg.init_scale
        return x, y

    def _gi_loss(self, drec, w_global_stale, target_update, mask):
        # both metrics run on the fused concat-free disparity terms
        # (repro.kernels.fused_disparity) — the masked cosine shares
        # disparity.masked_cosine_distance with Eq. 7 instead of
        # reimplementing its own mask handling
        x, y = drec
        w_trained, _ = self.local_update(w_global_stale, x, y)
        est_update = tree_sub(w_trained, w_global_stale)
        if self.cfg.metric == "l1":
            return l1_disparity(est_update, target_update, mask)
        return masked_cosine_distance(est_update, target_update, mask)

    def _make_step(self):
        opt = adam(self.cfg.lr)

        def step(drec, opt_state, w_global_stale, target_update, mask):
            loss, grads = jax.value_and_grad(self._gi_loss)(
                drec, w_global_stale, target_update, mask)
            updates, opt_state = opt.update(grads, opt_state, drec)
            drec = apply_updates(drec, updates)
            return drec, opt_state, loss

        return step

    # ------------------------------------------------------------------ #
    def _loop_fns(self, w_global_stale, target_update, mask, n_iters
                  ) -> Tuple[Any, Callable, Callable]:
        """The ``(opt, live-predicate, Adam-step body)`` every GI engine's
        ``while_loop`` closes over.

        ONE definition on purpose: the segmented==one-shot (and
        batched==sequential) bit-for-bit contracts require the step body
        and the tol cadence to be byte-identical across engines — sharing
        the closure makes a silent fork impossible. ``live`` checks the
        budget and, when ``cfg.tol`` is set, the seed's cadence: ``i``
        iterations completed, the last one had index ``i-1``, break only
        when that index % 10 == 0.
        """
        opt = adam(self.cfg.lr)
        tol = self.cfg.tol

        def live(i, loss):
            not_done = i < n_iters
            if tol:
                at_check = (i > 0) & ((i - 1) % 10 == 0)
                not_done = not_done & ~(at_check & (loss < tol))
            return not_done

        def body(carry):
            i, drec, opt_state, losses, _ = carry
            loss, grads = jax.value_and_grad(self._gi_loss)(
                drec, w_global_stale, target_update, mask)
            updates, opt_state = opt.update(grads, opt_state, drec)
            drec = apply_updates(drec, updates)
            losses = losses.at[i].set(loss)
            return i + 1, drec, opt_state, losses, loss

        return opt, live, body

    def _invert_core(self, w_global_stale, target_update, mask, drec0,
                     n_iters, *, max_iters: int):
        """One client's full GI optimization as a single ``lax.while_loop``.

        ``n_iters`` is a dynamic iteration budget (<= static ``max_iters``);
        early stopping on ``cfg.tol`` is part of the loop predicate — checked
        after iterations 0, 10, 20, ... exactly like the sequential seed
        path, so tol-enabled configs keep the batched==sequential
        equivalence. The per-iteration loss history is written into a fixed
        ``(max_iters,)`` buffer (NaN beyond the iterations actually used).
        vmap lifts the while_loop to run until every lane has stopped.
        """
        opt, live, body = self._loop_fns(w_global_stale, target_update,
                                         mask, n_iters)

        def cond(carry):
            i, _, _, _, loss = carry
            return live(i, loss)

        carry0 = (jnp.zeros((), jnp.int32), drec0, opt.init(drec0),
                  jnp.full((max_iters,), jnp.nan, jnp.float32),
                  jnp.full((), jnp.inf, jnp.float32))
        used, drec, _, losses, final_loss = jax.lax.while_loop(
            cond, body, carry0)
        return drec, losses, final_loss, used

    # ------------------------------------------------------------------ #
    # Segmented continuous-batching executor
    # ------------------------------------------------------------------ #
    def _segment_core(self, w_global_stale, target_update, mask, n_iters,
                      i0, drec, opt_state, losses, last_loss, *,
                      seg_iters: int):
        """Advance one lane's GI optimization by at most ``seg_iters``
        iterations from carried state.

        Shares ``_loop_fns``'s body and live predicate with the one-shot
        engine — the only extra predicate is the segment bound
        ``i < i0 + seg_iters`` — so running a lane as a chain of segments
        reproduces the one-shot while_loop bit for bit regardless of how
        the executor regroups lanes between segments. Returns the advanced
        carry plus a ``done`` flag (the lane's *own* stopping condition,
        not the segment bound).
        """
        _, live, body = self._loop_fns(w_global_stale, target_update,
                                       mask, n_iters)
        bound = i0 + seg_iters

        def cond(carry):
            i, _, _, _, loss = carry
            return (i < bound) & live(i, loss)

        i, drec, opt_state, losses, last = jax.lax.while_loop(
            cond, body, (i0, drec, opt_state, losses, last_loss))
        return i, drec, opt_state, losses, last, ~live(i, last)

    def _get_segment_fn(self, seg_iters: int, has_mask: bool) -> Callable:
        """One traced segment executable per (K, has_mask); the big carries
        (drec, opt state, loss buffer, last loss) are donated so segment N+1
        reuses segment N's buffers instead of doubling resident memory.
        With a multi-shard mesh the lane axis splits via shard_map exactly
        like the one-shot engine (independent per-shard segments)."""
        key = (seg_iters, has_mask)
        fn = self._segment_cache.get(key)
        if fn is not None:
            return fn
        core = partial(self._segment_core, seg_iters=seg_iters)
        if has_mask:
            n_in = 9
            vm = jax.vmap(core, in_axes=(0,) * n_in)
        else:
            n_in = 8
            vm = jax.vmap(
                lambda w, t, n, i0, d, o, lo, ll:
                core(w, t, None, n, i0, d, o, lo, ll),
                in_axes=(0,) * n_in)
        if self.n_shards > 1:
            ax = cohort_spec(self.mesh)
            if self.param_spec is not None:
                wst = stack_specs(self.param_spec, self.mesh)
                mesh = self.mesh

                # (w, t, [m], n, i, drec, opt, losses, last): the two
                # leading stacked weight trees pin to model-axis placements
                # inside the body; the carried lane state stays cohort-only
                # at the boundary (the host compacts it between segments)
                def body(w, t, *rest):
                    return vm(constrain(w, wst, mesh),
                              constrain(t, wst, mesh),
                              *(constrain(r, ax, mesh) for r in rest))

                fn = jax.jit(body, out_shardings=to_named(ax, mesh))
            else:
                fn = jax.jit(shard_map_compat(
                    vm, self.mesh, in_specs=(ax,) * n_in, out_specs=ax))
        else:
            # donation is a no-op (and warns) on CPU hosts
            donate = (() if jax.default_backend() == "cpu"
                      else tuple(range(n_in - 4, n_in)))
            fn = jax.jit(vm, donate_argnums=donate)
        self._segment_cache[key] = fn
        return fn

    def _fresh_lane_state(self, rows: np.ndarray, w_global_stale, target,
                          masks, drec0, n_host: np.ndarray,
                          max_iters: int) -> Dict[str, Any]:
        """Lane state for clients entering the executor: row slices of the
        stacked inputs plus a cold carry (i=0, zeroed Adam moments, NaN loss
        buffer) — exactly the state the one-shot engine starts every lane
        from."""
        idx = jnp.asarray(rows)
        take = lambda tree: jax.tree_util.tree_map(lambda a: a[idx], tree)
        drec = take(drec0)
        k = len(rows)
        zeros = lambda: jax.tree_util.tree_map(
            lambda a: jnp.zeros_like(a, jnp.float32), drec)
        return {
            "w": take(w_global_stale),
            "t": take(target),
            "m": None if masks is None else masks[idx],
            "n": jnp.asarray(n_host[rows], jnp.int32),
            "i": jnp.zeros((k,), jnp.int32),
            "drec": drec,
            # stacked adam init (== vmap(opt.init) without a compile)
            "opt": {"mu": zeros(), "nu": zeros(),
                    "t": jnp.zeros((k,), jnp.int32)},
            "losses": jnp.full((k, max_iters), jnp.nan, jnp.float32),
            "last": jnp.full((k,), jnp.inf, jnp.float32),
        }

    @staticmethod
    def _cat_lane_states(parts: list) -> Dict[str, Any]:
        parts = [p for p in parts if p is not None]
        if len(parts) == 1:
            return parts[0]
        out: Dict[str, Any] = {}
        for k in parts[0]:
            if k == "m" and parts[0]["m"] is None:
                out["m"] = None
                continue
            out[k] = jax.tree_util.tree_map(
                lambda *a: jnp.concatenate(a), *[p[k] for p in parts])
        return out

    @staticmethod
    def _take_lane_state(state: Dict[str, Any], rows) -> Dict[str, Any]:
        idx = jnp.asarray(np.asarray(rows))
        return {k: (None if v is None
                    else jax.tree_util.tree_map(lambda a: a[idx], v))
                for k, v in state.items()}

    def _invert_segmented(self, w_global_stale, target, masks, drec0,
                          n_host: np.ndarray, max_iters: int, seg_iters: int,
                          max_lanes: int
                          ) -> Tuple[Tuple[jax.Array, jax.Array],
                                     Dict[str, Any]]:
        """Drain a stale-client queue through the resident :class:`LanePool`.

        The pool object (pending queue, lane machinery, lifetime occupancy
        counters) is built once in ``__init__`` and reused for every cohort —
        see :class:`LanePool` for the drain loop itself.
        """
        return self.pool.run_cohort(w_global_stale, target, masks, drec0,
                                    n_host, max_iters, seg_iters, max_lanes)

    def _blend_drec0(self, keys: jax.Array,
                     inits: Optional[Tuple[jax.Array, jax.Array]],
                     init_flags: Optional[jax.Array],
                     B: int, Bp: int) -> Tuple[jax.Array, jax.Array]:
        """Stacked (Bp, ...) initial D_rec: cold rows from the per-client
        PRNG keys, warm rows from ``inits`` where ``init_flags`` is True.

        Warm starts may arrive unpadded (B rows) or pre-bucketed for a
        *different* engine capacity (e.g. ``WarmStartCache.gather_sharded``
        bucketed for the one-shot engine while the segmented executor packs
        its own lanes) — extra rows beyond ``Bp`` are dropped, short rows
        padded, so both engines consume one blend."""
        pad = Bp - B
        fresh = _pad_leading(self._init_many(keys), pad)
        if inits is None:
            return fresh
        Bi = jax.tree_util.tree_leaves(inits)[0].shape[0]
        if Bi == B:
            inits = _pad_leading(inits, pad)
        elif Bi > Bp:
            inits = _take_leading(inits, Bp)
        elif Bi != Bp:
            raise ValueError(f"inits leading dim {Bi} is neither the "
                             f"cohort size {B} nor its bucket {Bp}")
        if init_flags is None:
            return inits
        flags = jnp.asarray(init_flags, bool)[:Bp]
        if flags.shape[0] < Bp:
            flags = jnp.concatenate(
                [flags, jnp.zeros((Bp - flags.shape[0],), bool)])
        return jax.tree_util.tree_map(
            lambda w, c: jnp.where(
                flags.reshape((Bp,) + (1,) * (w.ndim - 1)), w, c),
            inits, fresh)

    def invert_batch(
        self,
        w_global_stale: Any,
        w_stale: Any,
        keys: jax.Array,
        masks: Optional[jax.Array] = None,
        inits: Optional[Tuple[jax.Array, jax.Array]] = None,
        init_flags: Optional[jax.Array] = None,
        iters: Optional[Any] = None,
        segment_iters: Optional[int] = None,
        max_lanes: Optional[int] = None,
        target_q: Optional[Any] = None,
    ) -> Tuple[Tuple[jax.Array, jax.Array], Dict[str, Any]]:
        """Batched inversion of B stale clients in ONE jitted call.

        Args:
          w_global_stale / w_stale: pytrees stacked on a leading (B,) axis —
            each client may come from a *different* base round.
          target_q: optional stacked ``core.quantize.QuantizedTree`` wire
            payload. When given it *replaces* ``w_stale - w_global_stale``
            as the disparity target and the loss consumes it through the
            dequant-fused terms — the fp32 target stack never exists. (The
            GSPMD model-axis engine dequantizes it up front instead: its
            boundary constraints are weight-tree sharding specs, which a
            payload tree cannot carry.)
          keys: (B, 2) PRNG keys for cold-start D_rec initialization.
          masks: optional (B, n_params) boolean sparsification masks.
          inits: optional stacked warm-start D_rec ``(x (B, n_rec, ...),
            y (B, n_rec, C))`` — used where ``init_flags`` is True.
          init_flags: (B,) bool; False rows fall back to the fresh random init.
          iters: scalar or (B,) per-client iteration budgets (default
            ``cfg.iters``). Budgets <= ``cfg.iters`` reuse one compiled
            executable; a budget above it raises the static loop bound and
            costs a fresh compile.

        Returns ``((x', y') stacked, info)`` with per-client ``losses``
        (B, max_iters; NaN past the used prefix), ``final_loss`` and
        ``iters_used`` arrays.

        With a multi-shard ``mesh``, the batch is padded to ``n_shards``
        equal per-shard pow2 buckets and run through the shard_map engine;
        on a 1-shard mesh (or ``mesh=None``) the bucket reduces to the
        global pow2 bucket and the plain vmapped engine runs — the same
        computation, bit for bit.

        ``segment_iters`` (default ``cfg.segment_iters``; 0 = one-shot)
        routes the call through the segmented continuous-batching executor:
        same per-lane math (bit-for-bit equal results on a single shard),
        but finished lanes are compacted out between K-iteration segments,
        the resident bucket shrinks down the pow2 ladder, and — with
        ``max_lanes`` (default ``cfg.max_lanes``) capping residency — the
        rest of the cohort streams through a pending queue. Its ``info``
        additionally reports ``occupancy`` / ``wasted_lane_iters`` /
        ``segments`` / ``buckets``.
        """
        B = jax.tree_util.tree_leaves(w_stale)[0].shape[0]
        if target_q is not None:
            # model-axis GSPMD engines constrain the target with weight-tree
            # specs — dequantize up front there, consume fused everywhere else
            target = (target_q.to_tree() if self.param_spec is not None
                      else target_q)
        else:
            target = tree_sub(w_stale, w_global_stale)

        max_iters = int(self.cfg.iters)
        if iters is None:
            n_host = np.full((B,), max_iters, np.int32)
        else:
            # host-side max: budgets normally arrive as Python/numpy data,
            # so taking the max BEFORE any jnp conversion avoids blocking
            # on the device every call (the old int(jnp.max(...)) did)
            n_host = np.broadcast_to(
                np.asarray(iters, np.int32), (B,))
            max_iters = max(max_iters, int(n_host.max()))

        seg = (self.cfg.segment_iters if segment_iters is None
               else int(segment_iters))
        if seg and seg > 0:
            lanes = (self.cfg.max_lanes if max_lanes is None
                     else int(max_lanes))
            drec0 = self._blend_drec0(keys, inits, init_flags, B, B)
            drec, info = self._invert_segmented(
                w_global_stale, target, masks, drec0, n_host, max_iters,
                seg, lanes)
            self._emit_gi_metric(info)
            return drec, info

        n_iters = jnp.asarray(n_host)

        # pad the batch to per-shard pow2 buckets (global pow2 when
        # unsharded): one compile per bucket, padded lanes get n_iters=0 so
        # the vmapped while_loop masks them out
        Bp = shard_bucket(B, self.n_shards)
        pad = Bp - B

        # cold-start inits are padded BEFORE blending so warm starts may
        # arrive either unpadded (B) or already bucketed (Bp, e.g. from
        # ``WarmStartCache.gather_sharded``); padded lanes always run from
        # the repeated fresh row and are discarded
        drec0 = self._blend_drec0(keys, inits, init_flags, B, Bp)

        args = (_pad_leading(w_global_stale, pad), _pad_leading(target, pad),
                None if masks is None else _pad_leading(masks, pad),
                drec0,
                jnp.concatenate([n_iters, jnp.zeros((pad,), jnp.int32)]))
        with tracer.span("gi.invert") as _sp:
            _sp.arg("batch", B)
            _sp.arg("bucket", Bp)
            if self.n_shards > 1:
                fn = self._get_invert_many_sharded(max_iters,
                                                   masks is not None)
                args = args[:2] + args[3:] if masks is None else args
                drec, losses, final_loss, used = fn(*args)
            else:
                drec, losses, final_loss, used = \
                    self._get_invert_many(max_iters)(*args)
            _sp.fence(used)
        drec = _take_leading(drec, B)
        info = {"losses": losses[:B], "final_loss": final_loss[:B],
                "iters_used": used[:B], "batch": B, "padded_to": Bp,
                "n_shards": self.n_shards, "engine": "oneshot",
                "budgets": n_host}
        self._emit_gi_metric(info)
        return drec, info

    def _emit_gi_metric(self, info: Dict[str, Any]) -> None:
        """One ``gi_exec`` metric row per batched-executor invocation:
        lane occupancy, iterations-to-converge stats, and final-loss
        (disparity) values. Reads ``info``'s device arrays, so it only
        runs with the tracer enabled."""
        if not tracer.enabled:
            return
        iu = np.asarray(info["iters_used"])
        fl = np.asarray(info["final_loss"])
        fl = fl[np.isfinite(fl)]
        B = int(info["batch"])
        occ = info.get("occupancy")
        tracer.metric(
            "gi_exec", engine=info["engine"], batch=B,
            padded_to=int(info["padded_to"]),
            segments=int(info.get("segments", 1)),
            occupancy=None if occ is None else float(occ),
            iters_mean=float(iu.mean()) if B else 0.0,
            iters_min=int(iu.min()) if B else 0,
            iters_max=int(iu.max()) if B else 0,
            final_loss_mean=float(fl.mean()) if fl.size else None,
            final_loss_max=float(fl.max()) if fl.size else None)

    # ------------------------------------------------------------------ #
    def invert(
        self,
        w_global_stale: Any,
        w_stale: Any,
        key: jax.Array,
        mask: Optional[jax.Array] = None,
        init: Optional[Tuple[jax.Array, jax.Array]] = None,
        iters: Optional[int] = None,
    ) -> Tuple[Tuple[jax.Array, jax.Array], Dict[str, Any]]:
        """Sequential reference path: recover D_rec from one stale update.

        Kept as the seed implementation (Python-dispatched jitted steps) so
        the batched engine has an oracle to be tested against; the server's
        hot path uses ``invert_batch``. Returns ((x', y'), info).
        """
        target_update = tree_sub(w_stale, w_global_stale)
        drec = init if init is not None else self.init_drec(key)
        opt_state = adam(self.cfg.lr).init(drec)
        n_iters = iters if iters is not None else self.cfg.iters
        losses = []
        used = 0
        for i in range(n_iters):
            drec, opt_state, loss = self._step(
                drec, opt_state, w_global_stale, target_update, mask)
            used += 1
            if i % 10 == 0 or i == n_iters - 1:
                losses.append(float(loss))
                if self.cfg.tol and losses[-1] < self.cfg.tol:
                    break
        info = {"losses": losses, "final_loss": losses[-1] if losses else None,
                "iters_used": used}
        return drec, info

    # ------------------------------------------------------------------ #
    def estimate_unstale(self, w_global_now: Any,
                         drec: Tuple[jax.Array, jax.Array]) -> Any:
        """w_hat_i^t = LocalUpdate(w_global^t; D_rec) (paper Fig. 2)."""
        x, y = drec
        return self._estimate_one(w_global_now, x, y)

    def estimate_unstale_batch(self, w_global_now: Any,
                               drec: Tuple[jax.Array, jax.Array]) -> Any:
        """Stacked w_hat for a batch of D_rec (one jitted vmap call).

        On a multi-shard mesh the D_rec batch shards on the cohort axis and
        ``w_global_now`` replicates (it is the one cohort-invariant
        operand); a 1-shard mesh uses the plain vmap bit-for-bit.
        """
        x, y = drec
        with tracer.span("gi.estimate") as _sp:
            if self.n_shards <= 1:
                return _sp.fence(self._estimate_many(w_global_now, x, y))
            if self._estimate_sharded is None:
                ax = cohort_spec(self.mesh)
                vm = jax.vmap(lambda w, xx, yy:
                              self.local_update(w, xx, yy)[0],
                              in_axes=(None, 0, 0))
                if self.param_spec is not None:
                    wspec, mesh = self.param_spec, self.mesh

                    def body(w, xx, yy):
                        return vm(constrain(w, wspec, mesh),
                                  constrain(xx, ax, mesh),
                                  constrain(yy, ax, mesh))

                    self._estimate_sharded = jax.jit(
                        body, out_shardings=to_named(ax, mesh))
                else:
                    self._estimate_sharded = jax.jit(shard_map_compat(
                        vm, self.mesh,
                        in_specs=(replicated_spec(), ax, ax), out_specs=ax))
            B = x.shape[0]
            Bp = shard_bucket(B, self.n_shards)
            w_hat = self._estimate_sharded(
                w_global_now, _pad_leading(x, Bp - B),
                _pad_leading(y, Bp - B))
            return _sp.fence(_take_leading(w_hat, B))
