"""Switch-back to vanilla FL in late training (paper §3.2).

As the global model converges, staleness stops mattering: the raw-staleness
error E2(t) = Disparity[w_i^{t-tau}, w_i^t] shrinks below the GI estimation
error E1(t) = Disparity[w_hat_i^t, w_i^t]. The true unstale update w_i^t is
only observable when it *arrives* at t+tau', so the monitor evaluates the
comparison retroactively and switches then — the paper shows training is
insensitive to this delay (Table 2 / Fig. 6).

The switch is smoothed: aggregation uses gamma*w_hat + (1-gamma)*w_stale with
gamma decaying linearly 1 -> 0 over a window of ``decay_fraction`` x (elapsed
training) — 10% maximizes accuracy (Table 3).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.core.disparity import cosine_distance, l1_disparity


@dataclasses.dataclass
class SwitchMonitor:
    metric: str = "cosine"           # cosine | l1
    decay_fraction: float = 0.10
    consecutive_needed: int = 2      # E1>E2 must hold this many observations

    switched_at: Optional[int] = None
    decay_end: Optional[int] = None
    _consecutive: int = 0
    history: List[Dict[str, float]] = dataclasses.field(default_factory=list)

    def _disparity(self, a: Any, b: Any) -> float:
        if self.metric == "l1":
            return float(l1_disparity(a, b))
        return float(cosine_distance(a, b))

    # ------------------------------------------------------------------ #
    def observe(self, t: int, w_hat: Any, w_stale: Any, w_true: Any) -> None:
        """Record E1/E2 at the (delayed) moment w_i^t becomes observable."""
        e1 = self._disparity(w_hat, w_true)
        e2 = self._disparity(w_stale, w_true)
        self.history.append({"t": t, "E1": e1, "E2": e2})
        if self.switched_at is not None:
            return
        if e1 > e2:
            self._consecutive += 1
        else:
            self._consecutive = 0
        if self._consecutive >= self.consecutive_needed:
            self.switched_at = t
            self.decay_end = t + max(1, int(self.decay_fraction * t))

    # ------------------------------------------------------------------ #
    def gamma(self, t: int) -> float:
        """Weight on the GI estimate w_hat at round t (1 before the switch,
        linear decay to 0 across the smoothing window after it)."""
        if self.switched_at is None:
            return 1.0
        if t >= self.decay_end:
            return 0.0
        span = max(1, self.decay_end - self.switched_at)
        return max(0.0, 1.0 - (t - self.switched_at) / span)

    @property
    def switched(self) -> bool:
        return self.switched_at is not None
