"""Bounded device-resident global-model version history (``VersionStore``).

The server needs ``w_global^v`` for every base version a stale delivery may
reference — under unlimited staleness that is *any* past version. The seed
kept a Python list of full param pytrees, which (a) grows device memory
without bound (fatal for the ROADMAP's million-user target) and (b) forces
the fused aggregation round to materialize per-client base params with
per-client ``tree_map`` traffic.

``VersionStore`` replaces the list with a ring buffer of *stacked* history:
every leaf is stored as ``(capacity, *shape)`` on device, appends are
``dynamic_update_index_in_dim`` writes through one cached jit, and a whole
mixed-version cohort's base params gather as ONE ``jnp.take`` per leaf —
the (B, ...) stacked tree the multi-version cohort LocalUpdate consumes
directly. The append is O(1) (in place) wherever buffer donation is
supported — i.e. on accelerators; on CPU hosts donation is a no-op, XLA
copies the ring per append, and the cost is O(capacity x model) bytes of
host memcpy instead — keep ``capacity`` modest there (it is the test and
CI backend, with tiny models, so this is benchmarked but not optimized).

Versions older than the device window are **spilled to host** right before
their ring row is overwritten and are recovered exactly on access (float
buffers round-trip device->host->device bit-for-bit), so unlimited staleness
keeps exact semantics while device memory stays bounded at ``capacity``
rows. ``spill=False`` drops evicted versions instead (strictly bounded
total memory); reading one then raises ``KeyError``.

Indexing mirrors the historic list API (``len``, ``store[v]``, negative
indices, iteration) so every consumer — ``compute_deliveries``,
``w_pred``'s two-snapshot extrapolation, the pending E1/E2 checks, the sim
bridge's version alignment assert — works unchanged.

With ``quant`` set (``core.quantize.QuantConfig`` with ``store_bits < 32``)
the ring holds **quantized** rows instead: per-leaf flat int8 payloads plus
per-tile f32 scales, quantized inside the append jit with deterministic
nearest rounding and dequantized on every read. At int8 the device-resident
history shrinks ~4x (the ROADMAP's million-user target multiplies this by
``capacity``). Reads are *lossy* (one quantization step per coordinate) —
this is an explicit opt-in trade, documented in docs/compression.md; the
default ``store_bits=32`` keeps the exact ring, and spill/gather semantics
are unchanged either way (spilled rows hold the quantized payload, so
spilled reads equal in-window reads bit-for-bit).
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import (QuantConfig, dequant_flat, leaf_payload_bytes,
                                 quantize_leaf_jnp)


class VersionStore:
    """Ring buffer of global-param versions with host spill for the tail."""

    def __init__(self, template: Any, capacity: int = 64, spill: bool = True,
                 quant: Optional[QuantConfig] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.spill = bool(spill)
        self.quant = quant if (quant is not None and quant.store_bits < 32) \
            else None
        self._n = 0
        self._spilled: Dict[int, Any] = {}      # version -> host (np) pytree
        # donation updates the ring in place (no-op + warning on CPU hosts,
        # so only donate off-CPU — same policy as the segmented GI executor)
        donate_ok = jax.default_backend() != "cpu"
        if self.quant is not None:
            self._init_quant(template, donate_ok)
            return
        self._ring = jax.tree_util.tree_map(
            lambda l: jnp.zeros((self.capacity,) + tuple(jnp.shape(l)),
                                jnp.asarray(l).dtype), template)
        donate = (0,) if donate_ok else ()

        def _append(ring, params, slot):
            return jax.tree_util.tree_map(
                lambda b, p: jax.lax.dynamic_update_index_in_dim(
                    b, p.astype(b.dtype), slot, 0), ring, params)

        self._append_fn = jax.jit(_append, donate_argnums=donate)

    def _init_quant(self, template: Any, donate_ok: bool) -> None:
        """Quantized-ring layout: parallel per-leaf flat payload and scale
        rings (int8 ``(capacity, n)`` + f32 ``(capacity, tiles)``)."""
        q = self.quant
        leaves, self._treedef = jax.tree_util.tree_flatten(template)
        self._shapes: List[Tuple[int, ...]] = [tuple(jnp.shape(l))
                                               for l in leaves]
        self._dtypes = [jnp.asarray(l).dtype for l in leaves]
        self._sizes = [int(np.prod(sh) or 1) for sh in self._shapes]
        tiles = [-(-n // q.tile) for n in self._sizes]
        self._qring = [jnp.zeros((self.capacity, n), jnp.int8)
                       for n in self._sizes]
        self._sring = [jnp.zeros((self.capacity, tt), jnp.float32)
                       for tt in tiles]
        bits, tile = q.store_bits, q.tile
        donate = (0, 1) if donate_ok else ()

        def _append(qring, sring, params, slot):
            qs, ss = [], []
            for qb, sb, p in zip(qring, sring,
                                 jax.tree_util.tree_leaves(params)):
                qq, s = quantize_leaf_jnp(
                    p.astype(jnp.float32).reshape(-1), tile, bits)
                qs.append(jax.lax.dynamic_update_index_in_dim(
                    qb, qq, slot, 0))
                ss.append(jax.lax.dynamic_update_index_in_dim(
                    sb, s, slot, 0))
            return qs, ss

        self._append_fn = jax.jit(_append, donate_argnums=donate)

    def _deq_tree(self, q_leaves, s_leaves, batch_shape: Tuple[int, ...]
                  ) -> Any:
        """Dequantize flat ring rows back into the template structure."""
        out = []
        for qq, s, sh, dt in zip(q_leaves, s_leaves, self._shapes,
                                 self._dtypes):
            x = dequant_flat(qq, s, self.quant.tile)
            out.append(x.reshape(batch_shape + sh).astype(dt))
        return jax.tree_util.tree_unflatten(self._treedef, out)

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return self._n

    @property
    def window_start(self) -> int:
        """Oldest version still resident in the device ring."""
        return max(0, self._n - self.capacity)

    @property
    def n_spilled(self) -> int:
        return len(self._spilled)

    @property
    def device_bytes(self) -> int:
        """Bytes held by the device ring — constant once constructed."""
        if self.quant is not None:
            return sum(l.size * l.dtype.itemsize
                       for l in self._qring + self._sring)
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(self._ring))

    # ------------------------------------------------------------------ #
    def append(self, params: Any) -> int:
        """Store ``params`` as the next version; returns its version id."""
        v = self._n
        slot = v % self.capacity
        if v >= self.capacity and self.spill:
            # the row being overwritten holds version v - capacity: copy it
            # to host first so old versions stay exactly recoverable (the
            # quantized ring spills its payload rows — a spilled read equals
            # the in-window read it replaces, bit for bit)
            if self.quant is not None:
                self._spilled[v - self.capacity] = (
                    [np.asarray(b[slot]) for b in self._qring],
                    [np.asarray(b[slot]) for b in self._sring])
            else:
                self._spilled[v - self.capacity] = jax.tree_util.tree_map(
                    lambda b: np.asarray(b[slot]), self._ring)
        if self.quant is not None:
            self._qring, self._sring = self._append_fn(
                self._qring, self._sring, params, jnp.asarray(slot, jnp.int32))
        else:
            self._ring = self._append_fn(self._ring, params,
                                         jnp.asarray(slot, jnp.int32))
        self._n += 1
        return v

    def _check(self, v: int) -> int:
        v = int(v)
        if v < 0:
            v += self._n
        if not 0 <= v < self._n:
            raise IndexError(f"version {v} out of range [0, {self._n})")
        return v

    def __getitem__(self, v: int) -> Any:
        v = self._check(v)
        if v >= self.window_start:
            slot = v % self.capacity
            if self.quant is not None:
                return self._deq_tree([b[slot] for b in self._qring],
                                      [b[slot] for b in self._sring], ())
            return jax.tree_util.tree_map(lambda b: b[slot], self._ring)
        host = self._spilled.get(v)
        if host is None:
            raise KeyError(
                f"version {v} was evicted (capacity {self.capacity}, "
                f"spill disabled)")
        if self.quant is not None:
            qs, ss = host
            return self._deq_tree([jnp.asarray(q) for q in qs],
                                  [jnp.asarray(s) for s in ss], ())
        return jax.tree_util.tree_map(jnp.asarray, host)

    def __iter__(self) -> Iterator[Any]:
        for v in range(self._n):
            yield self[v]

    # ------------------------------------------------------------------ #
    def gather(self, versions: Sequence[int]) -> Any:
        """Stacked ``(B, ...)`` base params for a mixed-version cohort.

        In-window rows come from one ``jnp.take`` per leaf over the ring;
        spilled rows are stitched in exactly from the host copies with one
        scatter per leaf. The result rows are bit-for-bit the params
        appended as those versions — the contract the fused aggregation
        round's equivalence oracle rests on. (With a quantized ring the
        rows are the *dequantized* payloads instead — still identical
        across in-window/spilled reads and across repeated gathers, but
        one deterministic quantization step away from what was appended.)
        """
        vs = np.asarray(versions, np.int64).reshape(-1)
        if vs.size and (vs.min() < 0 or vs.max() >= self._n):
            raise IndexError(f"versions {vs} out of range [0, {self._n})")
        ws = self.window_start
        slots = jnp.asarray(np.where(vs >= ws, vs % self.capacity, 0)
                            .astype(np.int32))
        old = np.flatnonzero(vs < ws)
        if old.size:
            missing = [int(vs[r]) for r in old
                       if int(vs[r]) not in self._spilled]
            if missing:
                raise KeyError(
                    f"versions {missing} were evicted (capacity "
                    f"{self.capacity}, spill disabled)")
        if self.quant is not None:
            qrows = [jnp.take(b, slots, axis=0) for b in self._qring]
            srows = [jnp.take(b, slots, axis=0) for b in self._sring]
            if old.size:
                rows = jnp.asarray(old)
                host = [self._spilled[int(vs[r])] for r in old]
                for li in range(len(qrows)):
                    hq = jnp.asarray(np.stack([h[0][li] for h in host]))
                    hs = jnp.asarray(np.stack([h[1][li] for h in host]))
                    qrows[li] = qrows[li].at[rows].set(hq)
                    srows[li] = srows[li].at[rows].set(hs)
            return self._deq_tree(qrows, srows, (int(vs.size),))
        out = jax.tree_util.tree_map(
            lambda b: jnp.take(b, slots, axis=0), self._ring)
        if old.size:
            rows = jnp.asarray(old)
            host = [self._spilled[int(vs[r])] for r in old]
            stacked_old = jax.tree_util.tree_map(
                lambda *a: jnp.asarray(np.stack(a)), *host)
            out = jax.tree_util.tree_map(
                lambda o, h: o.at[rows].set(h.astype(o.dtype)),
                out, stacked_old)
        return out
