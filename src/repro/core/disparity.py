"""Disparity metrics between model updates (paper Eq. 6, Appendix D).

The paper evaluates ``Disparity[LocalUpdate(w_global^{t-tau}; D_rec),
w_i^{t-tau}]`` with **L1-norm** during gradient inversion (because D_rec is
large — Appendix D) and uses **cosine distance** for uniqueness detection
(Eq. 7) and for reporting estimation errors (Table 1, Fig. 4/5).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def tree_to_vector(tree: Any) -> jax.Array:
    """Flatten a pytree of arrays into one float32 vector (stable order)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])


def vector_to_tree(vec: jax.Array, like: Any) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, off = [], 0
    for l in leaves:
        n = l.size
        out.append(vec[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_stack(trees) -> Any:
    """Stack a sequence of same-structure pytrees on a new leading axis
    (the cohort/batch axis the vectorized FL runtime vmaps over)."""
    return jax.tree_util.tree_map(lambda *a: jnp.stack(a), *trees)


def tree_pad_leading(tree: Any, pad: int) -> Any:
    """Pad every leaf's leading (cohort) axis by repeating row 0 ``pad``
    times — how the batched/sharded engines fill compile buckets (padded
    lanes run with a zero iteration budget and are discarded)."""
    if pad == 0:
        return tree
    return jax.tree_util.tree_map(
        lambda a: jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])], axis=0), tree)


def tree_take_leading(tree: Any, n: int) -> Any:
    """Drop bucket padding: the first ``n`` rows of every leaf."""
    return jax.tree_util.tree_map(lambda a: a[:n], tree)


def tree_sub(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x, y: x.astype(jnp.float32) - y.astype(jnp.float32), a, b)


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(lambda x, y: x + y.astype(x.dtype), a, b)


def tree_scale(a: Any, s) -> Any:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def l1_disparity(update_a: Any, update_b: Any, mask: Optional[jax.Array] = None
                 ) -> jax.Array:
    """Mean |a - b| over (optionally masked) coordinates.

    ``update_*`` are pytrees (model deltas or weights); ``mask`` is a flat
    boolean vector from ``repro.core.sparsify.topk_mask`` — this is the
    paper's sparsified GI objective (§3.3).
    """
    d = jnp.abs(tree_to_vector(update_a) - tree_to_vector(update_b))
    if mask is None:
        return jnp.mean(d)
    m = mask.astype(jnp.float32)
    return jnp.sum(d * m) / jnp.maximum(jnp.sum(m), 1.0)


def cosine_distance(a: Any, b: Any) -> jax.Array:
    """1 - cos(a, b) over flattened pytrees (paper Eq. 7)."""
    va, vb = tree_to_vector(a), tree_to_vector(b)
    na = jnp.linalg.norm(va)
    nb = jnp.linalg.norm(vb)
    return 1.0 - jnp.dot(va, vb) / jnp.maximum(na * nb, 1e-12)


def l2_distance(a: Any, b: Any) -> jax.Array:
    return jnp.linalg.norm(tree_to_vector(a) - tree_to_vector(b))
