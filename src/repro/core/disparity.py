"""Disparity metrics between model updates (paper Eq. 6, Appendix D).

The paper evaluates ``Disparity[LocalUpdate(w_global^{t-tau}; D_rec),
w_i^{t-tau}]`` with **L1-norm** during gradient inversion (because D_rec is
large — Appendix D) and uses **cosine distance** for uniqueness detection
(Eq. 7) and for reporting estimation errors (Table 1, Fig. 4/5).

Both metrics (and their masked §3.3 forms) are built on the
``repro.kernels.fused_disparity`` reduction terms: leaf-wise fused partial
sums (Pallas on TPU, exact jnp elsewhere) with a closed-form ``custom_vjp``,
so evaluating — or differentiating — a disparity never materializes the two
full ``tree_to_vector`` concatenations the seed implementation paid per GI
iteration per lane. ``tree_to_vector`` itself stays for callers that need
the actual flat vector (uniqueness detection, top-K thresholds, tests).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import QuantizedTree
from repro.kernels.fused_disparity import (masked_cosine_terms,
                                           masked_cosine_terms_dq,
                                           masked_l1_terms,
                                           masked_l1_terms_dq)


def tree_to_vector(tree: Any) -> jax.Array:
    """Flatten a pytree of arrays into one float32 vector (stable order)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])


def tree_to_vector_batch(updates) -> jax.Array:
    """(B, n) update vectors for a whole cohort.

    Accepts either a list of per-client pytrees (the loop-path form) or ONE
    pytree stacked on a leading cohort axis (the fused-round form — one
    reshape+concat per leaf, no per-client tree traffic). Row ``b`` is
    bit-for-bit ``tree_to_vector(updates[b])`` either way; this is the one
    place that contract lives (uniqueness detection and top-K masking both
    flatten through here).
    """
    if isinstance(updates, (list, tuple)):
        return jnp.stack([tree_to_vector(u) for u in updates])
    leaves = jax.tree_util.tree_leaves(updates)
    B = leaves[0].shape[0]
    return jnp.concatenate(
        [l.astype(jnp.float32).reshape(B, -1) for l in leaves], axis=1)


def vector_to_tree(vec: jax.Array, like: Any) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, off = [], 0
    for l in leaves:
        n = l.size
        out.append(vec[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_stack(trees) -> Any:
    """Stack a sequence of same-structure pytrees on a new leading axis
    (the cohort/batch axis the vectorized FL runtime vmaps over)."""
    return jax.tree_util.tree_map(lambda *a: jnp.stack(a), *trees)


def tree_pad_leading(tree: Any, pad: int) -> Any:
    """Pad every leaf's leading (cohort) axis by repeating row 0 ``pad``
    times — how the batched/sharded engines fill compile buckets (padded
    lanes run with a zero iteration budget and are discarded)."""
    if pad == 0:
        return tree
    return jax.tree_util.tree_map(
        lambda a: jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])], axis=0), tree)


def tree_take_leading(tree: Any, n: int) -> Any:
    """Drop bucket padding: the first ``n`` rows of every leaf."""
    return jax.tree_util.tree_map(lambda a: a[:n], tree)


def tree_concat_leading(trees) -> Any:
    """Concatenate same-structure stacked pytrees along the leading cohort
    axis (one concatenate per leaf) — how the fused aggregation round joins
    the fresh and stale update stacks without per-client stacking."""
    trees = list(trees)
    if len(trees) == 1:
        return trees[0]
    return jax.tree_util.tree_map(
        lambda *a: jnp.concatenate(a, axis=0), *trees)


def tree_index_select(tree: Any, rows) -> Any:
    """Gather ``rows`` of every leaf's leading axis (one take per leaf).

    The fused server uses this to carve the GI-eligible sub-cohort out of
    the stacked stale cohort; rows are exact copies, so downstream engines
    see bit-for-bit the tensors a per-client ``tree_stack`` would build.
    """
    idx = jnp.asarray(np.asarray(rows, np.int64))
    return jax.tree_util.tree_map(lambda a: jnp.take(a, idx, axis=0), tree)


def tree_sub(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x, y: x.astype(jnp.float32) - y.astype(jnp.float32), a, b)


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(lambda x, y: x + y.astype(x.dtype), a, b)


def tree_scale(a: Any, s) -> Any:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def l1_disparity(update_a: Any, update_b: Any, mask: Optional[jax.Array] = None
                 ) -> jax.Array:
    """Mean |a - b| over (optionally masked) coordinates.

    ``update_*`` are pytrees (model deltas or weights); ``mask`` is a flat
    boolean vector from ``repro.core.sparsify.topk_mask`` — this is the
    paper's sparsified GI objective (§3.3). Computed via the fused
    concat-free reduction terms (``repro.kernels.fused_disparity``).
    ``update_b`` may be a quantized wire payload
    (``core.quantize.QuantizedTree``) — the dequant-fused terms consume it
    directly, so the fp32 target is never materialized.
    """
    if isinstance(update_b, QuantizedTree):
        s, c = masked_l1_terms_dq(update_a, update_b, mask)
    else:
        s, c = masked_l1_terms(update_a, update_b, mask)
    if mask is None:
        return s / c                      # c = static coordinate total
    return s / jnp.maximum(c, 1.0)


def masked_cosine_distance(a: Any, b: Any,
                           mask: Optional[jax.Array] = None) -> jax.Array:
    """1 - cos(a*m, b*m) over pytrees with an optional flat coordinate mask.

    The one masked-cosine implementation: ``cosine_distance`` (Eq. 7) is the
    ``mask=None`` form and the sparsified GI cosine objective (§3.3) passes
    the top-K mask — both share these fused terms instead of re-deriving
    their own mask handling. ``b`` may be a ``QuantizedTree`` payload (see
    ``l1_disparity``).
    """
    if isinstance(b, QuantizedTree):
        dot, na2, nb2 = masked_cosine_terms_dq(a, b, mask)
    else:
        dot, na2, nb2 = masked_cosine_terms(a, b, mask)
    return 1.0 - dot / jnp.maximum(jnp.sqrt(na2) * jnp.sqrt(nb2), 1e-12)


def cosine_distance(a: Any, b: Any) -> jax.Array:
    """1 - cos(a, b) over flattened pytrees (paper Eq. 7)."""
    return masked_cosine_distance(a, b, None)


def l2_distance(a: Any, b: Any) -> jax.Array:
    return jnp.linalg.norm(tree_to_vector(a) - tree_to_vector(b))
