"""Semi-asynchronous FL server (paper §3, Fig. 2).

Simulation model: at round t every client computes
``LocalUpdate(w_global^{t - tau_i}; D_i)`` — fast clients (tau=0) deliver
immediately; slow clients' updates arrive tau rounds late, i.e. the server
receives an update computed from the *outdated* global model. The server
never sees slow clients' fresh updates early (no oracle leakage): switching
decisions use w_i^t only when it arrives at t+tau (paper §3.2).

Strategies (paper §4 baselines + ours):
  unweighted | weighted | first_order | w_pred | asyn_tiers | ours | unstale

Two aggregation engines share one contract:

* **fused round** (``FLConfig.fused_step=True``, default) — the whole round
  is (at most) two jitted cohort computations over stacked tensors: ONE
  multi-version cohort LocalUpdate (each lane carries its own base params,
  gathered from the bounded ``VersionStore`` ring in one take per leaf —
  exactly the unlimited-staleness regime where every delivery references a
  different version and per-base-round grouping degenerates to B=1
  dispatches), then one stacked delta -> compensation -> FedAvg stage
  (``compensation.*_batch``, ``aggregation.fedavg_stacked``,
  ``tiers.tiered_aggregate_stacked``) with no per-client Python tree
  traffic. See docs/server_performance.md ("The fused aggregation round").
* **loop round** (``fused_step=False``) — the historic per-client path:
  deliveries grouped by base round, Python list-of-pytrees aggregation.
  Kept as the equivalence oracle: on MLP-style models the fused round is
  bit-for-bit identical (CPU conv kernels differ by ~1 ULP under cohort
  regrouping — the same caveat as the segmented GI executor).

The cohort is vectorized: fast clients are vmapped over a stacked shard
tensor; stale clients are vmapped as one multi-version cohort (fused) or
per staleness group (loop); GI runs vmapped over all unique stale clients.
Passing ``mesh=`` (a (pod, data) cohort mesh from
``repro.launch.mesh.make_server_mesh``) shard_maps that cohort axis over
devices — see docs/sharded_server.md; a 1-shard mesh is bit-for-bit the
single-device engine.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, compensation, tiers
from repro.core.client import (LocalProgram, make_cohort_update,
                               make_local_update, soft_ce_loss)
from repro.core.disparity import (tree_concat_leading, tree_index_select,
                                  tree_pad_leading, tree_scale, tree_stack,
                                  tree_sub, tree_take_leading)
from repro.core.gradient_inversion import GIConfig, GradientInverter
from repro.core.quantize import (ErrorFeedback, QuantConfig,
                                 quantize_delta_stack, tree_payload_bytes)
from repro.core.sparsify import WarmStartCache, topk_mask_batch
from repro.core.switching import SwitchMonitor
from repro.core.uniqueness import is_unique_batch
from repro.core.versions import VersionStore
from repro.data.staleness import StalenessSchedule
from repro.launch.mesh import mesh_shard_count, shard_map_compat
from repro.launch.sharding import (cohort_spec, constrain, fl_param_specs,
                                   model_axis_size, multi_version_specs,
                                   replicated_spec, shard_bucket,
                                   stack_specs, to_named)
from repro.obs import tracer

STRATEGIES = ("unweighted", "weighted", "first_order", "w_pred",
              "asyn_tiers", "ours", "unstale")


@dataclasses.dataclass
class FLConfig:
    strategy: str = "ours"
    rounds: int = 60
    weighted_a: float = 0.25
    weighted_b: float = 10.0
    fo_lambda: float = 1.0
    n_tiers: int = 2
    gi: GIConfig = dataclasses.field(default_factory=GIConfig)
    uniqueness_check: bool = True
    batched_gi: bool = True         # one vmapped jit over the stale cohort
    # fused aggregation round: stale deliveries run as ONE multi-version
    # cohort LocalUpdate (per-lane base params from the VersionStore) and
    # the delta/compensation/FedAvg stage operates on stacked cohort
    # tensors. False keeps the per-client loop path as the equivalence
    # oracle ("ours" with batched_gi=False implies the loop path — the
    # sequential GI engine is inherently per-client).
    fused_step: bool = True
    # VersionStore sizing: device rows kept resident; older versions spill
    # to host (exact fallback) unless version_spill=False evicts them.
    version_capacity: int = 64
    version_spill: bool = True
    switching: bool = True
    switch_check_every: int = 5
    server_lr: float = 1.0
    eval_every: int = 1
    seed: int = 0
    # upload wire format (core.quantize): bits=32 (default) is an exact
    # identity — NO quantization code touches the round. bits=8/4 quantizes
    # every client upload (fresh and stale deltas) with per-tile scales,
    # stochastic Philox rounding and per-client error feedback; the GI
    # target is consumed dequant-fused. quant.store_bits additionally
    # quantizes the VersionStore's device ring rows.
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    # weight-sharding rule set (repro.launch.sharding.param_specs modes)
    # used when the mesh carries a model axis; "tp" shards attention heads /
    # FFN hidden / vocab on `model`. Ignored on (pod, data)-only meshes.
    mesh_mode: str = "tp"


class Server:
    def __init__(self, model, program: LocalProgram, cfg: FLConfig,
                 client_x: np.ndarray, client_y: np.ndarray,
                 client_mask: np.ndarray, schedule: StalenessSchedule,
                 test_x: np.ndarray, test_y: np.ndarray,
                 variant_stream=None,
                 mesh: Optional[jax.sharding.Mesh] = None):
        assert cfg.strategy in STRATEGIES, cfg.strategy
        self.model = model
        self.program = program
        self.cfg = cfg
        self.schedule = schedule
        self.variant = variant_stream
        self.test_x = jnp.asarray(test_x)
        self.test_y = jnp.asarray(test_y)

        # (pod, data) cohort mesh (repro.launch.mesh.make_server_mesh): with
        # >1 shard every cohort-batched hot path — fresh/stale LocalUpdates,
        # top-K masks, warm-start gathers, the batched GI while_loop and the
        # unstale estimates — runs under shard_map with the client axis
        # split across shards. A 1-shard mesh (or None) dispatches to the
        # single-device engines, bit for bit.
        self.mesh = mesh
        self._n_shards = mesh_shard_count(mesh)
        self._cohort_update_sharded = None         # built lazily on first use
        self._cohort_update_multi_sharded = None
        # optional model axis (make_server_mesh(..., model=k)): weights
        # shard per fl_param_specs and every cohort engine routes through
        # GSPMD (jit + NamedSharding) instead of shard_map — the shard_map
        # lane bodies carry no collectives, so model-dim partitioning has
        # to come from the compiler. Model-sharded runs match the 1-mesh
        # engine at tolerance (docs/real_models.md), like the rest of the
        # multi-shard contract.
        self._wspec = None
        self._wspec_stacked = None
        if model_axis_size(mesh) > 1:
            if getattr(model, "cfg", None) is None:
                raise ValueError(
                    "mesh has a model axis but the model carries no "
                    "ModelConfig — wrap a transformer with "
                    "repro.models.fl_bridge.lm_fl_model, or build the mesh "
                    "with make_server_mesh(model=1) (paper-scale models "
                    "replicate)")
            self._wspec = fl_param_specs(model.cfg, mesh, cfg.mesh_mode)
            self._wspec_stacked = stack_specs(self._wspec, mesh)

        self.key = jax.random.PRNGKey(cfg.seed)
        self.global_params = model.init(jax.random.PRNGKey(cfg.seed + 1))
        # bounded device-resident version history (ring + exact host spill)
        # replacing the unbounded per-round list of param pytrees; keeps the
        # list API (len / indexing / iteration) for every consumer
        self.history = VersionStore(self.global_params,
                                    capacity=cfg.version_capacity,
                                    spill=cfg.version_spill,
                                    quant=(cfg.quant if
                                           cfg.quant.store_bits < 32
                                           else None))
        self.history.append(self.global_params)    # version 0

        self.cx = client_x if variant_stream is None else variant_stream.xs
        self.cy = client_y
        self.cmask = client_mask
        self.n_clients = client_x.shape[0]
        # per-client example counts, computed once: the per-round
        # float(mask.sum()) per client was a device sync in the hot loop
        self._counts = np.asarray(
            np.asarray(client_mask).reshape(self.n_clients, -1).sum(axis=1),
            np.float64)

        _lu = make_local_update(model.apply, program)
        self._lu_fn = _lu
        self._local_update = jax.jit(_lu)
        self._cohort_update = jax.jit(
            jax.vmap(lambda p, x, y, m: _lu(p, x, y, m)[0],
                     in_axes=(None, 0, 0, 0)))
        # multi-version cohort: every lane trains from its own base params
        # (in_axes=(0, 0, 0, 0)) — one dispatch for a cohort scattered over
        # arbitrarily many base rounds
        self._cohort_update_multi_fn = make_cohort_update(
            model.apply, program, per_client_params=True)
        self._cohort_update_multi = jax.jit(self._cohort_update_multi_fn)
        self._eval = jax.jit(self._eval_fn)

        # "ours" machinery
        self.inverter = GradientInverter(
            model.apply, model.input_shape, model.n_classes, program, cfg.gi,
            mesh=mesh, param_spec=self._wspec)
        self.warm = WarmStartCache()
        self.monitor = SwitchMonitor()
        # upload wire format: per-client error-feedback residuals plus a
        # running bytes-on-wire total (exact packed payload accounting at
        # bits<32, 4 bytes/coord at the default fp32 — so the counter is
        # comparable across bitwidths)
        self._ef = ErrorFeedback()
        self.wire_bytes = 0
        self._upload_nbytes = tree_payload_bytes(self.global_params,
                                                 cfg.quant)
        # due_round -> [(scheduled_round, client, w_hat, w_stale), ...]
        self._pending_checks: Dict[int, List[Tuple[int, int, Any, Any]]] = {}
        self.gi_log: List[Dict[str, Any]] = []
        self.metrics: List[Dict[str, float]] = []
        # last aggregation's GI executor telemetry (occupancy / wasted lane
        # iters, per-client iteration counts and early-stop reasons) —
        # surfaced in the per-round metrics row and the sim bridge's wall
        # rows
        self._last_gi: Optional[Dict[str, Any]] = None
        # cross-round GI accounting, surfaced through summary(): total
        # iterations spent per client, inversions per client, and how lanes
        # stopped ("tol" = loss tolerance fired before the budget,
        # "budget" = the full iteration budget ran out)
        self.gi_client_iters: Dict[int, int] = {}
        self.gi_client_calls: Dict[int, int] = {}
        self.gi_stop_counts: Dict[str, int] = {"tol": 0, "budget": 0}

    # ------------------------------------------------------------------ #
    def _eval_fn(self, params):
        logits = self.model.apply(params, self.test_x)
        pred = jnp.argmax(logits, -1)
        correct = (pred == self.test_y).astype(jnp.float32)
        acc = jnp.mean(correct)
        # per-class accuracy in one segment_sum pass over the test labels
        # (identical to the historic per-class Python loop: the sums are
        # counts of 1.0s, exact in float32)
        C = self.model.n_classes
        per_class_correct = jax.ops.segment_sum(correct, self.test_y,
                                                num_segments=C)
        per_class_total = jax.ops.segment_sum(jnp.ones_like(correct),
                                              self.test_y, num_segments=C)
        per_class = per_class_correct / jnp.maximum(per_class_total, 1.0)
        return acc, per_class

    def evaluate(self) -> Tuple[float, np.ndarray]:
        acc, per_class = self._eval(self.global_params)
        return float(acc), np.asarray(per_class)

    # ------------------------------------------------------------------ #
    def _base_round(self, t: int, tau: int) -> int:
        return max(0, t - tau)

    def _client_shard(self, i: int):
        return (jnp.asarray(self.cx[i]), jnp.asarray(self.cy[i]),
                jnp.asarray(self.cmask[i]))

    def _client_stack(self, ids: Sequence[int]):
        """Stacked (x, y, mask) shards for a cohort, one gather per array."""
        idx = np.asarray(ids, np.int64)
        return (jnp.asarray(self.cx[idx]), jnp.asarray(self.cy[idx]),
                jnp.asarray(self.cmask[idx]))

    def _run_cohort(self, w_base, xs, ys, ms):
        """Vectorized LocalUpdate over a stacked cohort (shared base params).

        With a multi-shard mesh the cohort axis splits across shards
        (clients are independent — no collectives), padded to the cohort
        shard bucket; otherwise the plain jitted vmap runs unchanged.
        """
        if self._n_shards <= 1:
            return self._cohort_update(w_base, xs, ys, ms)
        if self._cohort_update_sharded is None:
            ax = cohort_spec(self.mesh)
            lu = self._lu_fn
            vm = jax.vmap(lambda p, x, y, m: lu(p, x, y, m)[0],
                          in_axes=(None, 0, 0, 0))
            if self._wspec is not None:
                # every operand is pinned inside the body (in_shardings
                # would reject committed replicated args); outputs leave in
                # cohort layout so callers' eager tree ops never touch a
                # model-sharded array
                wspec, mesh = self._wspec, self.mesh

                def body(p, x, y, m):
                    return vm(constrain(p, wspec, mesh),
                              *(constrain(v, ax, mesh) for v in (x, y, m)))

                self._cohort_update_sharded = jax.jit(
                    body, out_shardings=to_named(ax, mesh))
            else:
                self._cohort_update_sharded = jax.jit(shard_map_compat(
                    vm, self.mesh,
                    in_specs=(replicated_spec(), ax, ax, ax), out_specs=ax))
        B = xs.shape[0]
        pad = shard_bucket(B, self._n_shards) - B
        ws = self._cohort_update_sharded(
            w_base, tree_pad_leading(xs, pad), tree_pad_leading(ys, pad),
            tree_pad_leading(ms, pad))
        return tree_take_leading(ws, B)

    def _run_cohort_multi(self, w_base_stack, xs, ys, ms):
        """Multi-version cohort LocalUpdate: lane b trains from
        ``w_base_stack[b]`` — one dispatch regardless of how many distinct
        base rounds the cohort spans. Sharded exactly like ``_run_cohort``
        except the base params shard on the cohort axis too."""
        if self._n_shards <= 1:
            return self._cohort_update_multi(w_base_stack, xs, ys, ms)
        if self._cohort_update_multi_sharded is None:
            if self._wspec is not None:
                ax = cohort_spec(self.mesh)
                wst, mesh = self._wspec_stacked, self.mesh
                fn = self._cohort_update_multi_fn

                def body(p, x, y, m):
                    return fn(constrain(p, wst, mesh),
                              *(constrain(v, ax, mesh) for v in (x, y, m)))

                self._cohort_update_multi_sharded = jax.jit(
                    body, out_shardings=to_named(ax, mesh))
            else:
                self._cohort_update_multi_sharded = jax.jit(shard_map_compat(
                    self._cohort_update_multi_fn,
                    self.mesh,
                    in_specs=multi_version_specs(self.mesh),
                    out_specs=cohort_spec(self.mesh)))
        B = xs.shape[0]
        pad = shard_bucket(B, self._n_shards) - B
        ws = self._cohort_update_multi_sharded(
            tree_pad_leading(w_base_stack, pad), tree_pad_leading(xs, pad),
            tree_pad_leading(ys, pad), tree_pad_leading(ms, pad))
        return tree_take_leading(ws, B)

    @staticmethod
    def _delivery_order(pairs: Sequence[Tuple[int, int]]
                        ) -> List[Tuple[int, int]]:
        """``[(client, base_round)]`` in the exact order the loop path's
        grouped ``compute_deliveries`` + dict iteration emits deliveries
        (groups in first-appearance order of base rounds, members in pair
        order; a duplicated client keeps its first position with its last
        base round — plain dict semantics)."""
        groups: Dict[int, List[int]] = {}
        for i, base_t in pairs:
            groups.setdefault(base_t, []).append(i)
        ordered: Dict[int, int] = {}
        for base_t, members in groups.items():
            for i in members:
                ordered[i] = base_t
        return list(ordered.items())

    def compute_deliveries(self, t: int, pairs: Sequence[Tuple[int, int]]
                           ) -> Dict[int, Tuple[Any, Any, int]]:
        """Materialize stale deliveries ``{client: (w_stale, w_base, tau_eff)}``.

        ``pairs`` is ``[(client, base_round)]`` in delivery order: each update
        was computed from ``history[base_round]`` and arrives now (round
        ``t``), so its realized staleness is ``t - base_round``. Clients
        sharing a base round are batched through one vmapped LocalUpdate
        (the loop path; the fused round runs the whole mixed-version cohort
        as one dispatch instead). Callers decide WHO delivers — ``round``
        derives it from the static schedule, the event-driven simulator
        (``repro.sim.bridge``) from realized arrival times.
        """
        out: Dict[int, Tuple[Any, Any, int]] = {}
        groups: Dict[int, List[int]] = {}
        for i, base_t in pairs:
            groups.setdefault(base_t, []).append(i)
        for base_t, members in groups.items():
            w_base = self.history[base_t]
            xs, ys, ms = self._client_stack(members)
            ws = self._run_cohort(w_base, xs, ys, ms)
            for j, i in enumerate(members):
                w_i = jax.tree_util.tree_map(lambda a: a[j], ws)
                out[i] = (w_i, w_base, t - base_t)
        return out

    # ------------------------------------------------------------------ #
    def round(self, t: int) -> Dict[str, float]:
        """One round-synchronous step: the static schedule decides the cohort
        (all fast clients fresh; every slow client whose first update has
        arrived delivers one computed tau rounds ago)."""
        pairs = [(i, self._base_round(t, self.schedule.tau(i)))
                 for i in self.schedule.slow_clients
                 if t >= self.schedule.tau(i)]     # sync-FL skip before tau
        return self.step(t, self.schedule.fast_clients, pairs)

    def step(self, t: int, fresh_ids: Sequence[int],
             stale_pairs: Sequence[Tuple[int, int]],
             eval_now: Optional[bool] = None) -> Dict[str, float]:
        """One aggregation with an externally-determined cohort.

        ``fresh_ids`` train on the CURRENT global model (version ``t``, i.e.
        ``history[t]``); ``stale_pairs`` = [(client, base_round)] deliver
        updates computed from older versions with realized staleness
        ``t - base_round``. The event-driven simulator calls this directly —
        ``t`` is then the aggregation/version counter, not wall-clock time.
        Appends one entry to ``history`` (version ``t+1``) even when the
        cohort is empty, so version bookkeeping stays aligned.
        """
        cfg = self.cfg
        if self.variant is not None:
            self.variant.step()
            self.cx = self.variant.xs

        fast = list(fresh_ids)
        self._last_gi = None
        # "ours" without the batched GI engine is inherently per-client
        # (the sequential seed inverter), so it always takes the loop path
        fused = cfg.fused_step and (cfg.batched_gi or cfg.strategy != "ours")
        with tracer.span("server.step") as _sp:
            _sp.arg("version", t)
            if fused:
                gi_iters_this_round = self._aggregate_fused(t, fast,
                                                            stale_pairs)
            else:
                gi_iters_this_round = self._aggregate_loop(t, fast,
                                                           stale_pairs)
            self.history.append(self.global_params)

            # --- switching monitor: observe delayed arrivals of true updates
            if cfg.strategy == "ours" and cfg.switching:
                self._run_pending_checks(t)

            row: Dict[str, float] = {"round": t,
                                     "gi_iters": gi_iters_this_round}
            if self._last_gi is not None:
                # GI executor telemetry: fraction of paid lane-iterations
                # that advanced a real client (1.0 = no lockstep/padding
                # waste)
                row["gi_occupancy"] = self._last_gi["occupancy"]
                row["gi_wasted_lane_iters"] = float(
                    self._last_gi["wasted_lane_iters"])
            if eval_now is None:
                eval_now = (t % cfg.eval_every == 0)
            if eval_now:
                with tracer.span("step.eval"):
                    acc, per_class = self.evaluate()
                row["acc"] = acc
                for c, a in enumerate(per_class):
                    row[f"acc_class_{c}"] = float(a)
            self.metrics.append(row)
            if tracer.enabled:
                # cohort composition: fresh/stale split, base-round
                # scatter, realized staleness, and the pow2 bucket the GI
                # executor chose this round
                bases = [b for _, b in stale_pairs]
                taus = np.asarray([t - b for b in bases], np.int64)
                tracer.metric(
                    "cohort", version=t, n_fresh=len(fast),
                    n_stale=len(bases), n_base_rounds=len(set(bases)),
                    tau_mean=float(taus.mean()) if taus.size else 0.0,
                    tau_max=int(taus.max()) if taus.size else 0,
                    tau_hist=(np.bincount(taus).tolist()
                              if taus.size else []),
                    gi_bucket=(self._last_gi or {}).get("padded_to"),
                    gi_engine=(self._last_gi or {}).get("engine"))
        return row

    # ------------------------------------------------------------------ #
    # Fused aggregation round (stacked cohort tensors end to end)
    # ------------------------------------------------------------------ #
    def _aggregate_fused(self, t: int, fast: List[int],
                         stale_pairs: Sequence[Tuple[int, int]]) -> int:
        """One round as (at most) two jitted cohort computations.

        Stage 1 — LocalUpdates: one broadcast cohort for the fresh clients
        and ONE multi-version cohort for ALL stale deliveries (base params
        gathered from the VersionStore ring), regardless of how many
        distinct base rounds they span. Stage 2 — the stacked
        delta -> compensation -> FedAvg pipeline: leading-axis ops on the
        cohort stack, one weighted reduction per leaf. Bit-for-bit the loop
        path on matmul models (CPU conv kernels: ~1 ULP under regrouping).
        """
        cfg = self.cfg
        order = self._delivery_order(stale_pairs)
        ids = [i for i, _ in order]
        S = len(ids)

        fast_stack = None
        if fast:
            with tracer.span("step.fresh_update") as _sp:
                xs, ys, ms = self._client_stack(fast)
                w_fast = self._run_cohort(self.global_params, xs, ys, ms)
                fast_stack = _sp.fence(tree_sub(w_fast, self.global_params))
            if cfg.quant.enabled:
                # fresh uploads cross the same wire: the server aggregates
                # the dequantized deltas, the clients carry the residuals
                _, fast_stack, nbytes = quantize_delta_stack(
                    fast_stack, fast, t, cfg.quant, self._ef)
                self.wire_bytes += nbytes
            else:
                self.wire_bytes += len(fast) * self._upload_nbytes

        gi_iters = 0
        stale_stack = None
        taus = np.zeros((0,), np.int64)
        stale_weights = np.zeros((0,), np.float64)
        if S:
            bases = np.asarray([b for _, b in order], np.int64)
            taus = t - bases
            xs, ys, ms = self._client_stack(ids)
            counts = self._counts[np.asarray(ids, np.int64)]
            stale_weights = counts
            strat = cfg.strategy
            if strat == "unstale":
                # oracle: every stale client's TRUE update from the current
                # model, batched like the fresh cohort — the stale
                # LocalUpdates are never aggregated, so skip the base-param
                # gather and the multi-version dispatch entirely
                with tracer.span("step.stale_update") as _sp:
                    w_true = self._run_cohort(self.global_params, xs, ys, ms)
                    stale_stack = _sp.fence(
                        tree_sub(w_true, self.global_params))
                taus = np.zeros((S,), np.int64)
            else:
                with tracer.span("step.stale_update") as _sp:
                    w_base_stack = self.history.gather(bases)
                    w_stale_stack = self._run_cohort_multi(w_base_stack, xs,
                                                           ys, ms)
                    delta_stack = _sp.fence(
                        tree_sub(w_stale_stack, w_base_stack))
                qdelta = None
                if cfg.quant.enabled:
                    # stale uploads are quantized deltas too: downstream
                    # fp32 stages (uniqueness, top-K, compensation, FedAvg)
                    # see the dequantized reconstruction, while the GI
                    # target consumes the payload itself dequant-fused
                    qdelta, delta_stack, nbytes = quantize_delta_stack(
                        delta_stack, ids, t, cfg.quant, self._ef)
                    self.wire_bytes += nbytes
                    w_stale_stack = jax.tree_util.tree_map(
                        lambda b, d: b.astype(jnp.float32) + d,
                        w_base_stack, delta_stack)
                else:
                    self.wire_bytes += S * self._upload_nbytes
                if strat in ("unweighted", "asyn_tiers"):
                    stale_stack = delta_stack
                elif strat == "weighted":
                    stale_stack = delta_stack
                    stale_weights = counts * compensation.staleness_weight_batch(
                        taus, cfg.weighted_a, cfg.weighted_b)
                elif strat == "first_order":
                    stale_stack = compensation.first_order_batch(
                        delta_stack, self.global_params, w_base_stack,
                        cfg.fo_lambda)
                elif strat == "w_pred":
                    stale_stack = compensation.w_pred_batch(
                        delta_stack, self.history, w_base_stack, taus,
                        cfg.fo_lambda)
                elif strat == "ours":
                    stale_stack, iters = self._ours_update_fused(
                        t, ids, taus, w_stale_stack, w_base_stack,
                        delta_stack, fast_stack, qdelta=qdelta)
                    gi_iters = int(iters.sum())

        parts = [p for p in (fast_stack, stale_stack) if p is not None]
        if parts:
            with tracer.span("step.fedavg") as _sp:
                updates = tree_concat_leading(parts)
                weights = np.concatenate(
                    [self._counts[np.asarray(fast, np.int64)],
                     stale_weights])
                if cfg.strategy == "asyn_tiers" and S:
                    # tiering runs on the cohort's *realized* staleness —
                    # under the simulator these are observed delays, not
                    # the schedule
                    staleness = ([0.0] * len(fast)
                                 + [float(x) for x in taus])
                    agg = tiers.tiered_aggregate_stacked(
                        updates, staleness, weights.tolist(), cfg.n_tiers)
                else:
                    agg = aggregation.fedavg_stacked(updates,
                                                     weights.tolist())
                self.global_params = _sp.fence(aggregation.apply_update(
                    self.global_params, agg, cfg.server_lr))
        return gi_iters

    def _ours_update_fused(self, t: int, ids: List[int], taus: np.ndarray,
                           w_stale_stack, w_base_stack, delta_stack,
                           fast_stack, qdelta=None
                           ) -> Tuple[Any, np.ndarray]:
        """The paper's pipeline over the stacked stale cohort, stacked in
        AND out: uniqueness, masks, warm starts, inversion and the unstale
        estimates all operate on leading-axis tensors; the recovered deltas
        scatter back into the raw-delta stack (non-unique / switched-back
        clients keep their raw rows). Returns ``(delta stack, iters (S,))``.
        Same engines and PRNG stream as the loop path's
        ``_ours_update_batch`` — only the (un)stacking around them is gone.
        """
        cfg = self.cfg
        S = len(ids)
        iters = np.zeros((S,), np.int64)
        gamma = self.monitor.gamma(t) if cfg.switching else 1.0
        if gamma <= 0.0:
            return delta_stack, iters      # fully switched back to vanilla FL

        rows = np.arange(S)
        if cfg.uniqueness_check and fast_stack is not None:
            unique, _ = is_unique_batch(delta_stack, fast_stack)
            rows = np.flatnonzero(unique)
        if rows.size == 0:
            return delta_stack, iters      # no unique knowledge: aggregate raw

        gi_ids = [ids[r] for r in rows]
        w_stale_g = tree_index_select(w_stale_stack, rows)
        w_base_g = tree_index_select(w_base_stack, rows)
        delta_g = tree_index_select(delta_stack, rows)

        masks = None
        if cfg.gi.keep_fraction < 1.0:
            masks = topk_mask_batch(delta_g, cfg.gi.keep_fraction,
                                    mesh=self.mesh)

        # split per client in delivery order — reproduces the seed engine's
        # exact PRNG stream, so cold-start inits match the sequential path
        subs = []
        for _ in gi_ids:
            self.key, sub = jax.random.split(self.key)
            subs.append(sub)
        keys = jnp.stack(subs)

        with tracer.span("step.gi") as _sp:
            inits, flags = None, None
            if cfg.gi.warm_start:
                if self._n_shards > 1:
                    xs, ys, warm = self.warm.gather_sharded(
                        gi_ids, self.mesh,
                        pad_to=shard_bucket(len(gi_ids), self._n_shards))
                else:
                    xs, ys, warm = self.warm.gather(gi_ids)
                if xs is not None:
                    inits, flags = (xs, ys), jnp.asarray(warm)
            drec, info = self.inverter.invert_batch(
                w_base_g, w_stale_g, keys,
                masks=masks, inits=inits, init_flags=flags,
                target_q=(None if qdelta is None
                          else tree_index_select(qdelta, rows)))
            w_hat_stack = _sp.fence(self.inverter.estimate_unstale_batch(
                self.global_params, drec))
        iters_used = np.asarray(info["iters_used"])
        final_loss = np.asarray(info["final_loss"])
        stops = self._record_gi_telemetry(info, iters_used, gi_ids)

        if cfg.gi.warm_start:
            self.warm.put_stacked(gi_ids, *drec)

        hat_delta = tree_sub(w_hat_stack, self.global_params)
        schedule_checks = cfg.switching and t % cfg.switch_check_every == 0
        for b, i in enumerate(gi_ids):
            self.gi_log.append({"round": t, "client": i,
                                "final_loss": float(final_loss[b]),
                                "iters_used": int(iters_used[b]),
                                "stop": stops[b]})
            if schedule_checks:
                # delayed E1/E2 check (observable at t + tau); only the
                # clients that actually ran GI are unstacked, on the host
                w_hat_b = jax.tree_util.tree_map(lambda a: a[b], w_hat_stack)
                w_stale_b = jax.tree_util.tree_map(lambda a: a[b], w_stale_g)
                tau = int(taus[rows[b]])
                self._pending_checks.setdefault(t + tau, []).append(
                    (t, i, w_hat_b, w_stale_b))

        if tracer.enabled:
            tracer.metric("compensation", strategy="ours",
                          gamma=float(gamma), n=len(gi_ids))
        if gamma < 1.0:
            hat_delta = jax.tree_util.tree_map(
                lambda h, s: gamma * h + (1.0 - gamma) * s,
                hat_delta, delta_g)
        out = jax.tree_util.tree_map(
            lambda full, h: full.at[jnp.asarray(rows)].set(h),
            delta_stack, hat_delta)
        iters[rows] = iters_used
        return out, iters

    def _record_gi_telemetry(self, info: Dict[str, Any],
                             iters_used: np.ndarray,
                             gi_ids: Optional[Sequence[int]] = None
                             ) -> List[str]:
        """Record one GI invocation's executor telemetry into ``_last_gi``
        and the cross-round accumulators.

        Returns the per-client early-stop reasons: ``"tol"`` when the lane
        stopped before its iteration budget (the loss tolerance fired),
        ``"budget"`` when it ran the budget out. Budgets come from the
        executor's ``info`` (per-client when warm starts or callers vary
        them) and default to ``cfg.gi.iters``.
        """
        budgets = np.asarray(info.get(
            "budgets", np.full(len(iters_used), self.cfg.gi.iters)))
        stops = ["tol" if int(u) < int(b) else "budget"
                 for u, b in zip(iters_used, budgets)]
        occ = info.get("occupancy")
        if occ is None:
            # one-shot engine: lockstep cost model — every resident lane
            # (incl. bucket padding) pays for the slowest lane
            cost = int(info["padded_to"]) * int(iters_used.max(initial=0))
            used = int(iters_used.sum())
            occ = float(used / cost) if cost else 1.0
            wasted = cost - used if cost else 0
        else:
            wasted = int(info["wasted_lane_iters"])
        self._last_gi = {"occupancy": float(occ),
                         "wasted_lane_iters": wasted,
                         "engine": info.get("engine", "oneshot"),
                         "padded_to": int(info.get("padded_to",
                                                   len(iters_used))),
                         "clients": ([] if gi_ids is None
                                     else [int(i) for i in gi_ids]),
                         "iters": [int(u) for u in iters_used],
                         "stops": stops}
        if gi_ids is not None:
            for i, u, s in zip(gi_ids, iters_used, stops):
                i = int(i)
                self.gi_client_iters[i] = (self.gi_client_iters.get(i, 0)
                                           + int(u))
                self.gi_client_calls[i] = self.gi_client_calls.get(i, 0) + 1
                self.gi_stop_counts[s] += 1
        return stops

    # ------------------------------------------------------------------ #
    # Loop aggregation round (per-client reference path)
    # ------------------------------------------------------------------ #
    def _aggregate_loop(self, t: int, fast: List[int],
                        stale_pairs: Sequence[Tuple[int, int]]) -> int:
        """The historic per-client round: deliveries grouped by base round,
        per-client compensation, Python list-of-pytrees FedAvg. The fused
        round's equivalence oracle (``FLConfig.fused_step=False``)."""
        cfg = self.cfg
        slow_deliveries = self.compute_deliveries(t, stale_pairs)

        # --- fast clients: fresh updates from the current global model
        if fast:
            xs, ys, ms = self._client_stack(fast)
            w_fast = self._run_cohort(self.global_params, xs, ys, ms)
            fast_updates = [
                tree_sub(jax.tree_util.tree_map(lambda a: a[j], w_fast),
                         self.global_params)
                for j in range(len(fast))]
            if cfg.quant.enabled:
                # same wire as the fused round: quantize the fresh uploads
                # (identical Philox streams + per-client residuals, so the
                # two paths see the same quantized bytes)
                _, fdeq, nbytes = quantize_delta_stack(
                    tree_stack(fast_updates), fast, t, cfg.quant, self._ef)
                self.wire_bytes += nbytes
                fast_updates = [
                    jax.tree_util.tree_map(lambda a: a[j], fdeq)
                    for j in range(len(fast))]
            else:
                self.wire_bytes += len(fast) * self._upload_nbytes
            fast_counts = [float(self._counts[i]) for i in fast]
        else:
            fast_updates, fast_counts = [], []

        if slow_deliveries and cfg.strategy != "unstale" \
                and not cfg.quant.enabled:
            self.wire_bytes += len(slow_deliveries) * self._upload_nbytes
        if slow_deliveries and cfg.quant.enabled and cfg.strategy != "unstale":
            # stale uploads: replace each delivered w_stale with the
            # dequantized reconstruction base + deq(quant(delta)), so every
            # downstream per-client stage sees what actually crossed the wire
            ids_d = list(slow_deliveries.keys())
            dstack = tree_stack([tree_sub(slow_deliveries[i][0],
                                          slow_deliveries[i][1])
                                 for i in ids_d])
            _, ddeq, nbytes = quantize_delta_stack(
                dstack, ids_d, t, cfg.quant, self._ef)
            self.wire_bytes += nbytes
            for j, i in enumerate(ids_d):
                w_base = slow_deliveries[i][1]
                w_q = jax.tree_util.tree_map(
                    lambda b, d: b.astype(jnp.float32) + d[j],
                    w_base, ddeq)
                slow_deliveries[i] = (w_q, w_base, slow_deliveries[i][2])

        updates = list(fast_updates)
        weights = list(fast_counts)
        staleness_list = [0.0] * len(fast)
        gi_iters_this_round = 0

        # "ours": the whole stale cohort goes through ONE batched GI call
        # (uniqueness, masks, warm starts and inversion are all stacked;
        # with cfg.gi.segment_iters > 0 the call is the segmented executor's
        # pending queue and lanes drain it at near-full occupancy)
        ours_deltas: Dict[int, Tuple[Any, int]] = {}
        if cfg.strategy == "ours" and slow_deliveries:
            ours_deltas = self._ours_update_batch(t, slow_deliveries,
                                                  fast_updates)

        for i, (w_stale, w_base, tau_eff) in slow_deliveries.items():
            count = float(self._counts[i])
            strat = cfg.strategy
            # "ours"/"unstale" never read the raw stale delta here ("ours"
            # computes it once inside the batched pipeline)
            stale_delta = (None if strat in ("ours", "unstale")
                           else tree_sub(w_stale, w_base))

            if strat == "unstale":
                x, y, m = self._client_shard(i)
                w_true = self._local_update(self.global_params, x, y, m)[0]
                updates.append(tree_sub(w_true, self.global_params))
                weights.append(count)
                staleness_list.append(0.0)
                continue

            if strat in ("unweighted", "asyn_tiers"):
                updates.append(stale_delta)
                weights.append(count)
            elif strat == "weighted":
                w = compensation.staleness_weight(tau_eff, cfg.weighted_a, cfg.weighted_b)
                updates.append(stale_delta)
                weights.append(count * w)
            elif strat == "first_order":
                updates.append(compensation.first_order(
                    stale_delta, self.global_params, w_base, cfg.fo_lambda))
                weights.append(count)
            elif strat == "w_pred":
                updates.append(compensation.w_pred(
                    stale_delta, self.history, w_base, tau_eff, cfg.fo_lambda))
                weights.append(count)
            elif strat == "ours":
                delta, used = ours_deltas[i]
                gi_iters_this_round += used
                updates.append(delta)
                weights.append(count)
            staleness_list.append(float(tau_eff))

        if updates:
            if cfg.strategy == "asyn_tiers" and slow_deliveries:
                # tiering runs on the cohort's *realized* staleness — under
                # the simulator these are observed delays, not the schedule
                agg = tiers.tiered_aggregate(updates, staleness_list, weights,
                                             cfg.n_tiers)
            else:
                agg = aggregation.fedavg(updates, weights)
            self.global_params = aggregation.apply_update(
                self.global_params, agg, cfg.server_lr)
        return gi_iters_this_round

    # ------------------------------------------------------------------ #
    def _ours_update_batch(self, t: int,
                           deliveries: Dict[int, Tuple[Any, Any, int]],
                           fast_updates) -> Dict[int, Tuple[Any, int]]:
        """The paper's pipeline over a round's whole stale cohort.

        Uniqueness detection, top-K masking and warm starts are computed as
        stacked batch tensors; the inversion itself is ONE jitted
        vmap+while_loop call (``GradientInverter.invert_batch``) — no
        per-client or per-iteration Python dispatch. Returns
        ``{client: (delta, iters_used)}`` aligned with ``deliveries``.
        ``cfg.batched_gi=False`` keeps the sequential per-client engine
        (identical pipeline, used for equivalence tests and benchmarks).
        """
        cfg = self.cfg
        ids = list(deliveries.keys())
        stale_deltas = {i: tree_sub(deliveries[i][0], deliveries[i][1])
                        for i in ids}
        out: Dict[int, Tuple[Any, int]] = {
            i: (stale_deltas[i], 0) for i in ids}

        gamma = self.monitor.gamma(t) if cfg.switching else 1.0
        if gamma <= 0.0:
            return out                     # fully switched back to vanilla FL

        gi_ids = ids
        if cfg.uniqueness_check and fast_updates:
            unique, _ = is_unique_batch([stale_deltas[i] for i in ids],
                                        fast_updates)
            gi_ids = [i for i, u in zip(ids, unique) if u]
        if not gi_ids:
            return out                     # no unique knowledge: aggregate raw

        # stacked inputs: each client may come from a different base round
        w_stale_stack = tree_stack([deliveries[i][0] for i in gi_ids])
        w_base_stack = tree_stack([deliveries[i][1] for i in gi_ids])

        masks = None
        if cfg.gi.keep_fraction < 1.0:
            masks = topk_mask_batch([stale_deltas[i] for i in gi_ids],
                                    cfg.gi.keep_fraction, mesh=self.mesh)

        # split per client in delivery order — reproduces the seed engine's
        # exact PRNG stream, so cold-start inits match the sequential path
        subs = []
        for _ in gi_ids:
            self.key, sub = jax.random.split(self.key)
            subs.append(sub)
        keys = jnp.stack(subs)

        if cfg.batched_gi:
            inits, flags = None, None
            if cfg.gi.warm_start:
                if self._n_shards > 1:
                    # pre-bucketed + mesh-placed; survives round-to-round
                    # reshards because the cache itself is host-resident
                    xs, ys, warm = self.warm.gather_sharded(
                        gi_ids, self.mesh,
                        pad_to=shard_bucket(len(gi_ids), self._n_shards))
                else:
                    xs, ys, warm = self.warm.gather(gi_ids)
                if xs is not None:
                    inits, flags = (xs, ys), jnp.asarray(warm)
            with tracer.span("step.gi") as _sp:
                drec, info = self.inverter.invert_batch(
                    w_base_stack, w_stale_stack, keys,
                    masks=masks, inits=inits, init_flags=flags)
                w_hat_stack = _sp.fence(
                    self.inverter.estimate_unstale_batch(
                        self.global_params, drec))
            iters_used = np.asarray(info["iters_used"])
            final_loss = np.asarray(info["final_loss"])
        else:   # sequential reference engine (same inputs, per-client loop)
            with tracer.span("step.gi") as _sp:
                drecs, iters_used, final_loss = [], [], []
                for b, i in enumerate(gi_ids):
                    init_b = self.warm.get(i) if cfg.gi.warm_start else None
                    mask_b = None if masks is None else masks[b]
                    d, inf = self.inverter.invert(
                        deliveries[i][1], deliveries[i][0], keys[b],
                        mask=mask_b, init=init_b)
                    drecs.append(d)
                    iters_used.append(inf["iters_used"])
                    final_loss.append(inf["final_loss"])
                drec = tree_stack(drecs)
                w_hat_stack = _sp.fence(
                    self.inverter.estimate_unstale_batch(
                        self.global_params, drec))
            iters_used = np.asarray(iters_used)
            # the sequential engine runs one lane at a time: no lockstep
            # waste by construction, budget = cfg.gi.iters for every lane
            info = {"engine": "sequential", "padded_to": len(gi_ids),
                    "occupancy": 1.0, "wasted_lane_iters": 0}
        stops = self._record_gi_telemetry(info, iters_used, gi_ids)

        if cfg.gi.warm_start:
            self.warm.put_stacked(gi_ids, *drec)

        for b, i in enumerate(gi_ids):
            w_hat = jax.tree_util.tree_map(lambda a: a[b], w_hat_stack)
            w_stale = deliveries[i][0]
            self.gi_log.append({"round": t, "client": i,
                                "final_loss": float(final_loss[b]),
                                "iters_used": int(iters_used[b]),
                                "stop": stops[b]})
            hat_delta = tree_sub(w_hat, self.global_params)

            # schedule the delayed E1/E2 check (observable at t + tau) —
            # recording WHICH client it belongs to so the check recomputes
            # that client's true update, not the first slow client's. tau is
            # the *realized* staleness of this delivery (== schedule.tau in
            # the round-synchronous path, observed delay under the simulator)
            tau = deliveries[i][2]
            if cfg.switching and t % cfg.switch_check_every == 0:
                self._pending_checks.setdefault(t + tau, []).append(
                    (t, i, w_hat, w_stale))

            if gamma < 1.0:
                hat_delta = jax.tree_util.tree_map(
                    lambda h, s: gamma * h + (1.0 - gamma) * s,
                    hat_delta, stale_deltas[i])
            out[i] = (hat_delta, int(iters_used[b]))
        return out

    def _run_pending_checks(self, t: int) -> None:
        for due in [k for k in self._pending_checks if k <= t]:
            for (t0, i, w_hat, w_stale) in self._pending_checks.pop(due):
                # the true unstale update w_i^{t0} arrives now: recompute it
                # exactly as client i computed it at t0
                if t0 >= len(self.history):
                    continue
                try:
                    w_base = self.history[t0]
                except KeyError:
                    continue    # version evicted (spill disabled): skip check
                x, y, m = self._client_shard(i)
                w_true = self._local_update(w_base, x, y, m)[0]
                self.monitor.observe(t0, w_hat, w_stale, w_true)

    def summary(self) -> Dict[str, Any]:
        """Cross-round GI accounting: total/per-client iteration counts and
        early-stop reasons (tol vs budget), plus the last invocation's
        executor telemetry."""
        gi: Dict[str, Any] = {
            "total_iters": int(sum(self.gi_client_iters.values())),
            "clients_inverted": len(self.gi_client_iters),
            "per_client_iters": {int(k): int(v) for k, v in
                                 sorted(self.gi_client_iters.items())},
            "per_client_calls": {int(k): int(v) for k, v in
                                 sorted(self.gi_client_calls.items())},
            "stop_reasons": dict(self.gi_stop_counts),
        }
        if self._last_gi is not None:
            gi["last"] = dict(self._last_gi)
        return {"strategy": self.cfg.strategy,
                "versions": len(self.metrics),
                "quant_bits": int(self.cfg.quant.bits),
                "wire_bytes": int(self.wire_bytes),
                "gi": gi}

    # ------------------------------------------------------------------ #
    def run(self, rounds: Optional[int] = None) -> List[Dict[str, float]]:
        n = rounds or self.cfg.rounds
        for t in range(n):
            self.round(t)
        # always evaluate the final model (eval_every may not divide n-1)
        if self.metrics and "acc" not in self.metrics[-1]:
            acc, per_class = self.evaluate()
            self.metrics[-1]["acc"] = acc
            for c, a in enumerate(per_class):
                self.metrics[-1][f"acc_class_{c}"] = float(a)
        return self.metrics
