"""Client-side LocalUpdate (paper Eq. 4/5).

``LocalUpdate(w_global; D_i)`` runs a multi-step local training program and
returns the locally-trained weights. Two requirements shape the design:

1. The FL runtime vmaps it over the whole cohort (clients are vectorized —
   this is what shards over the ``data``/``pod`` mesh axes at scale).
2. Gradient inversion differentiates *through* it w.r.t. the training data
   (x, y_soft), so it is written as a ``jax.lax.scan`` of optimizer steps —
   one fused differentiable program, the TPU-native re-expression of the
   paper's torch loop (DESIGN.md §3).

Labels may be hard ints (real clients) or soft distributions (D_rec), both
routed through the same soft-label cross entropy.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer, adam, apply_updates, fedprox_wrap, sgd


@dataclasses.dataclass(frozen=True)
class LocalProgram:
    """The client's local training program (paper §4.1: 5 epochs of SGD,
    lr=0.01, momentum=0.5; Appendix E varies steps and optimizer)."""

    steps: int = 5
    lr: float = 0.01
    momentum: float = 0.5
    optimizer: str = "sgdm"         # sgd | sgdm | adam | fedprox
    fedprox_mu: float = 0.01
    # remat each scanned optimizer step (jax.checkpoint): anything that
    # differentiates *through* LocalUpdate — GI's while_loop body above all
    # — recomputes the step's forward during the backward sweep instead of
    # holding `steps` sets of model activations live at once. Value-neutral
    # (same ops, same order), so every bitwise equivalence contract holds
    # with it on or off; composes with ModelConfig.remat, which remats
    # *inside* one forward (the layer scan).
    remat: bool = False

    def make(self, global_params=None) -> Optimizer:
        if self.optimizer == "sgd":
            return sgd(self.lr)
        if self.optimizer == "sgdm":
            return sgd(self.lr, momentum=self.momentum)
        if self.optimizer == "adam":
            return adam(self.lr)
        if self.optimizer == "fedprox":
            assert global_params is not None
            return fedprox_wrap(sgd(self.lr, momentum=self.momentum),
                                self.fedprox_mu, global_params)
        raise ValueError(self.optimizer)


def soft_ce_loss(apply_fn: Callable, params: Any, x: jax.Array, y: jax.Array,
                 sample_mask: Optional[jax.Array] = None) -> jax.Array:
    """Cross entropy supporting hard int labels or soft label logits.

    y int (n,) -> one-hot targets; y float (n, C) -> softmax(y) targets
    (D_rec labels are optimized as unconstrained logits).
    """
    logits = apply_fn(params, x).astype(jnp.float32)
    if jnp.issubdtype(y.dtype, jnp.integer):
        targets = jax.nn.one_hot(y, logits.shape[-1], dtype=jnp.float32)
    else:
        targets = jax.nn.softmax(y.astype(jnp.float32), axis=-1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.sum(targets * logp, axis=-1)
    if sample_mask is None:
        return jnp.mean(nll)
    m = sample_mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def make_local_update(apply_fn: Callable, program: LocalProgram):
    """Returns ``local_update(params, x, y, sample_mask=None) -> new_params``.

    Full-batch GD steps scanned ``program.steps`` times; differentiable in
    (params, x, y). This is the paper's ``LocalUpdate`` operator reused by
    (a) real clients, (b) GI's inner loop, (c) the unstale-estimate retrain.
    """

    def local_update(params, x, y, sample_mask=None):
        opt = program.make(global_params=params)
        opt_state = opt.init(params)

        def step(carry, _):
            p, s = carry
            loss, grads = jax.value_and_grad(
                lambda pp: soft_ce_loss(apply_fn, pp, x, y, sample_mask))(p)
            updates, s = opt.update(grads, s, p)
            return (apply_updates(p, updates), s), loss

        if program.remat:
            step = jax.checkpoint(step)
        (p, _), losses = jax.lax.scan(step, (params, opt_state), None,
                                      length=program.steps)
        return p, losses

    return local_update


def make_cohort_update(apply_fn: Callable, program: LocalProgram,
                       per_client_params: bool = False):
    """Vectorized LocalUpdate over a stacked cohort: x (N, n, ...), y (N, n),
    sample_mask (N, n). Returns stacked client params.

    ``per_client_params=False`` broadcasts one global model over the cohort
    (the synchronous round shape). ``per_client_params=True`` is the
    **multi-version** cohort: params arrive stacked ``(N, ...)`` too
    (``in_axes=(0, 0, 0, 0)``), so every lane trains from its *own* base
    version — the shape unlimited-staleness deliveries produce, where each
    client's update must start from ``w_global^{t - tau_i}``. Lanes are
    gathered from a ``repro.core.versions.VersionStore`` in one take per
    leaf, and the whole mixed-version cohort runs as ONE vmapped program
    instead of one dispatch per distinct base round.

    At production scale the N axis is sharded over the (pod, data) mesh axes
    (see repro.launch) — FL aggregation then lowers to an all-reduce.
    """
    lu = make_local_update(apply_fn, program)

    if per_client_params:
        def cohort_update(params, xs, ys, masks):
            return jax.vmap(lambda p, x, y, m: lu(p, x, y, m)[0])(
                params, xs, ys, masks)
    else:
        def cohort_update(params, xs, ys, masks):
            return jax.vmap(lambda x, y, m: lu(params, x, y, m)[0])(
                xs, ys, masks)

    return cohort_update
