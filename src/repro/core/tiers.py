"""Asyn-Tiers baseline (FedAT, Chai et al. 2021; paper §4).

Clients are clustered into asynchronous tiers by staleness; each tier runs
synchronous FedAvg internally, and the cross-tier combination weights each
tier by its client count (paper §4: two tiers in the evaluation).
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence

import numpy as np

from repro.core.aggregation import fedavg, fedavg_stacked
from repro.core.disparity import tree_index_select


def cluster_tiers(staleness: Sequence[float], n_tiers: int = 2) -> List[List[int]]:
    """Greedy 1-D clustering of clients by staleness into ``n_tiers`` groups
    (threshold at the largest gaps, FedAT-style).

    Deterministic on every platform: sorts are stable, tied gaps resolve to
    the earliest position, and cuts are only placed at strictly positive gaps
    — so clients with equal staleness always land in the same tier and
    ``n_tiers`` greater than the number of distinct staleness levels yields
    one tier per level. Works on *observed* (realized) staleness just as well
    as on a static schedule; the simulator feeds it per-arrival realized taus.
    """
    idx = np.argsort(staleness, kind="stable")
    taus = np.asarray(staleness, dtype=np.float64)[idx]
    if len(set(taus.tolist())) <= 1 or n_tiers <= 1:
        return [list(map(int, idx))]
    gaps = np.diff(taus)
    positive = np.nonzero(gaps > 0)[0]
    # largest gaps first; -gaps + stable sort => ties pick the earliest cut
    order = positive[np.argsort(-gaps[positive], kind="stable")]
    cut_pos = np.sort(order[: n_tiers - 1])
    tiers, start = [], 0
    for c in cut_pos:
        tiers.append([int(i) for i in idx[start:c + 1]])
        start = c + 1
    tiers.append([int(i) for i in idx[start:]])
    return [t for t in tiers if t]


def tiered_aggregate(updates: List[Any], staleness: Sequence[float],
                     sample_counts: Sequence[float], n_tiers: int = 2) -> Any:
    """FedAvg within each tier, then combine tier means weighted by size."""
    tiers = cluster_tiers(staleness, n_tiers)
    tier_means, tier_weights = [], []
    for tier in tiers:
        t_updates = [updates[i] for i in tier]
        t_counts = [sample_counts[i] for i in tier]
        tier_means.append(fedavg(t_updates, t_counts))
        tier_weights.append(float(len(tier)))
    return fedavg(tier_means, tier_weights)


def tiered_aggregate_stacked(stacked_updates: Any,
                             staleness: Sequence[float],
                             sample_counts: Sequence[float],
                             n_tiers: int = 2) -> Any:
    """``tiered_aggregate`` over a stacked cohort (axis 0 = client).

    Clustering stays on the host (same deterministic ``cluster_tiers``);
    each tier's mean is one gathered ``fedavg_stacked`` — O(n_tiers) device
    ops on leading-axis tensors instead of a per-client Python list walk,
    and bit-for-bit the list form's result for identical cohort rows.
    """
    tiers = cluster_tiers(staleness, n_tiers)
    counts = np.asarray(sample_counts, np.float64)
    tier_means, tier_weights = [], []
    for tier in tiers:
        sub = tree_index_select(stacked_updates, tier)
        tier_means.append(fedavg_stacked(sub, counts[tier].tolist()))
        tier_weights.append(float(len(tier)))
    return fedavg(tier_means, tier_weights)
