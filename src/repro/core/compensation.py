"""Baseline staleness-handling strategies the paper compares against (§4).

* ``staleness_weight``    — Weighted aggregation, w = 1/(1+e^{a(tau-b)})
                            with a=0.25, b=10 (Shi et al. 2020; paper §4).
* ``first_order``         — 1st-Order Taylor compensation with the
                            lambda*g (.) g Hessian approximation
                            (Zheng et al. 2017; paper Eq. 1-2).
* ``w_pred``              — future-global-weight prediction (Hakimi et al.
                            2019): staleness assumed pre-known, the future
                            global model is linearly extrapolated and the
                            first-order compensation applied toward it.

All operate on *updates* (deltas) u = w_client - w_global_base.
"""

from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.core.disparity import tree_scale, tree_sub


def staleness_weight(tau: float, a: float = 0.25, b: float = 10.0) -> float:
    """Sigmoid-decay aggregation weight for a stale update (paper §4)."""
    return float(1.0 / (1.0 + jnp.exp(a * (tau - b))))


def first_order(update_stale: Any, w_global_now: Any, w_global_stale: Any,
                lam: float = 1.0) -> Any:
    """g(w_t) ~= g(w_{t-tau}) + lam * g (.) g (.) (w_t - w_{t-tau}).

    ``update_stale`` plays the role of the (negative-scaled) gradient g; the
    compensation moves it toward what it would have been at w_t.
    """
    dw = tree_sub(w_global_now, w_global_stale)
    return jax.tree_util.tree_map(
        lambda g, d: g + lam * g * g * d, update_stale, dw)


def predict_future_global(history: List[Any], tau: int) -> Any:
    """W-Pred: linear extrapolation of the global weights tau rounds ahead
    from the last two snapshots (staleness assumed pre-known)."""
    assert len(history) >= 1
    if len(history) == 1:
        return history[-1]
    w_now, w_prev = history[-1], history[-2]
    step = tree_sub(w_now, w_prev)
    return jax.tree_util.tree_map(
        lambda w, s: w + tau * s.astype(w.dtype), w_now, step)


def w_pred(update_stale: Any, history: List[Any], w_global_stale: Any,
           tau: int, lam: float = 1.0) -> Any:
    """First-order compensation toward the *predicted* future global model."""
    w_future = predict_future_global(history, tau)
    return first_order(update_stale, w_future, w_global_stale, lam)
