"""Baseline staleness-handling strategies the paper compares against (§4).

* ``staleness_weight``    — Weighted aggregation, w = 1/(1+e^{a(tau-b)})
                            with a=0.25, b=10 (Shi et al. 2020; paper §4).
* ``first_order``         — 1st-Order Taylor compensation with the
                            lambda*g (.) g Hessian approximation
                            (Zheng et al. 2017; paper Eq. 1-2).
* ``w_pred``              — future-global-weight prediction (Hakimi et al.
                            2019): staleness assumed pre-known, the future
                            global model is linearly extrapolated and the
                            first-order compensation applied toward it.

All operate on *updates* (deltas) u = w_client - w_global_base.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.disparity import tree_scale, tree_sub
from repro.obs import tracer

# ``history`` arguments below accept anything with len() and [-1]/[-2]
# indexing: the historic Python list of snapshots or the bounded
# ``repro.core.versions.VersionStore`` ring buffer.


def staleness_weight(tau: float, a: float = 0.25, b: float = 10.0) -> float:
    """Sigmoid-decay aggregation weight for a stale update (paper §4)."""
    return float(1.0 / (1.0 + jnp.exp(a * (tau - b))))


_SW_MEMO: Dict[Tuple[float, float, float], float] = {}


def staleness_weight_batch(taus: Sequence[float], a: float = 0.25,
                           b: float = 10.0) -> np.ndarray:
    """Per-client ``staleness_weight`` for a whole cohort, memoized per
    distinct ``(tau, a, b)``.

    Realized staleness values are small integers, so after warm-up this is
    a pure dict lookup — the fused aggregation round pays zero device
    dispatches for weighting while staying bit-identical to the scalar
    form (each entry IS ``staleness_weight(tau)``'s float64 result).
    """
    out = np.empty(len(taus), np.float64)
    for j, tau in enumerate(np.asarray(taus).tolist()):
        key = (tau, a, b)
        w = _SW_MEMO.get(key)
        if w is None:
            w = staleness_weight(tau, a, b)
            _SW_MEMO[key] = w
        out[j] = w
    if tracer.enabled and len(out):
        tracer.metric("compensation", strategy="weighted", n=len(out),
                      alpha_mean=float(out.mean()),
                      alpha_min=float(out.min()),
                      alpha_max=float(out.max()))
    return out


def first_order(update_stale: Any, w_global_now: Any, w_global_stale: Any,
                lam: float = 1.0) -> Any:
    """g(w_t) ~= g(w_{t-tau}) + lam * g (.) g (.) (w_t - w_{t-tau}).

    ``update_stale`` plays the role of the (negative-scaled) gradient g; the
    compensation moves it toward what it would have been at w_t.
    """
    dw = tree_sub(w_global_now, w_global_stale)
    return jax.tree_util.tree_map(
        lambda g, d: g + lam * g * g * d, update_stale, dw)


def predict_future_global(history: List[Any], tau: int) -> Any:
    """W-Pred: linear extrapolation of the global weights tau rounds ahead
    from the last two snapshots (staleness assumed pre-known)."""
    assert len(history) >= 1
    if len(history) == 1:
        return history[-1]
    w_now, w_prev = history[-1], history[-2]
    step = tree_sub(w_now, w_prev)
    return jax.tree_util.tree_map(
        lambda w, s: w + tau * s.astype(w.dtype), w_now, step)


def w_pred(update_stale: Any, history: List[Any], w_global_stale: Any,
           tau: int, lam: float = 1.0) -> Any:
    """First-order compensation toward the *predicted* future global model."""
    w_future = predict_future_global(history, tau)
    return first_order(update_stale, w_future, w_global_stale, lam)


# --------------------------------------------------------------------------- #
# Stacked-cohort forms (the fused aggregation round's leading-axis pipeline)
# --------------------------------------------------------------------------- #


def _first_order_stacked(updates_stacked: Any, w_target: Any,
                         w_base_stacked: Any, lam: float) -> Any:
    """Shared math for the stacked first-order forms (no telemetry —
    public wrappers emit their own per-strategy metric row).

    Compensation math is pinned to fp32: bf16-compute models hand bf16
    deltas through here, but the g (.) g (.) dw Hessian surrogate squares
    already-small update entries — in bf16 (8 mantissa bits) the correction
    underflows to garbage. Outputs are therefore always fp32 leaves
    (``aggregation.apply_update`` casts back to the param dtype at the very
    end); for fp32 inputs the casts are no-ops and the result is
    bit-identical to the historic form."""
    dw = tree_sub(w_target, w_base_stacked)

    def comp(g, d):
        gf = g.astype(jnp.float32)
        return gf + lam * gf * gf * d.astype(jnp.float32)

    return jax.tree_util.tree_map(comp, updates_stacked, dw)


def _cohort_size(tree: Any) -> int:
    return int(jax.tree_util.tree_leaves(tree)[0].shape[0])


def first_order_batch(updates_stacked: Any, w_global_now: Any,
                      w_base_stacked: Any, lam: float = 1.0) -> Any:
    """``first_order`` over a stacked cohort in one pass per leaf.

    ``updates_stacked`` / ``w_base_stacked`` carry the cohort on axis 0
    (each lane may come from a different base version); ``w_global_now``
    may be cohort-invariant (broadcast) or stacked too. Elementwise, so
    every lane is bit-for-bit the per-client ``first_order`` result.
    """
    if tracer.enabled:
        tracer.metric("compensation", strategy="first_order",
                      lam=float(lam), n=_cohort_size(updates_stacked))
    return _first_order_stacked(updates_stacked, w_global_now,
                                w_base_stacked, lam)


def predict_future_global_batch(history, taus: Sequence[int]) -> Any:
    """W-Pred extrapolation for a cohort of per-lane staleness values.

    Returns the stacked ``(B, ...)`` predicted future models (one linear
    extrapolation per lane from the same last-two snapshots); with a single
    snapshot the cohort-invariant ``history[-1]`` is returned and callers
    broadcast it. Per lane this is exactly ``predict_future_global``.
    """
    assert len(history) >= 1
    if len(history) == 1:
        return jax.tree_util.tree_map(
            lambda w: w.astype(jnp.float32), history[-1])
    w_now, w_prev = history[-1], history[-2]
    step = tree_sub(w_now, w_prev)
    tv = jnp.asarray(np.asarray(taus, np.float32))
    # fp32 like the rest of the compensation math: tau * step amplifies the
    # inter-round drift by the staleness, so bf16 extrapolation compounds
    return jax.tree_util.tree_map(
        lambda w, s: w.astype(jnp.float32)
        + tv.reshape((-1,) + (1,) * s.ndim) * s.astype(jnp.float32),
        w_now, step)


def w_pred_batch(updates_stacked: Any, history, w_base_stacked: Any,
                 taus: Sequence[int], lam: float = 1.0) -> Any:
    """Stacked-cohort W-Pred: extrapolate once per lane, compensate in one
    leading-axis pass (no per-client pytree traffic)."""
    if tracer.enabled:
        tv = np.asarray(taus, np.float64)
        tracer.metric("compensation", strategy="w_pred", lam=float(lam),
                      n=_cohort_size(updates_stacked),
                      tau_mean=float(tv.mean()) if tv.size else 0.0)
    w_future = predict_future_global_batch(history, taus)
    return _first_order_stacked(updates_stacked, w_future, w_base_stacked,
                                lam)
