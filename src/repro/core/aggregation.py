"""Server-side aggregation (FedAvg and weighted variants).

FedAvg weights every update by the client's sample count; strategy weights
(staleness decay, gamma smoothing, tier size) multiply on top (paper §4,
footnote 3). Aggregation operates on *updates* (deltas from the current
global model), which is equivalent to weight averaging under equal bases and
is what makes stale-update conversion composable.
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp


def fedavg(updates: List[Any], weights: Optional[Sequence[float]] = None) -> Any:
    """Weighted mean of update pytrees."""
    assert updates, "no updates to aggregate"
    if weights is None:
        weights = [1.0] * len(updates)
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)

    def combine(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        return jnp.tensordot(w, stacked, axes=1).astype(leaves[0].dtype)

    return jax.tree_util.tree_map(combine, *updates)


def apply_update(global_params: Any, update: Any, server_lr: float = 1.0) -> Any:
    return jax.tree_util.tree_map(
        lambda p, u: (p + server_lr * u.astype(jnp.float32)).astype(p.dtype),
        global_params, update)


def cohort_mean_update(stacked_updates: Any, weights: jax.Array) -> Any:
    """Vectorized FedAvg over a stacked cohort axis (axis 0) — the form the
    distributed runtime uses (the leading axis is sharded over the mesh and
    this mean lowers to an all-reduce)."""
    w = weights / jnp.maximum(jnp.sum(weights), 1e-12)

    def mean(leaf):
        return jnp.tensordot(w, leaf.astype(jnp.float32), axes=1)

    return jax.tree_util.tree_map(mean, stacked_updates)
