"""Server-side aggregation (FedAvg and weighted variants).

FedAvg weights every update by the client's sample count; strategy weights
(staleness decay, gamma smoothing, tier size) multiply on top (paper §4,
footnote 3). Aggregation operates on *updates* (deltas from the current
global model), which is equivalent to weight averaging under equal bases and
is what makes stale-update conversion composable.
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp


def fedavg(updates: List[Any], weights: Optional[Sequence[float]] = None) -> Any:
    """Weighted mean of update pytrees."""
    assert updates, "no updates to aggregate"
    if weights is None:
        weights = [1.0] * len(updates)
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)

    def combine(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        return jnp.tensordot(w, stacked, axes=1).astype(leaves[0].dtype)

    return jax.tree_util.tree_map(combine, *updates)


def apply_update(global_params: Any, update: Any, server_lr: float = 1.0) -> Any:
    return jax.tree_util.tree_map(
        lambda p, u: (p + server_lr * u.astype(jnp.float32)).astype(p.dtype),
        global_params, update)


def fedavg_stacked(stacked_updates: Any,
                   weights: Optional[Sequence[float]] = None) -> Any:
    """``fedavg`` over a stacked cohort: one weighted reduction per leaf.

    ``stacked_updates`` carries the cohort on axis 0 — the fused aggregation
    round hands the whole (fresh ++ stale) update stack here instead of a
    Python list of per-client pytrees. The contraction is the *same*
    ``tensordot`` ``fedavg`` performs after stacking its list (one weighted
    segment reduction per leaf, shardable along the cohort axis under the
    (pod, data) mesh specs), so a stack whose rows equal ``updates[i]``
    aggregates bit-for-bit identically — the fused==loop equivalence anchor.
    """
    B = jax.tree_util.tree_leaves(stacked_updates)[0].shape[0]
    assert B > 0, "no updates to aggregate"
    if weights is None:
        weights = [1.0] * B
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)

    def combine(leaf):
        return jnp.tensordot(w, leaf.astype(jnp.float32),
                             axes=1).astype(leaf.dtype)

    return jax.tree_util.tree_map(combine, stacked_updates)
