"""Computationally-efficient GI via top-K sparsification + warm start (§3.3).

* ``topk_mask``: binary mask selecting the top-K magnitude coordinates of the
  stale *update* (w_i^{t-tau} - w_global^{t-tau}); only these coordinates
  enter the GI disparity objective. Paper: keeping the top 5% cuts ~80% of GI
  compute with a tiny error increase (Table 4) and is also the privacy
  mechanism (§3.4, Table 6/7). Above ``KERNEL_MIN_SIZE`` coordinates the mask
  is produced by the ``repro.kernels.sparsify_mask`` Pallas kernel (binary
  output mode); tiny vectors use the pure-jnp path.
* ``topk_mask_batch``: the round-level form — stacks every stale client's
  update vector and emits all masks in one batched kernel launch, matching
  the vmapped GI engine's (B, n) mask input.
* ``WarmStartCache``: reuse the previous round's D_rec as the next round's
  initialization when client data is (partially) fixed — another ~43%
  iteration reduction (Table 5). Storage is a pair of *stacked* host buffers
  (one row per client slot) so a round's warm starts gather into the
  (B, n_rec, ...) tensors the batched engine consumes without per-client
  stacking.

The mask is a *static-size* flat boolean vector (K fixed per round), which on
TPU keeps all GI shapes static.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.disparity import (tree_pad_leading, tree_to_vector,
                                  tree_to_vector_batch)
from repro.kernels.sparsify_mask import (topk_binary_mask,
                                         topk_binary_mask_batch,
                                         topk_binary_mask_batch_sharded)
from repro.launch.mesh import mesh_shard_count
from repro.launch.sharding import cohort_sharding, shard_bucket

# below this many coordinates the top_k + compare is cheaper than a kernel
# launch (and the Pallas interpreter), so stay in pure jnp
KERNEL_MIN_SIZE = 4096


def _kernel_default(n: int) -> bool:
    # the TPU kernel lowers on tpu and runs interpreted on cpu; other
    # backends (gpu) keep the backend-agnostic pure-jnp path
    return n >= KERNEL_MIN_SIZE and jax.default_backend() in ("cpu", "tpu")


def topk_mask(update: Any, keep_fraction: float,
              use_kernel: Optional[bool] = None) -> jax.Array:
    """Flat boolean mask of the top ``keep_fraction`` |update| coordinates.

    ``keep_fraction=1.0`` (sparsification rate 0%) returns all-ones.
    """
    vec = jnp.abs(tree_to_vector(update))
    n = vec.shape[0]
    if keep_fraction >= 1.0:
        return jnp.ones((n,), bool)
    if use_kernel is None:
        use_kernel = _kernel_default(n)
    if use_kernel:
        return topk_binary_mask(vec, float(keep_fraction))
    k = max(1, int(round(n * keep_fraction)))
    # threshold = k-th largest magnitude
    thresh = jax.lax.top_k(vec, k)[0][-1]
    return vec >= thresh


def topk_mask_batch(updates, keep_fraction: float,
                    use_kernel: Optional[bool] = None,
                    mesh=None) -> jax.Array:
    """(B, n) boolean masks for a batch of update pytrees in one launch.

    ``updates`` may be a list of pytrees or one leading-axis-stacked pytree
    (``disparity.tree_to_vector_batch`` owns that contract). With a
    multi-shard ``mesh`` the rows are padded to the cohort shard bucket and
    masked per shard (kernel grid per shard, jnp fallback on CPU shards);
    thresholds are row-local so the sharded masks equal the unsharded ones
    exactly. The returned array is always unpadded (B, n).
    """
    vecs = tree_to_vector_batch(updates)
    B, n = vecs.shape
    if keep_fraction >= 1.0:
        return jnp.ones((B, n), bool)
    n_shards = mesh_shard_count(mesh)
    if mesh is not None and n_shards > 1:
        Bp = shard_bucket(B, n_shards)
        vecs = tree_pad_leading(vecs, Bp - B)   # row-0 pads, masked out after
        return topk_binary_mask_batch_sharded(
            vecs, float(keep_fraction), mesh)[:B]
    if use_kernel is None:
        use_kernel = _kernel_default(n)
    if use_kernel:
        return topk_binary_mask_batch(jnp.abs(vecs), float(keep_fraction))
    k = max(1, int(round(n * keep_fraction)))
    mags = jnp.abs(vecs)
    thresh = jax.lax.top_k(mags, k)[0][:, -1:]
    return mags >= thresh


def mask_stats(mask: jax.Array) -> Dict[str, float]:
    return {"kept": int(jnp.sum(mask)), "total": int(mask.shape[0]),
            "fraction": float(jnp.mean(mask.astype(jnp.float32)))}


class WarmStartCache:
    """Per-client D_rec cache backed by stacked host buffers.

    Each client owns one row of a pair of ``(capacity, n_rec, ...)`` numpy
    buffers; ``gather``/``put_stacked`` move a whole round's batch in one
    slice so the batched GI engine never loops over clients on the host.
    D_rec tensors are small, so host residency is cheap.
    """

    def __init__(self):
        self._x: Optional[np.ndarray] = None
        self._y: Optional[np.ndarray] = None
        self._slot: Dict[int, int] = {}
        self._free: List[int] = []

    def __len__(self) -> int:
        return len(self._slot)

    def __contains__(self, client_id: int) -> bool:
        return client_id in self._slot

    # ------------------------------------------------------------------ #
    def _ensure_capacity(self, x: np.ndarray, y: np.ndarray) -> None:
        if self._x is None:
            cap = 4
            self._x = np.zeros((cap, *x.shape), x.dtype)
            self._y = np.zeros((cap, *y.shape), y.dtype)
            self._free = list(range(cap - 1, -1, -1))
        elif not self._free:
            cap = self._x.shape[0]
            self._x = np.concatenate([self._x, np.zeros_like(self._x)])
            self._y = np.concatenate([self._y, np.zeros_like(self._y)])
            self._free = list(range(2 * cap - 1, cap - 1, -1))

    def put(self, client_id: int, x: jax.Array, y: jax.Array) -> None:
        x = np.asarray(x)
        y = np.asarray(y)
        if client_id not in self._slot:
            self._ensure_capacity(x, y)
            self._slot[client_id] = self._free.pop()
        s = self._slot[client_id]
        self._x[s] = x
        self._y[s] = y

    def get(self, client_id: int) -> Optional[Tuple[jax.Array, jax.Array]]:
        s = self._slot.get(client_id)
        if s is None:
            return None
        return jnp.asarray(self._x[s]), jnp.asarray(self._y[s])

    def drop(self, client_id: int) -> None:
        s = self._slot.pop(client_id, None)
        if s is not None:
            self._free.append(s)

    # ------------------------------------------------------------------ #
    def gather(self, client_ids: Sequence[int]
               ) -> Tuple[Optional[jax.Array], Optional[jax.Array], np.ndarray]:
        """Stacked warm starts for a round's batch.

        Returns ``(xs (B, n_rec, ...), ys (B, n_rec, C), warm (B,) bool)``;
        cold clients get zero rows (callers blend in a fresh init where
        ``warm`` is False). ``(None, None, warm)`` if nothing is cached yet.
        """
        warm = np.array([i in self._slot for i in client_ids], bool)
        if self._x is None or not warm.any():
            return None, None, warm
        rows = np.array([self._slot.get(i, 0) for i in client_ids], np.int64)
        xs = self._x[rows].copy()
        ys = self._y[rows].copy()
        xs[~warm] = 0
        ys[~warm] = 0
        return jnp.asarray(xs), jnp.asarray(ys), warm

    def gather_sharded(self, client_ids: Sequence[int], mesh,
                       pad_to: Optional[int] = None
                       ) -> Tuple[Optional[jax.Array], Optional[jax.Array],
                                  np.ndarray]:
        """``gather`` placed onto a cohort mesh.

        Because storage is host-resident numpy keyed by client id, warm
        starts survive arbitrary *resharding* between rounds: a batch put
        from a 4-shard mesh gathers identically onto a 2-shard (or fresh)
        mesh the next round. ``pad_to`` zero-pads rows up to the cohort
        shard bucket so the placed arrays divide the mesh evenly; padded
        ``warm`` entries are False. Returns unsharded host values when
        ``mesh`` is a single shard (bit-for-bit the plain ``gather``).
        """
        xs, ys, warm = self.gather(client_ids)
        n = len(client_ids) if pad_to is None else int(pad_to)
        if n > len(warm):
            warm = np.concatenate([warm, np.zeros(n - len(warm), bool)])
        if xs is None or mesh is None or mesh_shard_count(mesh) <= 1:
            return xs, ys, warm
        pad = n - xs.shape[0]
        if pad > 0:
            xs = jnp.concatenate(
                [xs, jnp.zeros((pad, *xs.shape[1:]), xs.dtype)])
            ys = jnp.concatenate(
                [ys, jnp.zeros((pad, *ys.shape[1:]), ys.dtype)])
        sh = cohort_sharding(mesh)
        return jax.device_put(xs, sh), jax.device_put(ys, sh), warm

    def put_stacked(self, client_ids: Sequence[int],
                    xs: jax.Array, ys: jax.Array) -> None:
        """Store a round's recovered D_rec batch: row b -> client_ids[b]
        (device layout is irrelevant: rows land in the host buffers, so a
        batch recovered on one mesh warm-starts any future mesh)."""
        xs = np.asarray(xs)
        ys = np.asarray(ys)
        for b, i in enumerate(client_ids):
            self.put(int(i), xs[b], ys[b])
