"""Computationally-efficient GI via top-K sparsification + warm start (§3.3).

* ``topk_mask``: binary mask selecting the top-K magnitude coordinates of the
  stale *update* (w_i^{t-tau} - w_global^{t-tau}); only these coordinates
  enter the GI disparity objective. Paper: keeping the top 5% cuts ~80% of GI
  compute with a tiny error increase (Table 4) and is also the privacy
  mechanism (§3.4, Table 6/7).
* ``WarmStartCache``: reuse the previous round's D_rec as the next round's
  initialization when client data is (partially) fixed — another ~43%
  iteration reduction (Table 5).

The mask is a *static-size* flat boolean vector (K fixed per round), which on
TPU keeps all GI shapes static; the fused mask application for large models
is the ``repro.kernels.sparsify_mask`` Pallas kernel.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.disparity import tree_to_vector


def topk_mask(update: Any, keep_fraction: float) -> jax.Array:
    """Flat boolean mask of the top ``keep_fraction`` |update| coordinates.

    ``keep_fraction=1.0`` (sparsification rate 0%) returns all-ones.
    """
    vec = jnp.abs(tree_to_vector(update))
    n = vec.shape[0]
    if keep_fraction >= 1.0:
        return jnp.ones((n,), bool)
    k = max(1, int(round(n * keep_fraction)))
    # threshold = k-th largest magnitude
    thresh = jax.lax.top_k(vec, k)[0][-1]
    return vec >= thresh


def mask_stats(mask: jax.Array) -> Dict[str, float]:
    return {"kept": int(jnp.sum(mask)), "total": int(mask.shape[0]),
            "fraction": float(jnp.mean(mask.astype(jnp.float32)))}


class WarmStartCache:
    """Per-client D_rec cache (host-side; D_rec tensors are small)."""

    def __init__(self):
        self._store: Dict[int, Tuple[jax.Array, jax.Array]] = {}

    def get(self, client_id: int) -> Optional[Tuple[jax.Array, jax.Array]]:
        return self._store.get(client_id)

    def put(self, client_id: int, x: jax.Array, y: jax.Array) -> None:
        self._store[client_id] = (x, y)

    def drop(self, client_id: int) -> None:
        self._store.pop(client_id, None)

    def __contains__(self, client_id: int) -> bool:
        return client_id in self._store
