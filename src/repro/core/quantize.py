"""Quantized upload wire format with on-device error feedback (§ wire).

Clients upload model *deltas*. At paper scale (PR 9's 0.5B-param bench)
the fp32 wire format and the stacked fp32 cohort trees it turns into are
the dominant byte cost of the whole stale path — ROADMAP item 3. This
module makes compression a first-class axis of that path:

* :class:`QuantConfig` — the knob set: ``bits`` (32 = exact identity,
  8/4 = int wire formats), ``tile`` (coordinates per scale), stochastic
  vs nearest rounding, error feedback on/off. ``bits=32`` short-circuits
  every call site, so the default configuration is *bit-for-bit* the
  pre-quantization repo (trajectory and digest tests pin this).
* :func:`quantize_delta_stack` — host-side quantization of a stacked
  cohort delta tree, exactly what the (simulated) clients would put on
  the wire: per-leaf, per-``tile`` max-abs scales, stochastic rounding
  driven by the same counter-based Philox construction as
  ``sim.rand.job_uniforms`` (one stream per (client, round) upload —
  deterministic and replayable no matter how the server batches
  cohorts), and per-client **error-feedback accumulators**
  (:class:`ErrorFeedback`): the residual ``delta - deq(quant(delta))``
  is carried on-device and added to the next round's delta, so the
  *running sum* of dequantized uploads tracks the true sum to within
  one quantization step regardless of bitwidth.
* :class:`QuantizedTree` — the registered-pytree payload (int8 leaves +
  f32 per-tile scales) the server consumes *without* dequantizing:
  ``kernels.fused_disparity`` has dequant-fused reduction terms, so the
  GI while_loop's disparity never materializes an fp32 cohort tree.
* ``quantize_leaf_jnp`` / ``dequant_flat`` — jit-friendly device-side
  forms (deterministic nearest rounding) used by the ``VersionStore``'s
  quantized ring rows and by the dequant-fused jnp fallbacks.

int4 payloads are held as int8 on device (one nibble per byte — the HBM
win over fp32 is already 4x) but counted *packed* on the wire
(``bits/8`` bytes per coordinate), which is what the service's
bytes-on-wire accounting reports.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["QuantConfig", "QuantizedTree", "ErrorFeedback",
           "quantize_delta_stack", "quantize_flat", "dequantize_flat_np",
           "quantize_leaf_jnp", "dequant_flat", "quant_uniforms",
           "upload_stream", "leaf_payload_bytes", "tree_payload_bytes"]

# counter bits reserved per upload stream: each stream owns 2^64 Philox
# counter blocks, the same construction as sim.rand (counter-based, so a
# stream's values never depend on what other streams drew)
_STREAM_SHIFT = 64

_VALID_BITS = (4, 8, 32)


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Wire-format knobs. ``bits=32`` (the default) is an exact identity:
    every call site guards on ``enabled`` and the fp32 path is untouched."""
    bits: int = 32          # 32 = no quantization | 8 | 4
    # coordinates per scale. 128 (the default) makes per-tile scales map
    # 1:1 onto the Pallas kernels' 128-lane rows — other tiles are legal
    # but take the jnp fallback in the dequant-fused terms.
    tile: int = 128
    stochastic: bool = True     # Philox stochastic rounding (unbiased)
    error_feedback: bool = True  # carry the per-client residual forward
    seed: int = 0               # Philox key for the rounding streams
    # quantize the VersionStore's device ring rows too (~4x smaller
    # resident history at int8; deterministic nearest rounding). 32 keeps
    # the store exact — the default, since history rows feed base-param
    # gathers.
    store_bits: int = 32

    def __post_init__(self):
        if self.bits not in _VALID_BITS:
            raise ValueError(f"bits must be one of {_VALID_BITS}, "
                             f"got {self.bits}")
        if self.store_bits not in _VALID_BITS:
            raise ValueError(f"store_bits must be one of {_VALID_BITS}, "
                             f"got {self.store_bits}")
        if self.tile < 1:
            raise ValueError(f"tile must be >= 1, got {self.tile}")

    @property
    def enabled(self) -> bool:
        return self.bits < 32

    @property
    def qmax(self) -> int:
        """Largest magnitude the payload may carry (symmetric range)."""
        return (1 << (self.bits - 1)) - 1


def _n_tiles(n: int, tile: int) -> int:
    return -(-int(n) // int(tile))


def _qmax(bits: int) -> int:
    return (1 << (int(bits) - 1)) - 1


def leaf_payload_bytes(n: int, cfg: QuantConfig) -> int:
    """Wire bytes for one flat leaf of ``n`` coordinates: packed payload
    (``bits/8`` per coordinate, nibbles packed at int4) plus one f32
    scale per tile. fp32 leaves are just ``4n``."""
    if not cfg.enabled:
        return 4 * int(n)
    return (int(n) * cfg.bits + 7) // 8 + 4 * _n_tiles(n, cfg.tile)


def tree_payload_bytes(tree: Any, cfg: QuantConfig) -> int:
    """Wire bytes for one upload of a (template) pytree."""
    return sum(leaf_payload_bytes(int(np.prod(jnp.shape(l)) or 1), cfg)
               for l in jax.tree_util.tree_leaves(tree))


# --------------------------------------------------------------------------- #
# Philox rounding streams (the sim.rand construction, own counter layout)
# --------------------------------------------------------------------------- #


def upload_stream(client: int, version: int) -> int:
    """Stream id of one upload: unique per (client, round-consumed).

    Purely a function of the upload's identity — not of cohort batching,
    wave slicing or aggregation order — so a replay quantizes every
    upload bit-for-bit identically (the same property job ids give
    ``sim.rand.job_uniforms``)."""
    return (int(client) << 32) | (int(version) & 0xFFFFFFFF)


def quant_uniforms(seed: int, stream: int, n: int) -> np.ndarray:
    """``(n,)`` float64 uniforms for one upload's stochastic rounding.

    Counter-based Philox keyed on ``seed`` with the counter pinned to the
    stream id — no sequential state, so draws are independent of every
    other upload and bitwise reproducible."""
    bg = np.random.Philox(key=int(seed),
                          counter=int(stream) << _STREAM_SHIFT)
    return np.random.Generator(bg).random(int(n))


# --------------------------------------------------------------------------- #
# Host (client-side) quantizer — numpy, the wire semantics
# --------------------------------------------------------------------------- #


def quantize_flat(vec: np.ndarray, bits: int, tile: int,
                  uniforms: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Quantize one flat f32 vector: per-tile max-abs scales, stochastic
    rounding when ``uniforms`` is given (``floor(x/s + u)`` — unbiased),
    round-to-nearest-even otherwise. Returns ``(q int8 (n,), s f32 (t,))``;
    all-zero tiles get scale 0 (and payload 0)."""
    vec = np.asarray(vec, np.float32).reshape(-1)
    n = vec.shape[0]
    t = _n_tiles(n, tile)
    pad = t * tile - n
    xp = np.pad(vec, (0, pad)) if pad else vec
    xt = xp.reshape(t, tile)
    qmax = float(_qmax(bits))
    s = (np.abs(xt).max(axis=1) / qmax).astype(np.float32)
    safe = np.where(s > 0, s, 1.0).astype(np.float32)
    y = np.where(s[:, None] > 0, xt / safe[:, None], 0.0)
    if uniforms is None:
        q = np.rint(y)
    else:
        u = np.asarray(uniforms, np.float64).reshape(-1)
        u = np.pad(u, (0, pad)) if pad else u
        q = np.floor(y.astype(np.float64) + u.reshape(t, tile))
    q = np.clip(q, -qmax, qmax).astype(np.int8)
    return q.reshape(-1)[:n], s


def dequantize_flat_np(q: np.ndarray, s: np.ndarray, tile: int) -> np.ndarray:
    """Host inverse of :func:`quantize_flat` (f32)."""
    q = np.asarray(q, np.int8).reshape(-1)
    n = q.shape[0]
    t = s.shape[0]
    pad = t * tile - n
    qf = (np.pad(q, (0, pad)) if pad else q).astype(np.float32)
    x = qf.reshape(t, tile) * np.asarray(s, np.float32)[:, None]
    return x.reshape(-1)[:n]


# --------------------------------------------------------------------------- #
# Device (jnp) forms — jit-friendly, deterministic rounding
# --------------------------------------------------------------------------- #


def quantize_leaf_jnp(x: jax.Array, tile: int, bits: int
                      ) -> Tuple[jax.Array, jax.Array]:
    """jnp twin of :func:`quantize_flat` with nearest rounding (used by the
    VersionStore's quantized ring — no rounding stream on the read/write
    hot path). ``x`` is a flat f32 vector."""
    n = x.shape[-1]
    t = _n_tiles(n, tile)
    pad = t * tile - n
    xp = jnp.pad(x, (0, pad)) if pad else x
    xt = xp.reshape(t, tile)
    qmax = float(_qmax(bits))
    s = (jnp.max(jnp.abs(xt), axis=-1) / qmax).astype(jnp.float32)
    safe = jnp.where(s > 0, s, 1.0)
    y = jnp.where(s[:, None] > 0, xt / safe[:, None], 0.0)
    q = jnp.clip(jnp.round(y), -qmax, qmax).astype(jnp.int8)
    return q.reshape(-1)[:n], s


def dequant_flat(q: jax.Array, s: jax.Array, tile: int) -> jax.Array:
    """``q * s`` over tiles, elementwise jnp (fuses into whatever reduction
    consumes it under jit — no fp32 buffer unless the consumer keeps one).
    Handles arbitrary leading batch dims (``(..., n)`` with ``(..., t)``
    scales)."""
    n = q.shape[-1]
    t = s.shape[-1]
    pad = t * tile - n
    qf = q.astype(jnp.float32)
    if pad:
        qf = jnp.pad(qf, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    x = qf.reshape(q.shape[:-1] + (t, tile)) * s[..., None]
    x = x.reshape(q.shape[:-1] + (t * tile,))
    return x[..., :n] if pad else x


# --------------------------------------------------------------------------- #
# The wire payload as a pytree
# --------------------------------------------------------------------------- #


@jax.tree_util.register_pytree_node_class
class QuantizedTree:
    """A quantized pytree payload: per-leaf flat int8 arrays (``(n,)``, or
    ``(B, n)`` stacked) plus per-tile f32 scales (``(t,)`` / ``(B, t)``).

    Registered as a pytree whose children are the payload and scale
    arrays, so it flows through ``vmap``, ``tree_index_select``,
    ``tree_pad_leading`` and the GI lane machinery exactly like an fp32
    target tree — the dequant-fused disparity terms consume it directly.
    ``bits``/``tile`` and the original tree structure ride in the aux data
    (static under tracing)."""

    def __init__(self, q: Sequence[jax.Array], s: Sequence[jax.Array],
                 bits: int, tile: int, treedef, shapes):
        self.q = list(q)
        self.s = list(s)
        self.bits = bits
        self.tile = tile
        self.treedef = treedef
        self.shapes = tuple(tuple(sh) for sh in shapes)

    def tree_flatten(self):
        return ((tuple(self.q), tuple(self.s)),
                (self.bits, self.tile, self.treedef, self.shapes))

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, s = children
        return cls(q, s, *aux)

    # -- consumption ---------------------------------------------------- #
    def dequant_leaves(self) -> List[jax.Array]:
        """Flat f32 leaves (elementwise; fuses into the consumer)."""
        return [dequant_flat(q, s, self.tile)
                for q, s in zip(self.q, self.s)]

    def to_tree(self) -> Any:
        """Materialize the fp32 pytree (leading batch dims preserved) —
        the dequant-then-fp32 path the fused terms exist to avoid; used
        by references, tests and the GSPMD model-axis fallback."""
        leaves = [d.reshape(d.shape[:-1] + sh)
                  for d, sh in zip(self.dequant_leaves(), self.shapes)]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    @property
    def wire_bytes_per_row(self) -> int:
        """Wire bytes of ONE upload (one batch row): packed payload +
        scales, per leaf."""
        cfg = QuantConfig(bits=self.bits, tile=self.tile)
        return sum(leaf_payload_bytes(q.shape[-1], cfg) for q in self.q)


# --------------------------------------------------------------------------- #
# Error feedback
# --------------------------------------------------------------------------- #


class ErrorFeedback:
    """Per-client quantization residual accumulators (host-resident, like
    ``sparsify.WarmStartCache``): ``e' = (delta + e) - deq(quant(delta + e))``.

    The residual is bounded by one quantization step per coordinate, so
    the running mean of a client's dequantized uploads converges to the
    mean of its true deltas at O(1/T) — the property the drain tests pin.
    """

    def __init__(self):
        self._resid: Dict[int, np.ndarray] = {}

    def __len__(self) -> int:
        return len(self._resid)

    def residual(self, client: int) -> Optional[np.ndarray]:
        return self._resid.get(int(client))

    def update(self, client: int, resid: np.ndarray) -> None:
        self._resid[int(client)] = np.asarray(resid, np.float32)

    def residual_norm(self, client: int) -> float:
        r = self.residual(client)
        return 0.0 if r is None else float(np.abs(r).max())

    def reset(self) -> None:
        self._resid.clear()


# --------------------------------------------------------------------------- #
# The upload path: stacked cohort deltas -> wire payload + what the
# server reconstructs
# --------------------------------------------------------------------------- #


def quantize_delta_stack(delta_stack: Any, clients: Sequence[int],
                         version: int, cfg: QuantConfig,
                         ef: Optional[ErrorFeedback] = None
                         ) -> Tuple[QuantizedTree, Any, int]:
    """Quantize a stacked ``(B, ...)`` cohort delta tree as B uploads.

    Row ``b`` is client ``clients[b]``'s upload consumed at round
    ``version``: its error-feedback residual (when ``ef`` is given and
    ``cfg.error_feedback``) is folded in, the (client, version) Philox
    stream drives stochastic rounding, and the new residual is written
    back. Returns ``(payload, dequantized delta tree, wire bytes)`` —
    the dequantized tree is what the server's fp32 stages see; the
    payload is what the GI target consumes dequant-fused.

    Requires ``cfg.enabled`` — callers guard with ``bits < 32`` so the
    identity path never converts to host.
    """
    if not cfg.enabled:
        raise ValueError("quantize_delta_stack requires bits < 32 "
                         "(bits=32 is the identity — guard at the caller)")
    leaves, treedef = jax.tree_util.tree_flatten(delta_stack)
    B = leaves[0].shape[0]
    if len(clients) != B:
        raise ValueError(f"{len(clients)} clients for a {B}-row stack")
    shapes = [tuple(l.shape[1:]) for l in leaves]
    host = [np.asarray(l, np.float32).reshape(B, -1) for l in leaves]
    sizes = [h.shape[1] for h in host]
    n_total = int(sum(sizes))
    use_ef = cfg.error_feedback and ef is not None

    q_out = [np.zeros((B, n), np.int8) for n in sizes]
    s_out = [np.zeros((B, _n_tiles(n, cfg.tile)), np.float32)
             for n in sizes]
    deq_out = [np.zeros((B, n), np.float32) for n in sizes]

    for b in range(B):
        c = int(clients[b])
        vec = np.concatenate([h[b] for h in host])
        if use_ef:
            r = ef.residual(c)
            if r is not None:
                vec = vec + r
        u = (quant_uniforms(cfg.seed, upload_stream(c, version), n_total)
             if cfg.stochastic else None)
        deq_vec = np.empty((n_total,), np.float32)
        off = 0
        for li, n in enumerate(sizes):
            useg = None if u is None else u[off:off + n]
            q, s = quantize_flat(vec[off:off + n], cfg.bits, cfg.tile, useg)
            q_out[li][b] = q
            s_out[li][b] = s
            d = dequantize_flat_np(q, s, cfg.tile)
            deq_out[li][b] = d
            deq_vec[off:off + n] = d
            off += n
        if use_ef:
            ef.update(c, vec - deq_vec)

    qt = QuantizedTree([jnp.asarray(q) for q in q_out],
                       [jnp.asarray(s) for s in s_out],
                       cfg.bits, cfg.tile, treedef, shapes)
    deq_tree = jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(d.reshape((B,) + sh))
                  for d, sh in zip(deq_out, shapes)])
    return qt, deq_tree, B * qt.wire_bytes_per_row
