"""Privacy-preserving uniqueness detection (paper §3.4, Eq. 7-8).

GI should only run on stale updates that carry *unique* knowledge. Rather
than inspecting labels, the server compares update directions: a stale
client's data is unique iff its cosine distance to every unstale update
exceeds the adaptive threshold — the mean pairwise cosine distance among the
unstale updates themselves (the mean adapts to the distance scale drifting
during training, paper Fig. 9).

``is_unique_batch`` is the round-level form: all stale deliveries are checked
against the fast cohort with one (B, M) distance matrix instead of B
separate passes over the unstale set. Both arguments accept either a list
of per-client pytrees (the historic loop-path form) or ONE pytree stacked
on a leading cohort axis (the fused aggregation round's form — rows are
flattened with one reshape per leaf, no per-client tree traffic, and are
bit-identical to the per-client ``tree_to_vector`` rows).
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.core.disparity import tree_to_vector_batch


def _pairwise_cosine_distances(vectors: np.ndarray) -> np.ndarray:
    norms = np.linalg.norm(vectors, axis=1, keepdims=True)
    normed = vectors / np.maximum(norms, 1e-12)
    sim = normed @ normed.T
    return 1.0 - sim


def _cohort_size(updates) -> int:
    """Number of clients in a list-of-pytrees or stacked-pytree cohort."""
    if isinstance(updates, (list, tuple)):
        return len(updates)
    return jax.tree_util.tree_leaves(updates)[0].shape[0]


def _rows(updates) -> np.ndarray:
    """Host copy of ``disparity.tree_to_vector_batch`` rows (the detection
    math below is pure numpy)."""
    return np.asarray(tree_to_vector_batch(updates))


def _normalized_rows(updates) -> np.ndarray:
    vecs = _rows(updates)
    norms = np.linalg.norm(vecs, axis=1, keepdims=True)
    return vecs / np.maximum(norms, 1e-12)


def uniqueness_threshold(unstale_updates) -> float:
    """Mean pairwise cosine distance among unstale updates (Eq. 8)."""
    if _cohort_size(unstale_updates) < 2:
        return 0.0
    d = _pairwise_cosine_distances(_rows(unstale_updates))
    n = d.shape[0]
    off = d[~np.eye(n, dtype=bool)]
    return float(off.mean())


def is_unique_batch(stale_updates,
                    unstale_updates,
                    threshold: float | None = None
                    ) -> Tuple[np.ndarray, Dict[str, Any]]:
    """Vectorized Eq. 7-8 over a round's whole stale cohort.

    Returns ``(unique (B,) bool, info)`` where ``info['min_dist']`` is the
    per-client min cosine distance to the unstale set. Either cohort may be
    a list of pytrees or one leading-axis-stacked pytree.
    """
    B = _cohort_size(stale_updates)
    if unstale_updates is None or _cohort_size(unstale_updates) == 0:
        return (np.ones(B, bool),
                {"min_dist": np.full(B, np.inf), "threshold": 0.0})
    thr = (uniqueness_threshold(unstale_updates)
           if threshold is None else threshold)
    S = _normalized_rows(stale_updates)          # (B, n)
    U = _normalized_rows(unstale_updates)        # (M, n)
    dists = 1.0 - S @ U.T                        # (B, M)
    min_dist = dists.min(axis=1)
    return min_dist > thr, {"min_dist": min_dist, "threshold": thr}


def is_unique(stale_update: Any, unstale_updates: List[Any],
              threshold: float | None = None) -> Tuple[bool, Dict[str, float]]:
    """True if the stale update's min cosine distance to unstale updates
    exceeds the threshold (Eq. 7-8). Single-client view of the batch check."""
    unique, info = is_unique_batch([stale_update], unstale_updates, threshold)
    return bool(unique[0]), {"min_dist": float(info["min_dist"][0]),
                             "threshold": float(info["threshold"])}
