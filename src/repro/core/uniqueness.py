"""Privacy-preserving uniqueness detection (paper §3.4, Eq. 7-8).

GI should only run on stale updates that carry *unique* knowledge. Rather
than inspecting labels, the server compares update directions: a stale
client's data is unique iff its cosine distance to every unstale update
exceeds the adaptive threshold — the mean pairwise cosine distance among the
unstale updates themselves (the mean adapts to the distance scale drifting
during training, paper Fig. 9).
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.disparity import tree_to_vector


def _pairwise_cosine_distances(vectors: np.ndarray) -> np.ndarray:
    norms = np.linalg.norm(vectors, axis=1, keepdims=True)
    normed = vectors / np.maximum(norms, 1e-12)
    sim = normed @ normed.T
    return 1.0 - sim


def uniqueness_threshold(unstale_updates: List[Any]) -> float:
    """Mean pairwise cosine distance among unstale updates (Eq. 8)."""
    if len(unstale_updates) < 2:
        return 0.0
    vecs = np.stack([np.asarray(tree_to_vector(u)) for u in unstale_updates])
    d = _pairwise_cosine_distances(vecs)
    n = d.shape[0]
    off = d[~np.eye(n, dtype=bool)]
    return float(off.mean())


def is_unique(stale_update: Any, unstale_updates: List[Any],
              threshold: float | None = None) -> Tuple[bool, Dict[str, float]]:
    """True if the stale update's min cosine distance to unstale updates
    exceeds the threshold (Eq. 7-8)."""
    if not unstale_updates:
        return True, {"min_dist": float("inf"), "threshold": 0.0}
    thr = uniqueness_threshold(unstale_updates) if threshold is None else threshold
    sv = np.asarray(tree_to_vector(stale_update))
    sv = sv / max(np.linalg.norm(sv), 1e-12)
    dists = []
    for u in unstale_updates:
        uv = np.asarray(tree_to_vector(u))
        uv = uv / max(np.linalg.norm(uv), 1e-12)
        dists.append(1.0 - float(sv @ uv))
    min_dist = float(min(dists))
    return min_dist > thr, {"min_dist": min_dist, "threshold": thr}
