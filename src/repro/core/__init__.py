# The paper's primary contribution: server-side conversion of stale FL model
# updates into unstale ones via gradient inversion (Wang & Gao, AAAI 2025).
from repro.core.disparity import cosine_distance, l1_disparity, tree_to_vector  # noqa: F401
from repro.core.client import LocalProgram, make_local_update  # noqa: F401
from repro.core.gradient_inversion import GIConfig, GradientInverter  # noqa: F401
from repro.core.server import FLConfig, Server  # noqa: F401
