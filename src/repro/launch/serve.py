"""Deprecated alias for :mod:`repro.launch.decode` (one-release shim).

``repro.launch.serve`` used to be the LLM prefill+decode driver; that
collided with the natural name for the streaming FL aggregation service
(``repro.service``), so the launcher now lives at ``repro.launch.decode``.
This shim keeps ``python -m repro.launch.serve`` and imports working for
one release, with a DeprecationWarning.
"""

from __future__ import annotations

import warnings

from repro.launch.decode import main

warnings.warn(
    "repro.launch.serve is deprecated; use repro.launch.decode "
    "(the FL streaming service lives at repro.service)",
    DeprecationWarning, stacklevel=2)

__all__ = ["main"]

if __name__ == "__main__":
    main()
