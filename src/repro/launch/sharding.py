"""Sharding rules: PartitionSpec trees for params, batches and caches.

Axis convention (launch/mesh.py): ``data`` (16), ``model`` (16), and for the
multi-pod mesh an outer ``pod`` (2). Modes:

* ``tp``      — tensor parallelism only: weights sharded on ``model``
                (attention heads / FFN hidden / experts / vocab),
                batch on (pod, data). Right for <= ~3B-param models.
* ``fsdp_tp`` — additionally shards the weights' other dim on ``data``
                (FSDP/ZeRO-style) so >= 15B-param models and their optimizer
                state fit per-chip HBM; GSPMD inserts the FSDP all-gathers.
                Training only — per-layer weight re-gathers are the FSDP
                deal; amortized over the whole fwd+bwd of a big batch.
* ``tp2``     — inference mode for big models: attention stays TP(model),
                FFN / MoE hidden dims are sharded over BOTH axes (256-way
                TP) and embeddings over (model x data). No weight
                all-gathers at all — activations (small at inference) move
                instead.

Every rule guards divisibility: a dim is only sharded if the mesh axis size
divides it (e.g. whisper's vocab 51865 and hymba's 32001 fall back to
d_model sharding). Optimizer state inherits the param specs.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import InputShape, ModelConfig

Params = Dict[str, Any]


def _axsize(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= _axsize(mesh, a)
        return n
    # Mesh.shape / AbstractMesh.shape: mapping axis name -> size
    return dict(mesh.shape).get(name, 1)


def _guard(dim: int, axis, mesh: Mesh):
    """axis if it divides dim else None."""
    if axis is None:
        return None
    return axis if dim % _axsize(mesh, axis) == 0 else None


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# --------------------------------------------------------------------------- #
# Server cohort specs: the stale-cohort batch axis shards on (pod, data)
# --------------------------------------------------------------------------- #


def cohort_spec(mesh: Mesh) -> P:
    """Spec sharding a leading client/batch axis over every data axis; the
    remaining dims (params, D_rec, mask coordinates, ...) replicate. This is
    the one layout rule of the sharded server hot path — every stacked
    cohort tensor (w_base/w_stale stacks, PRNG keys, masks, warm-start
    buffers, D_rec) uses it (docs/sharded_server.md)."""
    return P(data_axes(mesh))


def replicated_spec() -> P:
    """Spec for cohort-invariant operands (the current global model)."""
    return P()


def multi_version_specs(mesh: Mesh) -> Tuple[P, P, P, P]:
    """in_specs for the multi-version cohort LocalUpdate
    (``make_cohort_update(per_client_params=True)``): base params arrive
    stacked per lane — gathered from the ``VersionStore`` ring — so they
    shard on the cohort axis exactly like the data shards, masks and keys
    (no replicated operand at all; lanes are fully independent)."""
    ax = cohort_spec(mesh)
    return (ax, ax, ax, ax)


def cohort_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding form of ``cohort_spec`` for host->device placement
    (e.g. ``WarmStartCache.gather_sharded``)."""
    return NamedSharding(mesh, cohort_spec(mesh))


def model_axis_size(mesh: Optional[Mesh]) -> int:
    """Size of the server mesh's optional ``model`` axis (1 when absent).

    ``make_server_mesh(..., model=k)`` appends the axis for transformer-
    backed servers; everything cohort-related (``mesh_shard_count``,
    ``shard_bucket``) deliberately ignores it."""
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get("model", 1))


def fl_param_specs(cfg: ModelConfig, mesh: Mesh, mode: str = "tp",
                   params_shape: Optional[Params] = None) -> Params:
    """``param_specs`` restricted to the ``model`` axis for the FL server.

    The server's cohort (client/batch) axis owns ``(pod, data)``, so weight
    dims may only shard on ``model`` — a stacked per-lane weight tree that
    also used ``data`` would name the axis twice in one PartitionSpec.
    ``fsdp_tp``-style rules therefore degrade gracefully: any ``data``/
    ``pod`` entry a rule produced is replaced by replication, keeping the
    ``model``-axis placements (heads / FFN hidden / vocab) intact.
    """
    specs = param_specs(cfg, mesh, mode, params_shape)

    def keep_model(s: P) -> P:
        return P(*(a if a == "model" else None for a in tuple(s)))

    return jax.tree_util.tree_map(
        keep_model, specs, is_leaf=lambda x: isinstance(x, P))


def stack_specs(spec_tree: Any, mesh: Mesh) -> Any:
    """Specs for per-lane stacked weight pytrees ``(B, ...)``: the leading
    cohort axis shards over ``(pod, data)`` (exactly ``cohort_spec``) and
    the weight dims keep their per-leaf placements — the multi-version
    cohort / batched-GI operand layout on a model-axis mesh."""
    ax = data_axes(mesh)
    return jax.tree_util.tree_map(
        lambda s: P(ax, *tuple(s)), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def constrain(tree: Any, spec_tree: Any, mesh: Mesh) -> Any:
    """``with_sharding_constraint`` over a pytree of PartitionSpecs.

    The model-axis engines keep cohort-only layouts at every jit boundary
    (outputs a caller's eager tree ops touch must never be model-sharded —
    each eager op on a model-sharded array is its own tiny collective
    program) and pin the weight trees to their ``model``-axis placements
    *inside* the jitted body: GSPMD then partitions the heavy LocalUpdate /
    GI math across the model axis and re-gathers at the boundary."""
    return jax.lax.with_sharding_constraint(tree, to_named(spec_tree, mesh))


def shard_bucket(batch: int, n_shards: int) -> int:
    """Padded cohort size: per-shard pow2 buckets x ``n_shards``.

    Each shard keeps its own power-of-two compile bucket (the unsharded
    engine's pow2 buckets, per shard), so recompiles stay O(log B) and every
    shard receives the same local batch. ``n_shards=1`` reduces to the
    unsharded engine's global pow2 bucket — the bit-for-bit anchor.
    """
    if batch <= 0:
        return 0
    local = -(-batch // n_shards)        # ceil
    p = 1
    while p < local:
        p *= 2
    return p * n_shards


def segment_bucket(n_active: int, n_shards: int,
                   max_lanes: int = 0) -> Tuple[int, int]:
    """Lane capacity for one segment of the continuous-batching GI executor.

    Returns ``(n_resident, capacity)``: how many of the ``n_active``
    runnable clients get a lane this segment (the rest wait in the pending
    queue) and the padded per-shard pow2 capacity those lanes compile to.
    ``max_lanes=0`` means unbounded — every active client is resident, and
    the capacity is exactly ``shard_bucket``'s compile bucket, so as lanes
    finish and are compacted out the bucket *shrinks* through the same pow2
    ladder the one-shot engine pads up through.
    """
    n_resident = n_active if max_lanes <= 0 else min(n_active, max_lanes)
    return n_resident, shard_bucket(n_resident, n_shards)


# --------------------------------------------------------------------------- #
# Parameter specs
# --------------------------------------------------------------------------- #


def _leaf_spec(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
               mesh: Mesh, mode: str) -> P:
    """Spec for one parameter; ``path`` like 'layers/attn/wq'."""
    parts = path.split("/")
    name = parts[-1]
    stacked = "layers" in parts          # leading L axis
    off = 1 if stacked else 0
    dims = shape[off:]

    if mode == "fsdp_dp":
        # pure data-parallel compute (no TP): weights are sharded across ALL
        # mesh axes for storage only (ZeRO-3 style); compute re-gathers per
        # layer. Right for small attention-free models where TP's per-layer
        # activation all-reduces dominate (EXPERIMENTS.md §Perf hillclimb 3).
        all_dp = data_axes(mesh) + ("model",)
        for i, dsize in enumerate(dims):
            if _guard(dsize, all_dp, mesh):
                return P(*([None] * off),
                         *[all_dp if j == i else None
                           for j in range(len(dims))])
        return P(*([None] * (off + len(dims))))

    dp = data_axes(mesh) if mode in ("fsdp_tp", "tp2") else None
    # tp2: the data axis rides on the *hidden/feature* dim (2-axis TP, no
    # per-layer weight regathers); fsdp_tp: it rides on the d_model dim.
    tp2 = mode == "tp2"
    f_model = ("model",) + (dp or ()) if tp2 else "model"
    d_data = None if tp2 else dp

    def spec(*entries):
        return P(*([None] * off), *entries)

    g = lambda i, ax: _guard(dims[i], ax, mesh) if i < len(dims) else None

    # ---- embeddings / heads -------------------------------------------- #
    if name == "embed":
        v_ax = _guard(dims[0], "model", mesh)
        if v_ax:
            return P(v_ax, _guard(dims[1], dp, mesh))
        return P(None, _guard(dims[1], "model", mesh))
    if name == "lm_head":
        v_ax = _guard(dims[1], "model", mesh)
        if v_ax:
            return P(_guard(dims[0], dp, mesh), v_ax)
        return P(_guard(dims[0], "model", mesh), None)

    # ---- norms / small vectors ------------------------------------------ #
    if name in ("scale", "bias", "q_norm", "k_norm") or name.startswith("mu_") \
            or name in ("cm_mu_k", "cm_mu_r", "dt_bias", "D", "b_down",
                        "conv_b", "w0", "hb", "b1", "b2", "b3", "fb"):
        return spec(*([None] * len(dims)))

    # ---- MoE ------------------------------------------------------------- #
    if "moe" in parts and "shared" not in parts:
        if name == "router":
            return spec(None, None)
        if name in ("w_gate", "w_up"):      # (E, d, fe)
            if tp2:
                return spec(g(0, "model"), None, g(2, dp))
            return spec(g(0, "model"), g(1, dp), None)
        if name == "w_down":                # (E, fe, d)
            if tp2:
                return spec(g(0, "model"), g(1, dp), None)
            return spec(g(0, "model"), None, g(2, dp))

    # ---- attention ------------------------------------------------------- #
    if name in ("wq", "wk", "wv") and len(dims) == 2:
        return spec(g(0, d_data), g(1, "model"))
    if name == "wo" and len(dims) == 2:
        return spec(g(0, "model"), g(1, d_data))
    if name in ("bq", "bk", "bv"):
        return spec(g(0, "model"))

    # ---- dense / shared-expert MLP --------------------------------------- #
    if name in ("w_gate", "w_up", "cm_wk"):      # (d, f)
        return spec(g(0, d_data), g(1, f_model))
    if name in ("w_down", "cm_wv"):              # (f, d)
        return spec(g(0, f_model), g(1, d_data))
    if name == "b_up":
        return spec(g(0, f_model))
    if name in ("wr", "wg", "cm_wr"):            # rwkv (d, d)
        return spec(g(0, dp), g(1, "model"))
    if name == "w_lora_a":
        return spec(g(0, dp), None)
    if name == "w_lora_b":
        return spec(None, g(1, "model"))
    if name == "u":                              # (H, N)
        return spec(g(0, "model"), None)
    if name == "ln_x":
        return spec(g(0, "model"))

    # ---- mamba (hymba) ---------------------------------------------------- #
    if name == "w_in":                           # (d, 2*di)
        return spec(g(0, dp), g(1, "model"))
    if name == "conv_w":                         # (K, di)
        return spec(None, g(1, "model"))
    if name in ("w_bc", "w_dt1"):                # (di, *)
        return spec(g(0, "model"), None)
    if name == "w_dt2":                          # (r, di)
        return spec(None, g(1, "model"))
    if name == "A_log":                          # (di, N)
        return spec(g(0, "model"), None)
    if name == "w_out":                          # (di, d)
        return spec(g(0, "model"), g(1, dp))

    # default: replicate
    return spec(*([None] * len(dims)))


def _path_str(kp) -> str:
    out = []
    for p in kp:
        out.append(str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)))
    return "/".join(out)


def param_specs(cfg: ModelConfig, mesh: Mesh, mode: str = "tp",
                params_shape: Optional[Params] = None) -> Params:
    """PartitionSpec tree matching init_params(cfg) (built via eval_shape)."""
    if params_shape is None:
        from repro.models.transformer import init_params
        params_shape = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [
        _leaf_spec(_path_str(kp), tuple(leaf.shape), cfg, mesh, mode)
        for kp, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def state_specs(cfg: ModelConfig, mesh: Mesh, mode: str, optimizer,
                params_shape: Optional[Params] = None) -> Params:
    """Specs for the full train state (opt state inherits param specs)."""
    from repro.models.transformer import init_params
    if params_shape is None:
        params_shape = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
    p_specs = param_specs(cfg, mesh, mode, params_shape)
    opt_shape = jax.eval_shape(optimizer.init, params_shape)
    if opt_shape == ():                       # plain sgd
        o_specs: Any = ()
    elif isinstance(opt_shape, dict) and "mu" in opt_shape:  # adam
        o_specs = {"mu": p_specs, "nu": p_specs, "t": P()}
    else:                                     # sgd+momentum mirrors params
        o_specs = p_specs
    return {"params": p_specs, "opt": o_specs, "step": P()}


# --------------------------------------------------------------------------- #
# Batch / cache specs
# --------------------------------------------------------------------------- #


def batch_specs(cfg: ModelConfig, batch_shape: Dict[str, Any], mesh: Mesh,
                axes: Optional[Tuple[str, ...]] = None) -> Dict[str, P]:
    dp = axes if axes is not None else data_axes(mesh)
    out: Dict[str, P] = {}
    for k, v in batch_shape.items():
        B = v.shape[1] if k == "positions" and v.ndim == 3 else v.shape[0]
        b_ax = dp if B % _axsize(mesh, dp) == 0 else None
        if k == "positions" and v.ndim == 3:
            out[k] = P(None, b_ax, None)
        elif v.ndim == 1:
            out[k] = P(b_ax)
        else:
            out[k] = P(b_ax, *([None] * (v.ndim - 1)))
    return out


def cache_specs(cfg: ModelConfig, cache_shape: Params, mesh: Mesh,
                batch: int) -> Params:
    """Decode-cache specs. KV caches shard batch on data and seq on model;
    batch=1 (long_500k) shards seq over every axis instead."""
    dp = data_axes(mesh)
    all_ax = dp + ("model",)

    def leaf(kp, v):
        name = _path_str(kp).split("/")[-1]
        dims = v.shape
        if name in ("k", "v"):              # (L, B, S, KV, hd)
            if batch % _axsize(mesh, dp) == 0:
                return P(None, dp, _guard(dims[2], "model", mesh), None, None)
            return P(None, None, _guard(dims[2], all_ax, mesh) or
                     _guard(dims[2], "model", mesh), None, None)
        if name == "S":                     # rwkv state (L, B, H, N, N)
            b_ax = dp if batch % _axsize(mesh, dp) == 0 else None
            return P(None, b_ax, _guard(dims[2], "model", mesh), None, None)
        if name in ("tm_x", "cm_x"):        # (L, B, d)
            b_ax = dp if batch % _axsize(mesh, dp) == 0 else None
            return P(None, b_ax, _guard(dims[2], "model", mesh))
        if name == "conv":                  # (L, B, K, di)
            b_ax = dp if batch % _axsize(mesh, dp) == 0 else None
            return P(None, b_ax, None, _guard(dims[3], "model", mesh))
        if name == "h":                     # (L, B, di, N)
            b_ax = dp if batch % _axsize(mesh, dp) == 0 else None
            return P(None, b_ax, _guard(dims[2], "model", mesh), None)
        return P(*([None] * len(dims)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf(kp, v) for kp, v in flat])


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_specs, is_leaf=lambda x: isinstance(x, P))
