"""LLM decode launcher: prefill a batch of requests then decode tokens.

Exercises the same prefill / serve_step the decode dry-runs lower, at a
CPU-feasible reduced size (or --full on a real slice).

(Previously ``repro.launch.serve``; renamed so the natural name is free
for the streaming FL aggregation service, ``repro.service``. The old
module path remains one release as a deprecation shim.)
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.launch.specs import concrete_train_batch
from repro.models import transformer as T
from repro.models.model import make_serve_step


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.launch.decode")
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=sorted(ALIASES) + ARCH_IDS)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=not args.full)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(key, cfg)
    B, S = args.batch, args.prompt_len
    max_len = S + args.gen_len

    batch = concrete_train_batch(cfg, B, S, key)
    caches = T.init_cache(cfg, B, max_len)
    cross_kv = None
    if cfg.is_encdec:
        cross_kv = T.precompute_cross_kv(params, cfg, batch["frames"])

    serve_step = jax.jit(make_serve_step(cfg))

    # prefill by stepping the prompt through the cache (teacher forcing)
    tokens = batch.get("tokens")
    if tokens is None:  # vlm stub path: use random token ids for the driver
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    t0 = time.time()
    logits = None
    for i in range(S):
        logits, caches = serve_step(params, caches, tokens[:, i:i + 1],
                                    jnp.array(i, jnp.int32), cross_kv)
    prefill_s = time.time() - t0

    # greedy decode
    out_tokens = []
    cur = jnp.argmax(logits[:, -1], -1)[:, None]
    t0 = time.time()
    for i in range(S, max_len):
        out_tokens.append(cur)
        logits, caches = serve_step(params, caches, cur,
                                    jnp.array(i, jnp.int32), cross_kv)
        cur = jnp.argmax(logits[:, -1], -1)[:, None]
    decode_s = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    assert gen.shape == (B, args.gen_len)
    assert bool(jnp.all(gen >= 0)) and bool(jnp.all(gen < cfg.vocab_size))
    print(f"arch={cfg.name} prefill {S} steps in {prefill_s:.2f}s; "
          f"decoded {args.gen_len} tokens in {decode_s:.2f}s "
          f"({args.gen_len * B / max(decode_s, 1e-9):.1f} tok/s)")
    print("sample token ids:", gen[0, :8].tolist())


if __name__ == "__main__":
    main()
