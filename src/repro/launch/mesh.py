"""Production mesh definition (TPU v5e).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model).

Defined as a FUNCTION so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax import to fabricate the
512 host devices.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax


def make_mesh_compat(shape: Sequence[int], axes: Sequence[str]
                     ) -> jax.sharding.Mesh:
    """jax.make_mesh across jax versions: ``axis_types`` and
    ``jax.sharding.AxisType`` only exist in newer releases."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_abstract_mesh(shape: Sequence[int], names: Sequence[str]):
    """AbstractMesh across jax versions: newer jax takes (shape, names),
    older takes one tuple of (name, size) pairs."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(names))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, shape)))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh for CPU smoke runs of the launcher path."""
    return make_mesh_compat((1, 1), ("data", "model"))


# v5e hardware constants used by the roofline analysis (benchmarks/roofline).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
