"""Production mesh definition (TPU v5e) + the FL server's cohort mesh.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model).

The FL server hot path (batched gradient inversion + aggregation over a
stale cohort) shards its *client/batch* axis over a ``(pod, data)`` mesh —
``make_server_mesh`` builds one from however many devices are available
(on CPU, ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` fabricates
N host devices). A 1-device server mesh is the oracle: it must reproduce
the unsharded batched trajectory bit-for-bit (see docs/sharded_server.md).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax import to fabricate the
512 host devices.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_mesh_compat(shape: Sequence[int], axes: Sequence[str]
                     ) -> jax.sharding.Mesh:
    """jax.make_mesh across jax versions: ``axis_types`` and
    ``jax.sharding.AxisType`` only exist in newer releases."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_abstract_mesh(shape: Sequence[int], names: Sequence[str]):
    """AbstractMesh across jax versions: newer jax takes (shape, names),
    older takes one tuple of (name, size) pairs."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(names))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, shape)))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh for CPU smoke runs of the launcher path."""
    return make_mesh_compat((1, 1), ("data", "model"))


# --------------------------------------------------------------------------- #
# Server cohort mesh (pod, data) — the batch axis the stale cohort shards on
# --------------------------------------------------------------------------- #

SERVER_MESH_AXES = ("pod", "data")


def make_server_mesh(n_devices: Optional[int] = None, pods: int = 1,
                     model: int = 1) -> jax.sharding.Mesh:
    """(pod, data[, model]) mesh over the first ``n_devices`` devices.

    The server shards stale cohorts along ``(pod, data)`` jointly. The
    paper's GI models are tiny and replicate (``model=1``, the default:
    no model axis at all, shape unchanged from the historic mesh).
    ``model>1`` appends a third ``model`` axis for transformer-backed
    servers (``repro.models.fl_bridge``): weights shard along it per
    ``repro.launch.sharding.param_specs`` while the cohort axis keeps
    using ``(pod, data)`` — ``mesh_shard_count`` ignores the model axis,
    so cohort bucket math is untouched. Built with ``jax.sharding.Mesh``
    directly (not ``jax.make_mesh``) so a 1-device mesh can be made on a
    multi-device host — that 1-device mesh is the tier-1 bit-for-bit
    oracle.
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(f"n_devices={n} not in [1, {len(devs)}]")
    if n % (pods * model):
        raise ValueError(
            f"pods={pods} x model={model} does not divide n_devices={n}")
    if model > 1:
        return jax.sharding.Mesh(
            np.asarray(devs[:n]).reshape(pods, n // (pods * model), model),
            SERVER_MESH_AXES + ("model",))
    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape(pods, n // pods), SERVER_MESH_AXES)


def mesh_shard_count(mesh: Optional[jax.sharding.Mesh],
                     axes: Sequence[str] = SERVER_MESH_AXES) -> int:
    """Total shards along ``axes`` (1 for ``mesh=None`` / missing axes)."""
    if mesh is None:
        return 1
    sizes = dict(mesh.shape)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def shard_map_compat(f, mesh: jax.sharding.Mesh, in_specs, out_specs):
    """``shard_map`` across jax versions: new releases expose
    ``jax.shard_map``; 0.4.x has ``jax.experimental.shard_map.shard_map``
    (where replication checking must be disabled explicitly — the server's
    per-shard while_loops have no collectives for it to reason about)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# v5e hardware constants used by the roofline analysis (benchmarks/roofline).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
