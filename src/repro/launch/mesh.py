"""Production mesh definition (TPU v5e).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model).

Defined as a FUNCTION so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax import to fabricate the
512 host devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh for CPU smoke runs of the launcher path."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# v5e hardware constants used by the roofline analysis (benchmarks/roofline).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
