import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, WITHOUT allocating anything.

The two lines above MUST stay the first statements of this module: jax locks
the device count at first init, and the dry-run needs 512 placeholder host
devices to build the 2x16x16 mesh. (Smoke tests and benches import jax
normally and see 1 device — this flag is never set globally.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--probe]

Per run it prints/saves: memory_analysis (proves the program fits v5e HBM),
cost_analysis (FLOPs/bytes for §Roofline), and the per-device collective
inventory parsed from the partitioned HLO.
"""

import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.launch import sharding as shd
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import decode_specs, train_batch_specs
from repro.models import transformer as T
from repro.models.config import INPUT_SHAPES, ModelConfig
from repro.models.model import (make_prefill_logits_last, make_serve_step,
                                make_train_step)
from repro.optim import sgd

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")

# long_500k policy (DESIGN.md §5): SSM/hybrid/SWA archs run natively; pure
# full-attention archs run an explicit sliding-window VARIANT (w=4096).
LONG_SWA_WINDOW = 4096

DEFAULT_FSDP_THRESHOLD = 3e9   # params; larger models use fsdp_tp


def _resolve_cfg(arch: str, shape_name: str,
                 overrides: Optional[Dict[str, Any]] = None
                 ) -> tuple[ModelConfig, str]:
    cfg = get_config(arch)
    variant = "base"
    if shape_name == "long_500k" and cfg.block_type != "rwkv6" \
            and cfg.sliding_window is None:
        cfg = cfg.with_(sliding_window=LONG_SWA_WINDOW)
        variant = f"swa{LONG_SWA_WINDOW}"
    if overrides:
        cfg = cfg.with_(**overrides)
    return cfg, variant


def _param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    return sum(l.size for l in jax.tree_util.tree_leaves(shapes))


def _named(tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              mode: Optional[str] = None, n_micro: Optional[int] = None,
              overrides: Optional[Dict[str, Any]] = None,
              compile_: bool = True) -> Dict[str, Any]:
    """Lower (and compile) one (arch, shape, mesh) combination; returns the
    artifact record with cost/memory/collective analysis."""
    shape = INPUT_SHAPES[shape_name]
    cfg, variant = _resolve_cfg(arch, shape_name, overrides)
    if cfg.moe is not None and cfg.moe.n_experts % 16 == 0:
        cfg = cfg.with_(moe_expert_axis="model")
        if (overrides or {}).get("moe_impl") != "gather":
            # production default (EXPERIMENTS.md §Perf hillclimb 1): expert-
            # parallel shard_map MoE — 10.9x collective / 3.7x memory vs the
            # GSPMD gather path
            cfg = cfg.with_(moe_impl="shard_map")
    mesh = make_production_mesh(multi_pod=multi_pod)
    if cfg.moe is not None and cfg.moe_impl == "shard_map":
        from repro.models.layers import set_moe_mesh
        set_moe_mesh(mesh)
    n_params = _param_count(cfg)
    if mode is None:
        if shape.kind == "train":
            mode = "fsdp_tp" if n_params > DEFAULT_FSDP_THRESHOLD else "tp"
        else:
            # inference: plain TP until the TP-sharded weights alone crowd
            # HBM (llama4-scout: 218 GB bf16 / 16 = 13.6 GB) -> 2-axis TP
            mode = "tp2" if n_params * 2 / 16 > 8e9 else "tp"

    t0 = time.time()
    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = shd.param_specs(cfg, mesh, mode, params_shape)

    if shape.kind == "train":
        cfg_t = cfg if cfg.remat != "none" else cfg.with_(remat="full")
        nm = n_micro or 8
        opt = sgd(1e-2, momentum=0.9)
        dp = shd.data_axes(mesh)
        if mode == "fsdp_dp":
            # pure DP: batch over every axis, one microbatch
            dp = dp + ("model",)
            nm = n_micro or 1
        micro_b = shape.global_batch // nm
        baxes = dp if micro_b % _mesh_size(mesh, dp) == 0 else None
        cfg_t = cfg_t.with_(act_batch_axes=baxes)
        if cfg_t.moe is not None and baxes is not None:
            cfg_t = cfg_t.with_(moe_capacity_axes=baxes)
        if mode == "fsdp_tp":
            # sequence-parallel residual stream: shards the remat-saved
            # (L, B, S, d) carries over the model axis (needed to fit the
            # >=15B models; see EXPERIMENTS.md §Dry-run)
            cfg_t = cfg_t.with_(act_seq_axis="model")
        train_step = make_train_step(cfg_t, opt, n_micro=nm, batch_axes=baxes)
        st_specs = shd.state_specs(cfg_t, mesh, mode, opt, params_shape)
        batch = train_batch_specs(cfg_t, shape)
        b_specs = shd.batch_specs(cfg_t, batch, mesh,
                                  axes=dp if mode == "fsdp_dp" else None)
        state_shape = {
            "params": params_shape,
            "opt": jax.eval_shape(opt.init, params_shape),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        metric_specs = {"ce": P(), "aux": P(), "loss": P()}
        with mesh:
            lowered = jax.jit(
                train_step,
                in_shardings=(_named(st_specs, mesh), _named(b_specs, mesh)),
                out_shardings=(_named(st_specs, mesh), _named(metric_specs, mesh)),
                donate_argnums=0,
            ).lower(state_shape, batch)
    elif shape.kind == "prefill":
        dp = shd.data_axes(mesh)
        if shape.global_batch % _mesh_size(mesh, dp) == 0:
            cfg = cfg.with_(act_batch_axes=dp)
        prefill = make_prefill_logits_last(cfg)
        batch = train_batch_specs(cfg, shape)
        batch.pop("labels")
        b_specs = shd.batch_specs(cfg, batch, mesh)
        vocab_ax = "model" if cfg.vocab_size % 16 == 0 else None
        out_spec = P(dp if shape.global_batch %
                     _mesh_size(mesh, dp) == 0 else None, vocab_ax)
        with mesh:
            lowered = jax.jit(
                prefill,
                in_shardings=(_named(p_specs, mesh), _named(b_specs, mesh)),
                out_shardings=NamedSharding(mesh, out_spec),
            ).lower(params_shape, batch)
    else:  # decode
        dp = shd.data_axes(mesh)
        toks, cache_shape, extras = decode_specs(cfg, shape)
        c_specs = shd.cache_specs(cfg, cache_shape, mesh, shape.global_batch)
        # pin cache k/v sharding in-model to match the in_shardings (avoids
        # GSPMD resharding the stacked L dim inside the layer scan)
        kv_spec = c_specs.get("k")
        if kv_spec is not None:
            cb = kv_spec[1] if isinstance(kv_spec[1], tuple) else (
                (kv_spec[1],) if kv_spec[1] else None)
            cs = kv_spec[2] if isinstance(kv_spec[2], tuple) else (
                (kv_spec[2],) if kv_spec[2] else None)
            cfg = cfg.with_(cache_batch_axes=cb, cache_seq_axes=cs)
        serve_step = make_serve_step(cfg)
        b_ax = dp if shape.global_batch % _mesh_size(mesh, dp) == 0 else None
        tok_spec = P(b_ax, None)
        vocab_ax = "model" if cfg.vocab_size % 16 == 0 else None
        logit_spec = P(b_ax, None, vocab_ax)
        in_sh = [_named(p_specs, mesh), _named(c_specs, mesh),
                 NamedSharding(mesh, tok_spec), NamedSharding(mesh, P())]
        args = [params_shape, cache_shape, toks["tokens"], toks["cache_pos"]]
        if extras:
            ckv_spec = jax.tree_util.tree_map(
                lambda v: P(None, b_ax, None, None, None), extras["cross_kv"])
            in_sh.append(_named(ckv_spec, mesh))
            args.append(extras["cross_kv"])
        with mesh:
            lowered = jax.jit(
                serve_step,
                in_shardings=tuple(in_sh),
                out_shardings=(NamedSharding(mesh, logit_spec),
                               _named(c_specs, mesh)),
                donate_argnums=1,   # cache is updated in place (aliased)
            ).lower(*args)

    lower_s = time.time() - t0
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": mode, "n_params": int(n_params), "lower_s": round(lower_s, 2),
    }
    if not compile_:
        return rec

    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "code_bytes": int(mem.generated_code_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
    }
    rec["memory"]["peak_per_device"] = (
        rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
        + rec["memory"]["output_bytes"] - rec["memory"]["alias_bytes"])
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):    # older jax: one dict per computation
        ca = ca[0] if ca else {}
    rec["cost"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }
    txt = compiled.as_text()
    by_kind, counts = collective_bytes(txt)
    rec["collectives"] = {"bytes_by_kind": by_kind, "counts": counts,
                          "total_bytes": sum(by_kind.values())}
    return rec


def _mesh_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axes, tuple):
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axes, 1)


def save_artifact(rec: Dict[str, Any], out_dir: str = ARTIFACT_DIR) -> str:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__{rec['mode']}.json"
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ALIASES) + ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", choices=["tp", "fsdp_tp"])
    ap.add_argument("--n-micro", type=int)
    ap.add_argument("--out-dir", default=ARTIFACT_DIR)
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shp in combos:
        try:
            rec = lower_one(arch, shp, multi_pod=args.multi_pod,
                            mode=args.mode, n_micro=args.n_micro)
            if not args.no_save:
                save_artifact(rec, args.out_dir)
            mem_gib = rec["memory"]["peak_per_device"] / 2**30
            print(f"OK   {arch:24s} {shp:12s} mesh={rec['mesh']} mode={rec['mode']}"
                  f" peak/dev={mem_gib:.2f}GiB flops={rec['cost']['flops']:.3g}"
                  f" coll={rec['collectives']['total_bytes']/2**20:.1f}MiB"
                  f" (lower {rec['lower_s']}s compile {rec['compile_s']}s)",
                  flush=True)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append((arch, shp, repr(e)[:200]))
            print(f"FAIL {arch:24s} {shp:12s}: {repr(e)[:200]}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print(f"all {len(combos)} dry-runs passed")


if __name__ == "__main__":
    main()
