"""ShapeDtypeStruct input specs for every (architecture x input shape).

``input_specs(cfg, shape)`` returns stand-ins for every model input — weak-
type-correct, shardable, no device allocation — exactly what
``jax.jit(...).lower(**specs)`` consumes in the dry-run.

* train/prefill: {tokens, labels} (+ frames for audio, input_embeds +
  positions for vlm).
* decode: {tokens (B,1), cache_pos ()} plus the stacked KV/recurrent cache
  (+ cross_kv for the enc-dec arch). Decode caches are built with
  ``jax.eval_shape`` over ``init_cache`` so per-family shapes stay in sync.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import frontend as F
from repro.models import transformer as T
from repro.models.config import InputShape, ModelConfig

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, SDS]:
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, SDS] = {}
    if cfg.family == "vlm":
        out["input_embeds"] = F.vlm_input_embeds_spec(cfg, B, S)
        out["positions"] = SDS((3, B, S), jnp.int32)
    else:
        out["tokens"] = SDS((B, S), jnp.int32)
    out["labels"] = SDS((B, S), jnp.int32)
    if cfg.family == "audio":
        out["frames"] = F.audio_frame_embeddings_spec(cfg, B)
    return out


def decode_specs(cfg: ModelConfig, shape: InputShape
                 ) -> Tuple[Dict[str, SDS], Any, Dict[str, SDS]]:
    """Returns (token_specs, cache_specs, extras) for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    toks = {"tokens": SDS((B, 1), jnp.int32),
            "cache_pos": SDS((), jnp.int32)}
    extras: Dict[str, Any] = {}
    if cfg.is_encdec:
        n_ctx = cfg.encoder.n_ctx
        extras["cross_kv"] = {
            "k": SDS((cfg.n_layers, B, n_ctx, cfg.n_kv_heads, cfg.head_dim),
                     cfg.param_dtype),
            "v": SDS((cfg.n_layers, B, n_ctx, cfg.n_kv_heads, cfg.head_dim),
                     cfg.param_dtype),
        }
    return toks, cache, extras


def gi_cohort_specs(params_shape: Any, input_shape: Tuple[int, ...],
                    n_classes: int, n_rec: int, batch: int,
                    masked: bool = False) -> Dict[str, Any]:
    """ShapeDtypeStructs for one sharded batched-GI call over a ``batch``-
    client stale cohort — what ``GradientInverter.invert_batch`` consumes
    after bucketing (stacked base/stale weight pytrees, per-client PRNG
    keys, optional flat masks, warm-start D_rec). Used by the dry-run and
    the mesh tests to lower the sharded hot path without real weights.
    """
    stack = jax.tree_util.tree_map(
        lambda l: SDS((batch, *l.shape), l.dtype), params_shape)
    n_params = sum(int(jnp.prod(jnp.asarray(l.shape)))
                   for l in jax.tree_util.tree_leaves(params_shape))
    out: Dict[str, Any] = {
        "w_base": stack,
        "w_stale": stack,
        "keys": SDS((batch, 2), jnp.uint32),
        "drec_x": SDS((batch, n_rec, *input_shape), jnp.float32),
        "drec_y": SDS((batch, n_rec, n_classes), jnp.float32),
    }
    if masked:
        out["masks"] = SDS((batch, n_params), jnp.bool_)
    return out


def gi_cohort_shardings(mesh: jax.sharding.Mesh, param_spec: Any = None,
                        masked: bool = False) -> Dict[str, Any]:
    """NamedShardings matching ``gi_cohort_specs``' entries on a server mesh.

    Everything shards on the cohort axis; with ``param_spec`` (a
    ``fl_param_specs`` tree for one unstacked weight pytree — the
    model-axis mesh case) the stacked ``w_base``/``w_stale`` trees
    additionally shard their weight dims on ``model``. Paired with
    ``gi_cohort_specs`` this lowers the sharded GI hot path without real
    weights (dry-run / mesh tests)."""
    from repro.launch.sharding import cohort_sharding, stack_specs, to_named
    ax = cohort_sharding(mesh)
    w = (to_named(stack_specs(param_spec, mesh), mesh)
         if param_spec is not None else ax)
    out: Dict[str, Any] = {"w_base": w, "w_stale": w, "keys": ax,
                           "drec_x": ax, "drec_y": ax}
    if masked:
        out["masks"] = ax
    return out


def concrete_train_batch(cfg: ModelConfig, B: int, S: int, key) -> Dict[str, Any]:
    """Small concrete batch of the same structure (smoke tests / examples)."""
    ks = jax.random.split(key, 3)
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        out["input_embeds"] = F.vlm_input_embeds(ks[0], cfg, B, S)
        out["positions"] = F.mrope_positions(B, S, n_patches=min(8, S), grid=4)
    else:
        out["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    out["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    if cfg.family == "audio":
        out["frames"] = F.audio_frame_embeddings(ks[2], cfg, B)
    return out
