"""Post-SPMD HLO analysis: collective inventory and byte counts.

``compiled.as_text()`` (after GSPMD partitioning) contains per-device shapes.
We inventory every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute and sum the bytes of their result arrays — the per-device
collective traffic proxy used by the roofline's collective term.

Caveats (documented in EXPERIMENTS.md §Roofline):
* ops inside a while body (scan-over-layers, microbatch loop) appear ONCE in
  the text; callers scale by trip count (the roofline probe lowers unrolled
  1/2-layer variants and extrapolates instead).
* bytes are result-array sizes: for all-gather that is the post-gather size
  (~bytes received per device on a ring); for reduce-scatter it understates
  by ~axis_size (noted, and small next to the all-gathers in practice).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Tuple[Dict[str, int], Dict[str, int]]:
    """Returns (bytes_by_kind, count_by_kind) — per-device result bytes.

    ``-start`` variants are counted; their matching ``-done`` is skipped to
    avoid double counting.
    """
    by_kind: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        by_kind[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    return dict(by_kind), dict(counts)


def total_collective_bytes(hlo_text: str) -> int:
    by_kind, _ = collective_bytes(hlo_text)
    return sum(by_kind.values())
