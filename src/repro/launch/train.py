"""Training launcher.

Two modes:

* ``--mode fl`` (default) — the paper's semi-asynchronous FL training with
  intertwined data/device heterogeneity and the chosen staleness strategy
  (this is the end-to-end driver deliverable: a ~100M-class run is
  ``examples/train_fl_end_to_end.py``).
* ``--mode dense`` — plain distributed LM pretraining of any assigned
  architecture on synthetic token data (exercises the same train_step the
  dry-run lowers, at a CPU-feasible reduced size unless --full).

On the container this runs on the 1x1 host mesh; on a real v5e slice the
same code takes the production mesh (launch/mesh.py).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import save_pytree
from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.core.client import LocalProgram
from repro.core.gradient_inversion import GIConfig
from repro.core.server import FLConfig, Server
from repro.data.partition import (client_label_histograms, dirichlet_partition,
                                  pad_client_shards)
from repro.data.staleness import intertwined_schedule
from repro.data.synthetic import make_image_dataset
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import concrete_train_batch
from repro.models.model import init_train_state, make_train_step
from repro.models.small import lenet
from repro.optim import sgd


def run_fl(args) -> None:
    x, y = make_image_dataset(args.n_per_class, n_classes=args.n_classes,
                              hw=args.hw, seed=args.seed)
    tx, ty = make_image_dataset(max(20, args.n_per_class // 4),
                                n_classes=args.n_classes, hw=args.hw,
                                seed=args.seed + 99)
    model = lenet(n_classes=args.n_classes, in_hw=args.hw)
    idx = dirichlet_partition(y, args.clients, alpha=args.alpha, seed=args.seed)
    cx, cy, cm = pad_client_shards(x, y, idx, m=args.samples_per_client)
    hist = client_label_histograms(y, idx, args.n_classes)
    sched = intertwined_schedule(hist, target_class=args.target_class,
                                 n_slow=args.n_slow, tau=args.staleness)
    prog = LocalProgram(steps=args.local_steps, lr=args.local_lr, momentum=0.5)
    cfg = FLConfig(strategy=args.strategy, rounds=args.rounds,
                   gi=GIConfig(n_rec=args.gi_nrec, iters=args.gi_iters,
                               keep_fraction=args.gi_keep),
                   eval_every=args.eval_every, seed=args.seed)
    srv = Server(model, prog, cfg, cx, cy, cm, sched, tx, ty)
    t0 = time.time()
    metrics = srv.run()
    dt = time.time() - t0
    final = [m for m in metrics if "acc" in m][-1]
    print(json.dumps({"strategy": args.strategy, "rounds": args.rounds,
                      "final_acc": final["acc"],
                      "target_class_acc": final.get(f"acc_class_{args.target_class}"),
                      "wall_s": round(dt, 1)}))
    if args.checkpoint:
        save_pytree(args.checkpoint, srv.global_params,
                    meta={"metrics": metrics[-5:]})


def run_dense(args) -> None:
    cfg = get_config(args.arch, reduced=not args.full)
    opt = sgd(args.local_lr, momentum=0.9)
    mesh = make_host_mesh()
    step = jax.jit(make_train_step(cfg, opt, n_micro=args.n_micro))
    key = jax.random.PRNGKey(args.seed)
    state = init_train_state(key, cfg, opt)
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")
    with mesh:
        for i in range(args.rounds):
            key, sub = jax.random.split(key)
            batch = concrete_train_batch(cfg, args.batch, args.seq, sub)
            t0 = time.time()
            state, metrics = step(state, batch)
            loss = float(metrics["loss"])
            print(f"step {i:4d} loss={loss:.4f} ({time.time()-t0:.2f}s)",
                  flush=True)
            assert np.isfinite(loss), "loss diverged"
    if args.checkpoint:
        save_pytree(args.checkpoint, state["params"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["fl", "dense"], default="fl")
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=sorted(ALIASES) + ARCH_IDS)
    ap.add_argument("--strategy", default="ours")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--staleness", type=int, default=10)
    ap.add_argument("--n-slow", type=int, default=4)
    ap.add_argument("--target-class", type=int, default=2)
    ap.add_argument("--n-classes", type=int, default=5)
    ap.add_argument("--n-per-class", type=int, default=100)
    ap.add_argument("--hw", type=int, default=16)
    ap.add_argument("--samples-per-client", type=int, default=32)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--local-lr", type=float, default=0.05)
    ap.add_argument("--gi-nrec", type=int, default=16)
    ap.add_argument("--gi-iters", type=int, default=50)
    ap.add_argument("--gi-keep", type=float, default=1.0)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint")
    args = ap.parse_args()
    (run_fl if args.mode == "fl" else run_dense)(args)


if __name__ == "__main__":
    main()
