"""Sharded sweep runner: fan a sim scenario across seeds (and policies).

    PYTHONPATH=src python -m repro.sweep --scenario fedbuff_k4 --seeds 8
    PYTHONPATH=src python -m repro.sweep --scenario pure_async,fedbuff_k4 \
        --seeds 4 --horizon 6 --gi-iters 3 --out /tmp/sweep

Every (scenario, seed) pair is one event-driven simulation (repro.sim)
whose Server runs the sharded cohort hot path when a mesh is available
(``--mesh N``; ``auto`` uses every device, so under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` the whole sweep
exercises the 4-shard engine). After the fan-out, all final models are
evaluated in ONE sharded computation: the per-run parameters, test inputs
and labels stack on a run axis that shard_maps over the same (pod, data)
mesh — the sweep-level analogue of the server's cohort axis.

``--quant-bits 32,8,4`` fans the upload wire format as an extra axis:
every (scenario, seed) runs once per bitwidth (``core.quantize`` int8/int4
stochastic quantization with error feedback; 32 = exact fp32 identity),
rows and trajectory files gain a ``_q<bits>`` suffix, and each row's
metrics carry the bytes actually put on the wire — the accuracy-vs-bits
sweep behind docs/compression.md.

Outputs:
* ``<out>/trajectory_<scenario>_seed<k>.json`` — per-seed trajectory
  (summary + eval curve + per-aggregation ``server_step`` rows in the
  obs-metrics-v1 schema under ``metrics``);
* ``<out>/metrics_<scenario>_seed<k>.jsonl`` — the same per-aggregation
  rows as an ``obs-metrics-v1`` JSONL stream (``repro.obs.report`` input);
* ``<out>/sweep.json`` — merged rows in the same ``bench-v1`` schema that
  ``benchmarks/run.py --json`` emits, so ``benchmarks/compare.py`` and the
  CI artifact tooling consume either file interchangeably.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Any, Dict, List, Optional

import numpy as np

SCHEMA = "bench-v1"


def _build_mesh(spec: str):
    """``none`` | ``auto`` | an integer device count -> mesh or None."""
    import jax

    from repro.launch.mesh import make_server_mesh
    if spec == "none":
        return None
    if spec == "auto":
        n = len(jax.devices())
        return make_server_mesh(n) if n > 1 else None
    return make_server_mesh(int(spec))


def _stacked_eval(runs, mesh) -> Optional[np.ndarray]:
    """Final accuracy of every run's model as one sharded computation.

    Stacks (params, test_x, test_y) on a leading run axis and shard_maps the
    vmapped eval over the cohort mesh (plain vmap when unsharded). Falls
    back to None when the runs don't share one model/test geometry (mixed
    custom scenarios) — callers then keep the per-run accuracies.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.disparity import (tree_pad_leading, tree_stack,
                                      tree_take_leading)
    from repro.launch.mesh import mesh_shard_count, shard_map_compat
    from repro.launch.sharding import cohort_spec, shard_bucket

    shapes = {(tuple(r.server.test_x.shape), tuple(r.server.test_y.shape))
              for r in runs}
    if len(shapes) != 1:
        return None
    model = runs[0].server.model
    params = tree_stack([r.server.global_params for r in runs])
    tx = jnp.stack([r.server.test_x for r in runs])
    ty = jnp.stack([r.server.test_y for r in runs])

    def acc_one(p, x, y):
        pred = jnp.argmax(model.apply(p, x), -1)
        return jnp.mean((pred == y).astype(jnp.float32))

    vm = jax.vmap(acc_one)
    n_shards = mesh_shard_count(mesh)
    if n_shards <= 1:
        return np.asarray(jax.jit(vm)(params, tx, ty))
    R = len(runs)
    pad = shard_bucket(R, n_shards) - R
    ax = cohort_spec(mesh)
    fn = jax.jit(shard_map_compat(vm, mesh, in_specs=(ax, ax, ax),
                                  out_specs=ax))
    accs = fn(tree_pad_leading(params, pad), tree_pad_leading(tx, pad),
              tree_pad_leading(ty, pad))
    return np.asarray(tree_take_leading(accs, R))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sweep")
    ap.add_argument("--scenario", required=True,
                    help="scenario name, or comma-separated list "
                         "(see python -m repro.sim --list)")
    ap.add_argument("--seeds", type=int, default=4,
                    help="fan seeds 0..N-1 per scenario (default 4)")
    ap.add_argument("--horizon", type=float, default=None)
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--gi-iters", type=int, default=None)
    ap.add_argument("--quant-bits", default=None,
                    help="comma-separated upload bitwidths to fan over "
                         "(e.g. 32,8,4); omitted = fp32 uploads, no suffix")
    ap.add_argument("--mesh", default="auto",
                    help="'auto' (all devices), 'none', or a device count "
                         "for the (pod, data) cohort mesh")
    ap.add_argument("--out", default="sweep_out",
                    help="output directory (default ./sweep_out)")
    args = ap.parse_args(argv)

    from repro.sim import scenarios

    names = [s for s in args.scenario.split(",") if s]
    unknown = [s for s in names if s not in scenarios.names()]
    if unknown:
        print(f"unknown scenario(s) {unknown}; have {scenarios.names()}",
              file=sys.stderr)
        return 2
    if args.seeds < 1:
        print("--seeds must be >= 1", file=sys.stderr)
        return 2
    # None = no quant axis (fp32, unsuffixed names — the historic layout)
    qbits: List[Optional[int]] = [None]
    if args.quant_bits:
        qbits = [int(b) for b in args.quant_bits.split(",") if b]
        bad = [b for b in qbits if b not in (4, 8, 32)]
        if bad:
            print(f"--quant-bits must be from 4/8/32, got {bad}",
                  file=sys.stderr)
            return 2

    mesh = _build_mesh(args.mesh)
    overrides: Dict[str, Any] = {"mesh": mesh}
    if args.strategy:
        overrides["strategy"] = args.strategy
    if args.gi_iters is not None:
        overrides["gi_iters"] = args.gi_iters

    os.makedirs(args.out, exist_ok=True)
    runs, rows = [], []
    for scen in names:
        for seed in range(args.seeds):
            for bits in qbits:
                tag = "" if bits is None else f"_q{bits}"
                kw = dict(overrides)
                if bits is not None:
                    kw["quant_bits"] = bits
                t0 = time.perf_counter()
                run = scenarios.build(scen, seed=seed, horizon=args.horizon,
                                      **kw)
                summary = run.run()
                wall = time.perf_counter() - t0
                runs.append(run)
                # per-aggregation rows in the shared obs-metrics-v1 schema
                # (bridge rows carry kind="server_step")
                step_rows = getattr(run.engine.aggregator, "rows", [])
                traj = {
                    "scenario": scen, "seed": seed, "wall_s": wall,
                    "summary": summary,
                    "evals": [{"time": t, "version": v, "acc": a}
                              for t, v, a in run.engine.evals],
                    "server_metrics": run.server.metrics,
                    "metrics": step_rows,
                }
                tpath = os.path.join(
                    args.out, f"trajectory_{scen}_seed{seed}{tag}.json")
                with open(tpath, "w") as f:
                    json.dump(traj, f, indent=2, default=float)
                if step_rows:
                    from repro.obs import write_jsonl
                    write_jsonl(step_rows, os.path.join(
                        args.out, f"metrics_{scen}_seed{seed}{tag}.jsonl"))
                srv = summary.get("server") or {}
                metrics = {"final_acc": summary["final_acc"],
                           "aggregations": summary["aggregations"],
                           "mean_realized_tau":
                               summary["mean_realized_tau"]}
                derived = (f"acc={summary['final_acc']:.3f} "
                           f"aggs={summary['aggregations']} "
                           f"mean_tau={summary['mean_realized_tau']:.2f} "
                           f"digest={summary['trace_digest']}")
                if bits is not None:
                    metrics["quant_bits"] = srv.get("quant_bits", bits)
                    metrics["wire_bytes"] = srv.get("wire_bytes", 0)
                    derived += (f" bits={bits} "
                                f"wire={metrics['wire_bytes']}B")
                rows.append({
                    "name": f"sweep/{scen}_seed{seed}{tag}",
                    "us_per_call": wall * 1e6,
                    "derived": derived,
                    "metrics": metrics,
                })
                print(f"{rows[-1]['name']},{rows[-1]['us_per_call']:.1f},"
                      f"{rows[-1]['derived']}", flush=True)

    t0 = time.perf_counter()
    accs = _stacked_eval(runs, mesh)
    if accs is not None:
        from repro.launch.mesh import mesh_shard_count
        merged_us = (time.perf_counter() - t0) * 1e6
        per_run = {r["name"]: float(a) for r, a in zip(rows, accs)}
        # the sharded merged eval must agree with each run's own eval
        drift = max(abs(float(a) - r["metrics"]["final_acc"])
                    for r, a in zip(rows, accs))
        rows.append({
            "name": "sweep/merged_eval",
            "us_per_call": merged_us,
            "derived": (f"{len(runs)} models evaluated in one "
                        f"{mesh_shard_count(mesh)}-shard computation; "
                        f"max drift vs per-run eval {drift:.2e}"),
            "metrics": {"n_runs": len(runs), "max_drift": drift,
                        "mesh_shards": mesh_shard_count(mesh)},
        })
        print(f"sweep/merged_eval,{merged_us:.1f},{rows[-1]['derived']}",
              flush=True)
    else:
        per_run = {}

    merged = {"schema": SCHEMA, "generated_by": "repro.sweep",
              "config": {"scenarios": names, "seeds": args.seeds,
                         "horizon": args.horizon, "strategy": args.strategy,
                         "gi_iters": args.gi_iters, "mesh": args.mesh,
                         "quant_bits": args.quant_bits},
              "rows": rows, "final_accs": per_run}
    mpath = os.path.join(args.out, "sweep.json")
    with open(mpath, "w") as f:
        json.dump(merged, f, indent=2, default=float)
    print(f"wrote {mpath}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
