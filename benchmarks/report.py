"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from saved
artifacts (benchmarks/artifacts/{dryrun,roofline}/*.json).

Usage: PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""

from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(__file__)


def _fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(mesh_filter: str) -> str:
    files = sorted(glob.glob(os.path.join(HERE, "artifacts", "dryrun", "*.json")))
    rows = []
    for f in files:
        r = json.load(open(f))
        if r["mesh"] != mesh_filter:
            continue
        c = r["collectives"]["bytes_by_kind"]
        coll_parts = " ".join(
            f"{k.replace('collective-','c-')}:{v/2**20:.0f}M"
            for k, v in sorted(c.items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['variant']} | {r['mode']} | "
            f"{_fmt_bytes(r['memory']['peak_per_device'])} | "
            f"{r['cost']['flops']:.3g} | "
            f"{r['collectives']['total_bytes']/2**20:.0f} | "
            f"{coll_parts or '—'} | {r['compile_s']}s |")
    hdr = ("| arch | shape | variant | mode | peak GiB/chip | HLO flops/chip"
           " (scan-bodies-once) | coll MiB/chip | collective mix | compile |\n"
           "|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def roofline_table(tag: str = "") -> str:
    pat = os.path.join(HERE, "artifacts", "roofline", f"*{tag}.json")
    files = sorted(glob.glob(pat))
    rows = []
    for f in files:
        if tag == "" and "__opt" in f:
            continue
        r = json.load(open(f))
        t = r["terms_s"]
        dom = r["bottleneck"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['variant']} | {r['mode']} | "
            f"{t['compute']*1e3:.2f} | {t['memory']*1e3:.2f} | "
            f"{t['collective']*1e3:.2f} | **{dom}** | "
            f"{r['model_flops_global']:.3g} | {r['useful_ratio']:.2f} |")
    hdr = ("| arch | shape | variant | mode | compute ms | memory ms | "
           "collective ms | bottleneck | MODEL_FLOPS | useful ratio |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def main():
    print("### Dry-run 16x16 (single pod)\n")
    print(dryrun_table("16x16"))
    print("\n### Dry-run 2x16x16 (multi-pod)\n")
    print(dryrun_table("2x16x16"))
    print("\n### Roofline (single-pod, L-extrapolated probe)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
