"""Benchmark-regression gate: fresh bench-v1 JSON vs a committed baseline.

    PYTHONPATH=src python -m benchmarks.compare \
        --baseline benchmarks/BENCH_baseline.json --fresh /tmp/bench.json

Gated rows (everything else is informational):

* ``sim/engine_*``  — engine throughput; FAILS when fresh ``events_per_sec``
  drops below baseline / factor;
* ``server/*``      — batched-GI hot-path wall time; FAILS when fresh
  ``us_per_call`` exceeds baseline * factor;
* ``gi/*``          — GI executor wall time (one-shot + segmented
  continuous-batching at a skewed cohort) and the fused-vs-concat disparity
  reduction; FAILS like ``server/*`` on ``us_per_call``;
* ``step/*``        — the fused aggregation round (multi-version cohort
  LocalUpdate + stacked FedAvg pipeline) vs the loop path at scattered base
  rounds, and VersionStore append/gather; FAILS on ``us_per_call``;
* ``quant/*``       — the quantized upload wire format: dequant-fused vs
  dequant-then-fp32 disparity value+grad on an int8 cohort payload (FAILS
  on ``us_per_call``) and host quantizer+EF throughput (FAILS on
  ``events_per_sec``);
* ``serve/*``       — the streaming service in steady state: sustained
  uploads/sec and int8 payload bytes/sec (both FAIL like ``sim/engine_*``
  on ``events_per_sec``) and p99 trigger-to-aggregate latency (FAILS on
  ``us_per_call``).

``--max-slowdown-factor`` defaults to 1.25 (the >25% gate). Slowdowns are
**canary-normalized**: both JSONs carry ``calibration/*`` rows (fixed
reference workloads measured in the same process), and the gate divides the
baseline/fresh canary ratio out of every gated row — a uniformly slower or
busier machine does not fail the gate; only code-specific slowdowns do.
Rows present in the baseline but missing from the fresh run FAIL (a renamed
or dropped benchmark must be an explicit baseline refresh, not a silent
skip).
Zero/absent measurements are asymmetric on purpose: a zero in the
*baseline* ungates the row (it was recorded as skipped, e.g. a mesh row
captured on a single-device host), but a zero in the *fresh* run FAILS —
if the baseline measured it, the fresh environment losing the measurement
(say, the CI job dropping ``XLA_FLAGS``) would otherwise silently ungate
the sharded path. Exit status: 0 pass, 1 regression, 2 usage/file errors.

Refreshing the baseline after an intentional perf change:

    PYTHONPATH=src python -m benchmarks.run --only sim,server \
        --json benchmarks/BENCH_baseline.json

(or download the ``bench-fresh`` artifact from the CI run and commit it —
see docs/sharded_server.md).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Tuple

GATED_PREFIXES = ("sim/engine_", "sim_scale/", "server/", "gi/", "step/",
                  "quant/", "serve/", "llm/")

# calibration canaries (benchmarks/run.py::calibrate): fixed reference
# workloads whose baseline/fresh ratio measures machine-wide speed, which
# the gate divides out so only code-specific slowdowns fail. Rows fall back
# to raw comparison when either file lacks the canary.
CANARY_FOR = {"events_per_sec": "calibration/python_loop",
              "us_per_call": "calibration/jax_spmv"}


def _load(path: str) -> Dict[str, Dict[str, Any]]:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "bench-v1":
        raise ValueError(f"{path}: not a bench-v1 document")
    return {r["name"]: r for r in doc["rows"]}


def _gate_value(row: Dict[str, Any]) -> Optional[Tuple[str, float, bool]]:
    """(metric_name, value, higher_is_better) for a gated row, else None."""
    if not any(row["name"].startswith(p) for p in GATED_PREFIXES):
        return None
    eps = row.get("metrics") or {}
    if "events_per_sec" in eps:
        v = float(eps["events_per_sec"])
        return ("events_per_sec", v, True) if v > 0 else None
    v = float(row.get("us_per_call") or 0.0)
    return ("us_per_call", v, False) if v > 0 else None


def _canary_scale(baseline: Dict[str, Dict[str, Any]],
                  fresh: Dict[str, Dict[str, Any]], metric: str,
                  brow: Dict[str, Any], frow: Dict[str, Any]) -> float:
    """fresh-machine slowdown factor for one gated row (1.0 = no canary).

    Prefers the row's own paired canary (``metrics.canary_us``, measured
    interleaved with the row so both saw the same load window); falls back
    to the run-level ``calibration/*`` rows."""
    bv = float((brow.get("metrics") or {}).get("canary_us") or 0.0)
    fv = float((frow.get("metrics") or {}).get("canary_us") or 0.0)
    if bv > 0 and fv > 0:
        return fv / bv
    name = CANARY_FOR.get(metric)
    bcal = baseline.get(name) if name else None
    fcal = fresh.get(name) if name else None
    if not bcal or not fcal:
        return 1.0
    bv = float(bcal.get("us_per_call") or 0.0)
    fv = float(fcal.get("us_per_call") or 0.0)
    return fv / bv if bv > 0 and fv > 0 else 1.0


def compare(baseline: Dict[str, Dict[str, Any]],
            fresh: Dict[str, Dict[str, Any]],
            factor: float) -> List[str]:
    """Returns failure messages (empty = gate passes)."""
    failures: List[str] = []
    for name, brow in sorted(baseline.items()):
        gate = _gate_value(brow)
        if gate is None:
            continue
        metric, bval, higher_better = gate
        frow = fresh.get(name)
        if frow is None:
            failures.append(f"{name}: present in baseline but missing from "
                            f"the fresh run")
            continue
        fgate = _gate_value(frow)
        if fgate is None:
            failures.append(f"{name}: fresh run has no usable {metric} "
                            f"measurement")
            continue
        fval = fgate[1]
        scale = _canary_scale(baseline, fresh, metric, brow, frow)
        if higher_better:
            # credit throughput for machine-wide slowdown before gating
            adj = fval * scale
            ratio = bval / adj
            verdict = f"{fval:.0f} vs baseline {bval:.0f} {metric}"
        else:
            adj = fval / scale
            ratio = adj / bval
            verdict = f"{fval:.1f} vs baseline {bval:.1f} {metric}"
        ok = ratio <= factor
        line = (f"{name}: {verdict} (machine x{scale:.2f}, code slowdown "
                f"x{ratio:.2f}, gate x{factor:.2f})")
        if ok:
            print(f"PASS {line}")
        else:
            # rows that carry a span breakdown (benchmarks/run.py records
            # one traced call per server row) say WHERE the regression
            # landed, not just that the row got slower
            sb = (frow.get("metrics") or {}).get("span_breakdown")
            if sb:
                line += ("\n  span breakdown: " + ", ".join(
                    f"{k}={float(v) * 1e3:.1f}ms" for k, v in
                    sorted(sb.items(), key=lambda kv: -float(kv[1]))))
            failures.append(line)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.compare")
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--max-slowdown-factor", type=float, default=1.25,
                    help="fail when slower than baseline by more than this "
                         "factor (default 1.25 = the >25%% gate)")
    args = ap.parse_args(argv)
    try:
        baseline = _load(args.baseline)
        fresh = _load(args.fresh)
    except (OSError, ValueError, KeyError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    failures = compare(baseline, fresh, args.max_slowdown_factor)
    if failures:
        for msg in failures:
            print(f"FAIL {msg}", file=sys.stderr)
        print(f"{len(failures)} benchmark regression(s) beyond the "
              f"{(args.max_slowdown_factor - 1) * 100:.0f}% gate; if "
              f"intentional, refresh benchmarks/BENCH_baseline.json "
              f"(see module docstring)", file=sys.stderr)
        return 1
    print("benchmark gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
