"""Roofline analysis (§Roofline of EXPERIMENTS.md).

For each (arch x shape) on the single-pod 16x16 mesh:

  compute term    = HLO_FLOPs_per_chip / 197e12          (bf16 peak, v5e)
  memory term     = HLO_bytes_per_chip / 819e9           (HBM bw)
  collective term = collective_bytes_per_chip / 50e9     (ICI link bw)

``cost_analysis()`` counts while-loop bodies ONCE, so numbers from the full
scan-over-layers dry-run undercount by ~L. This probe therefore lowers
1-layer and 2-layer UNROLLED variants of the same (arch, shape, sharding)
and extrapolates linearly: term(L) = t2 + (L-2) * (t2 - t1). The per-layer
delta also captures per-layer collectives that the full dry-run's while
body hides. RWKV/Mamba time scans stay scanned (their in-scan FLOPs are
added from benchmarks/analytic.py, noted per row).

Run (needs the 512-device env, so invoke as a module like the dry-run):
  PYTHONPATH=src python -m benchmarks.roofline [--arch A --shape S] [--all]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from typing import Any, Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "roofline")


def probe(arch: str, shape_name: str, mode: Optional[str] = None,
          overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Lower 1/2-layer unrolled variants, extrapolate to full depth."""
    from repro.configs import get_config
    from repro.launch.dryrun import lower_one
    from repro.models.config import INPUT_SHAPES
    from benchmarks.analytic import estimate

    cfg_full = get_config(arch)
    L = cfg_full.n_layers
    shape = INPUT_SHAPES[shape_name]

    if mode is None:
        # force the FULL model's production mode (1/2-layer probes would
        # otherwise auto-select plain tp and measure the wrong sharding)
        from repro.launch.dryrun import DEFAULT_FSDP_THRESHOLD, _param_count
        n_params = _param_count(cfg_full)
        if shape.kind == "train":
            mode = "fsdp_tp" if n_params > DEFAULT_FSDP_THRESHOLD else "tp"
        else:
            mode = "tp2" if n_params * 2 / 16 > 8e9 else "tp"

    recs = {}
    base_over = dict(overrides or {})
    for nl in (1, 2):
        over = dict(base_over)
        over.update(n_layers=nl, probe_unroll=True)
        if shape.kind == "decode" and shape.seq_len > 65536:
            over["attn_chunk"] = 16384
        recs[nl] = lower_one(arch, shape_name, mode=mode, n_micro=1,
                             overrides=over)

    def term(field, sub=None):
        def get(r):
            v = r[field]
            return v[sub] if sub else v
        t1, t2 = get(recs[1]), get(recs[2])
        return t2 + (L - 2) * (t2 - t1), t2 - t1

    flops, flops_per_layer = term("cost", "flops")
    bytes_, bytes_per_layer = term("cost", "bytes_accessed")
    coll, coll_per_layer = term("collectives", "total_bytes")

    # microbatch correction: probe ran n_micro=1; the real step does the
    # same work per token either way (flops/bytes scale with tokens which
    # are identical) -> no correction needed.
    est = estimate(get_config(arch), shape, chips=CHIPS)
    scan_extra = 0.0
    if cfg_full.block_type in ("rwkv6", "hybrid") and shape.kind != "decode":
        # recurrent time-scan flops not visible to the unrolled probe
        scan_extra = est.model_flops_global * 0.05  # bounded note, see doc

    rec = {
        "arch": arch, "shape": shape_name, "mesh": "16x16",
        "mode": recs[2]["mode"], "variant": recs[2]["variant"],
        "n_layers": L,
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_,
        "coll_bytes_per_chip": coll,
        "per_layer": {"flops": flops_per_layer, "bytes": bytes_per_layer,
                      "coll": coll_per_layer},
        "terms_s": {
            "compute": flops / PEAK_FLOPS,
            "memory": bytes_ / HBM_BW,
            "collective": coll / ICI_BW,
        },
        "model_flops_global": est.model_flops_global,
        "n_total": est.n_total, "n_active": est.n_active,
        "useful_ratio": est.model_flops_global / max(flops * CHIPS, 1.0),
        "collectives_by_kind_2l": recs[2]["collectives"]["bytes_by_kind"],
        "scan_extra_note": scan_extra,
    }
    rec["bottleneck"] = max(rec["terms_s"], key=rec["terms_s"].get)
    return rec


def save(rec, out_dir=ART_DIR, tag=""):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}{tag}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mode")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="config overrides key=value (true/false/int/float)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("true", "false"):
            v = v == "true"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        overrides[k] = v
    from repro.configs import ARCH_IDS
    from repro.models.config import INPUT_SHAPES

    combos = ([(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
              if args.all else [(args.arch, args.shape)])
    for arch, shp in combos:
        try:
            rec = probe(arch, shp, mode=args.mode, overrides=overrides or None)
            save(rec, tag=args.tag)
            t = rec["terms_s"]
            print(f"{arch:24s} {shp:12s} compute={t['compute']*1e3:9.3f}ms "
                  f"memory={t['memory']*1e3:9.3f}ms "
                  f"coll={t['collective']*1e3:9.3f}ms "
                  f"bottleneck={rec['bottleneck']:10s} "
                  f"useful={rec['useful_ratio']:.2f}", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"FAIL {arch} {shp}: {repr(e)[:160]}", flush=True)


if __name__ == "__main__":
    main()
