"""One benchmark per paper table/figure (Wang & Gao, AAAI 2025).

Every function reproduces the *shape* of one paper artifact at CPU scale
(synthetic data, small cohorts) and returns (rows, derived) where ``derived``
is the headline comparison the paper's claim rests on. benchmarks/run.py
prints them as CSV; EXPERIMENTS.md §Paper-claims records the full tables.

Scale knobs default to quick settings; the EXPERIMENTS run uses
``scale=2`` for tighter trends.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compensation
from repro.core.client import LocalProgram, make_local_update
from repro.core.disparity import cosine_distance, l1_disparity, tree_sub
from repro.core.gradient_inversion import GIConfig, GradientInverter
from repro.core.server import FLConfig, Server
from repro.core.sparsify import topk_mask
from repro.core.uniqueness import is_unique
from repro.data.partition import (client_label_histograms, dirichlet_partition,
                                  pad_client_shards)
from repro.data.staleness import intertwined_schedule
from repro.data.synthetic import (make_feature_dataset, make_image_dataset,
                                  make_timeseries_dataset)
from repro.data.variant import VariantDataStream
from repro.models.small import cnn1d, lenet, mlp3

KEY = jax.random.PRNGKey(0)


@dataclasses.dataclass
class Scale:
    n_classes: int = 5
    hw: int = 16
    n_per_class: int = 100
    clients: int = 12
    m: int = 24
    n_slow: int = 3
    rounds: int = 30          # slow clients deliver from round tau on; too
    local_steps: int = 5      # few rounds and strategies don't differentiate
    lr: float = 0.1
    gi_iters: int = 30
    gi_nrec: int = 12
    target: int = 2

    @classmethod
    def of(cls, scale: int = 1) -> "Scale":
        if scale >= 2:
            return cls(n_per_class=100, clients=12, rounds=45, gi_iters=40)
        return cls()


def _setting(sc: Scale, alpha=0.1, tau=10, seed=0, style=0):
    x, y = make_image_dataset(sc.n_per_class, n_classes=sc.n_classes,
                              hw=sc.hw, seed=seed, style=style)
    tx, ty = make_image_dataset(30, n_classes=sc.n_classes, hw=sc.hw,
                                seed=seed + 99, style=style)
    idx = dirichlet_partition(y, sc.clients, alpha=alpha, seed=seed)
    cx, cy, cm = pad_client_shards(x, y, idx, m=sc.m)
    hist = client_label_histograms(y, idx, sc.n_classes)
    sched = intertwined_schedule(hist, target_class=sc.target,
                                 n_slow=sc.n_slow, tau=tau)
    return cx, cy, cm, hist, sched, tx, ty


def _run(sc: Scale, strategy, cx, cy, cm, sched, tx, ty, variant=None,
         rounds=None, switching=True, gi_keep=1.0, seed=0):
    model = lenet(n_classes=sc.n_classes, in_hw=sc.hw)
    prog = LocalProgram(steps=sc.local_steps, lr=sc.lr, momentum=0.5)
    cfg = FLConfig(strategy=strategy, rounds=rounds or sc.rounds,
                   gi=GIConfig(n_rec=sc.gi_nrec, iters=sc.gi_iters, lr=0.1,
                               keep_fraction=gi_keep),
                   switching=switching, eval_every=rounds or sc.rounds,
                   seed=seed)
    srv = Server(model, prog, cfg, cx, cy, cm, sched, tx, ty,
                 variant_stream=variant)
    srv.run()
    final = [m for m in srv.metrics if "acc" in m][-1]
    return final, srv


# --------------------------------------------------------------------------- #
# A staleness "lab": one client's stale update vs the truth at tau
# --------------------------------------------------------------------------- #


def _staleness_lab(tau_steps: int, seed=0):
    """Returns (w0, w_now, client (x, y), w_stale, w_true, program, model)."""
    model = mlp3(n_features=12, n_classes=4, hidden=24)
    program = LocalProgram(steps=5, lr=0.1, momentum=0.5)
    w0 = model.init(jax.random.PRNGKey(seed))
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed + 1), 4)
    means = jax.random.normal(k1, (4, 12)) * 2
    y = jax.random.randint(k2, (24,), 0, 4)
    x = means[y] + 0.3 * jax.random.normal(k3, (24, 12))
    oy = jax.random.randint(k4, (24,), 0, 4)
    ox = means[oy] + 0.6 * jax.random.normal(k3, (24, 12))
    lu = make_local_update(model.apply, program)
    w_stale, _ = lu(w0, x, y)
    w_now = w0
    for _ in range(tau_steps):
        w_now, _ = lu(w_now, ox, oy)
    w_true, _ = lu(w_now, x, y)
    return model, program, w0, w_now, (x, y), w_stale, w_true


def table1_taylor_error(taus=(5, 10, 20, 50)) -> Tuple[List[Dict], float]:
    """Table 1: error of 1st-order Taylor compensation grows with staleness."""
    rows = []
    for tau in taus:
        _, _, w0, w_now, _, w_stale, w_true = _staleness_lab(tau)
        comp = compensation.first_order(tree_sub(w_stale, w0), w_now, w0)
        true_delta = tree_sub(w_true, w_now)
        rows.append({"staleness": tau,
                     "cos_err": float(cosine_distance(comp, true_delta)),
                     "l1_err": float(l1_disparity(comp, true_delta))})
    growth = rows[-1]["cos_err"] / max(rows[0]["cos_err"], 1e-9)
    return rows, growth


def fig4_gi_vs_first_order(taus=(2, 5, 10, 20), gi_iters=120
                           ) -> Tuple[List[Dict], float]:
    """Fig. 4: GI estimation error < 1st-order error, esp. at high tau."""
    rows = []
    for tau in taus:
        model, program, w0, w_now, (x, y), w_stale, w_true = _staleness_lab(tau)
        true_delta = tree_sub(w_true, w_now)
        fo = compensation.first_order(tree_sub(w_stale, w0), w_now, w0)
        inv = GradientInverter(model.apply, model.input_shape, model.n_classes,
                               program, GIConfig(n_rec=12, iters=gi_iters, lr=0.1))
        drec, _ = inv.invert(w0, w_stale, jax.random.PRNGKey(tau))
        w_hat = inv.estimate_unstale(w_now, drec)
        rows.append({
            "staleness": tau,
            "gi_err": float(l1_disparity(tree_sub(w_hat, w_now), true_delta)),
            "fo_err": float(l1_disparity(fo, true_delta)),
        })
    last = rows[-1]
    return rows, last["gi_err"] / max(last["fo_err"], 1e-9)


def table4_sparsification(keeps=(1.0, 0.10, 0.05, 0.01), gi_iters=80
                          ) -> Tuple[List[Dict], float]:
    """Table 4: top-K sparsification cuts GI compute with small error cost.

    Compute proxy: iterations needed to reach the dense run's halfway loss;
    the paper counts GI iterations the same way.
    """
    model, program, w0, w_now, (x, y), w_stale, w_true = _staleness_lab(8)
    true_delta = tree_sub(w_true, w_now)
    stale_delta = tree_sub(w_stale, w0)
    rows = []
    dense_target = None
    for keep in keeps:
        mask = None if keep >= 1.0 else topk_mask(stale_delta, keep)
        inv = GradientInverter(model.apply, model.input_shape, model.n_classes,
                               program,
                               GIConfig(n_rec=12, iters=gi_iters, lr=0.1,
                                        keep_fraction=keep))
        drec, info = inv.invert(w0, w_stale, KEY, mask=mask)
        w_hat = inv.estimate_unstale(w_now, drec)
        err = float(l1_disparity(tree_sub(w_hat, w_now), true_delta))
        losses = info["losses"]
        if dense_target is None:
            dense_target = losses[len(losses) // 2]
        # iterations (in units of 10) until below the dense halfway loss
        it_needed = next((i * 10 for i, l in enumerate(losses)
                          if l <= dense_target), gi_iters)
        rows.append({"keep_fraction": keep, "est_error": err,
                     "iters_to_target": it_needed,
                     "final_gi_loss": losses[-1]})
    i05 = min(2, len(rows) - 1)
    err_increase = rows[i05]["est_error"] / max(rows[0]["est_error"], 1e-9)
    return rows, err_increase


def table5_warm_start(change_fracs=(0.0, 0.05, 0.20, 0.50), gi_iters=60
                      ) -> Tuple[List[Dict], float]:
    """Table 5: warm-starting D_rec saves iterations when data is ~fixed."""
    model, program, w0, w_now, (x, y), w_stale, _ = _staleness_lab(4)
    inv = GradientInverter(model.apply, model.input_shape, model.n_classes,
                           program, GIConfig(n_rec=12, iters=gi_iters, lr=0.1))
    drec_prev, info_cold = inv.invert(w0, w_stale, KEY)
    cold_final = info_cold["losses"][-1]
    lu = make_local_update(model.apply, program)
    rows = []
    for frac in change_fracs:
        # client data changes by frac; new stale update from changed data
        n_change = int(frac * x.shape[0])
        kx = jax.random.PRNGKey(int(frac * 100) + 7)
        x2 = x.at[:n_change].set(jax.random.normal(kx, (n_change, x.shape[1])))
        w_stale2, _ = lu(w0, x2, y)
        # iterations for warm start to reach the cold run's final loss
        drec, info = inv.invert(w0, w_stale2, KEY, init=drec_prev,
                                iters=gi_iters)
        losses = info["losses"]
        it_needed = next((i * 10 for i, l in enumerate(losses)
                          if l <= cold_final), gi_iters)
        rows.append({"change_frac": frac, "iters_to_cold_final": it_needed,
                     "warm_first_loss": losses[0]})
    saved = 1.0 - rows[0]["iters_to_cold_final"] / gi_iters
    return rows, saved


def table9_fixed_data(sc: Scale, tau=10, strategies=None
                      ) -> Tuple[List[Dict], float]:
    """Table 9: accuracy per strategy, fixed-data scenario."""
    strategies = strategies or ["unweighted", "weighted", "first_order",
                                "w_pred", "asyn_tiers", "ours", "unstale"]
    cx, cy, cm, hist, sched, tx, ty = _setting(sc, tau=tau)
    rows = []
    accs = {}
    for s in strategies:
        final, _ = _run(sc, s, cx, cy, cm, sched, tx, ty)
        rows.append({"strategy": s, "acc": final["acc"],
                     "acc_target": final.get(f"acc_class_{sc.target}", 0.0)})
        accs[s] = final["acc"]
    best_base = max(v for k, v in accs.items() if k not in ("ours", "unstale"))
    return rows, accs.get("ours", 0.0) - best_base


def table10_alpha(sc: Scale, alphas=(1.0, 0.1, 0.01),
                  strategies=("unweighted", "weighted", "ours")
                  ) -> Tuple[List[Dict], float]:
    rows = []
    gaps = []
    for a in alphas:
        cx, cy, cm, hist, sched, tx, ty = _setting(sc, alpha=a)
        accs = {}
        for s in strategies:
            final, _ = _run(sc, s, cx, cy, cm, sched, tx, ty)
            accs[s] = final["acc"]
            rows.append({"alpha": a, "strategy": s, "acc": final["acc"]})
        gaps.append(accs["ours"] - accs["unweighted"])
    return rows, gaps[-1]


def table11_staleness(sc: Scale, taus=(5, 10, 20),
                      strategies=("unweighted", "weighted", "ours")
                      ) -> Tuple[List[Dict], float]:
    rows = []
    gaps = []
    for tau in taus:
        cx, cy, cm, hist, sched, tx, ty = _setting(sc, tau=tau)
        accs = {}
        for s in strategies:
            final, _ = _run(sc, s, cx, cy, cm, sched, tx, ty)
            accs[s] = final["acc"]
            rows.append({"staleness": tau, "strategy": s, "acc": final["acc"]})
        gaps.append(accs["ours"] - accs["unweighted"])
    return rows, gaps[-1]


def tables12_13_variant(sc: Scale, tau=8, rates=(0.5, 1.0, 2.0),
                        strategies=("unweighted", "ours")
                        ) -> Tuple[List[Dict], float]:
    """Tables 12/13: variant-data scenario (style drift), rate sweep."""
    rows = []
    gaps = []
    for rate in rates:
        cx, cy, cm, hist, sched, tx, ty = _setting(sc, tau=tau)
        px, py = make_image_dataset(sc.n_per_class, n_classes=sc.n_classes,
                                    hw=sc.hw, style=1, seed=1)
        accs = {}
        for s in strategies:
            stream = VariantDataStream(cx.copy(), cy, cm, px, py, rate=rate,
                                       seed=0)
            final, _ = _run(sc, s, cx, cy, cm, sched, tx, ty, variant=stream)
            accs[s] = final["acc"]
            rows.append({"rate": rate, "strategy": s, "acc": final["acc"]})
        gaps.append(accs["ours"] - accs["unweighted"])
    return rows, float(np.mean(gaps))


def table14_modalities(sc: Scale, taus=(2, 5, 10)) -> Tuple[List[Dict], float]:
    """Appendix A: MLP / 1D-CNN on tabular and time-series data."""
    rows = []
    final_gap = 0.0
    for modality in ("tabular", "timeseries"):
        if modality == "tabular":
            x, y = make_feature_dataset(40, n_classes=6, n_features=16)
            tx, ty = make_feature_dataset(15, n_classes=6, n_features=16,
                                          seed=5)
            model = mlp3(n_features=16, n_classes=6, hidden=32)
        else:
            x, y = make_timeseries_dataset(40, n_classes=5, seq=32, channels=4)
            tx, ty = make_timeseries_dataset(15, n_classes=5, seq=32,
                                             channels=4, seed=5)
            model = cnn1d(seq=32, channels=4, n_classes=5)
        idx = dirichlet_partition(y, sc.clients, alpha=0.1, seed=0)
        cx, cy, cm = pad_client_shards(x, y, idx, m=sc.m)
        hist = client_label_histograms(y, idx, model.n_classes)
        for tau in taus:
            sched = intertwined_schedule(hist, 1, sc.n_slow, tau)
            accs = {}
            for s in ("unweighted", "ours"):
                prog = LocalProgram(steps=sc.local_steps, lr=sc.lr,
                                    momentum=0.5)
                cfg = FLConfig(strategy=s, rounds=sc.rounds,
                               gi=GIConfig(n_rec=12, iters=sc.gi_iters, lr=0.1),
                               eval_every=sc.rounds, seed=0)
                srv = Server(model, prog, cfg, cx, cy, cm, sched,
                             jnp.asarray(tx), jnp.asarray(ty))
                srv.run()
                final = [m for m in srv.metrics if "acc" in m][-1]
                accs[s] = final["acc"]
            rows.append({"modality": modality, "staleness": tau,
                         "acc_unweighted": accs["unweighted"],
                         "acc_ours": accs["ours"],
                         "rel_improvement": accs["ours"] - accs["unweighted"]})
            final_gap = rows[-1]["rel_improvement"]
    return rows, final_gap


def table15_weighting_tradeoff(sc: Scale, tau=10) -> Tuple[List[Dict], float]:
    """Table 15: increased weights help stale clients but hurt overall."""
    cx, cy, cm, hist, sched, tx, ty = _setting(sc, tau=tau)
    rows = []
    results = {}
    for label, a, b in (("reduced", 0.25, 10.0), ("none", 0.0, 0.0),
                        ("increased", -0.25, 10.0)):
        model = lenet(n_classes=sc.n_classes, in_hw=sc.hw)
        prog = LocalProgram(steps=sc.local_steps, lr=sc.lr, momentum=0.5)
        cfg = FLConfig(strategy="weighted" if label != "none" else "unweighted",
                       weighted_a=a, weighted_b=b, rounds=sc.rounds,
                       eval_every=sc.rounds, seed=0)
        srv = Server(model, prog, cfg, cx, cy, cm, sched, tx, ty)
        srv.run()
        final = [m for m in srv.metrics if "acc" in m][-1]
        rows.append({"weighting": label, "acc_all": final["acc"],
                     "acc_stale_class": final.get(f"acc_class_{sc.target}", 0)})
        results[label] = final
    trade = (results["increased"][f"acc_class_{sc.target}"]
             - results["none"][f"acc_class_{sc.target}"])
    return rows, trade


def tables19_20_local_programs(taus=8) -> Tuple[List[Dict], float]:
    """Tables 19/20: GI vs 1st-order error across local steps / optimizers."""
    rows = []
    for steps in (1, 5, 10):
        model = mlp3(n_features=12, n_classes=4, hidden=24)
        program = LocalProgram(steps=steps, lr=0.1, momentum=0.5)
        w0 = model.init(KEY)
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        means = jax.random.normal(k1, (4, 12)) * 2
        y = jax.random.randint(k2, (24,), 0, 4)
        x = means[y] + 0.3 * jax.random.normal(k3, (24, 12))
        lu = make_local_update(model.apply, program)
        w_stale, _ = lu(w0, x, y)
        w_now = w0
        oy = jax.random.randint(k3, (24,), 0, 4)
        for _ in range(taus):
            w_now, _ = lu(w_now, means[oy] + jax.random.normal(k3, (24, 12)),
                          oy)
        w_true, _ = lu(w_now, x, y)
        true_delta = tree_sub(w_true, w_now)
        inv = GradientInverter(model.apply, model.input_shape, model.n_classes,
                               program, GIConfig(n_rec=12, iters=80, lr=0.1))
        drec, _ = inv.invert(w0, w_stale, KEY)
        w_hat = inv.estimate_unstale(w_now, drec)
        fo = compensation.first_order(tree_sub(w_stale, w0), w_now, w0)
        rows.append({"local_steps": steps, "optimizer": "sgdm",
                     "gi_err": float(l1_disparity(tree_sub(w_hat, w_now), true_delta)),
                     "fo_err": float(l1_disparity(fo, true_delta))})
    for opt in ("sgd", "sgdm", "adam", "fedprox"):
        model = mlp3(n_features=12, n_classes=4, hidden=24)
        program = LocalProgram(steps=5, lr=0.05 if opt == "adam" else 0.1,
                               optimizer=opt)
        w0 = model.init(KEY)
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
        means = jax.random.normal(k1, (4, 12)) * 2
        y = jax.random.randint(k2, (24,), 0, 4)
        x = means[y] + 0.3 * jax.random.normal(k3, (24, 12))
        lu = make_local_update(model.apply, program)
        w_stale, _ = lu(w0, x, y)
        w_now = w0
        oy = jax.random.randint(k3, (24,), 0, 4)
        for _ in range(taus):
            w_now, _ = lu(w_now, means[oy] + jax.random.normal(k3, (24, 12)), oy)
        w_true, _ = lu(w_now, x, y)
        true_delta = tree_sub(w_true, w_now)
        inv = GradientInverter(model.apply, model.input_shape, model.n_classes,
                               program, GIConfig(n_rec=12, iters=80, lr=0.1))
        drec, _ = inv.invert(w0, w_stale, KEY)
        w_hat = inv.estimate_unstale(w_now, drec)
        fo = compensation.first_order(tree_sub(w_stale, w0), w_now, w0)
        rows.append({"local_steps": 5, "optimizer": opt,
                     "gi_err": float(l1_disparity(tree_sub(w_hat, w_now), true_delta)),
                     "fo_err": float(l1_disparity(fo, true_delta))})
    sgdm = [r for r in rows if r["optimizer"] == "sgdm"][0]
    return rows, sgdm["gi_err"] / max(sgdm["fo_err"], 1e-9)


def fig9_uniqueness_accuracy(sc: Scale, rounds=12) -> Tuple[List[Dict], float]:
    """Fig. 9 / Table 8: uniqueness detection accuracy during training."""
    cx, cy, cm, hist, sched, tx, ty = _setting(sc, alpha=0.02, tau=4)
    model = lenet(n_classes=sc.n_classes, in_hw=sc.hw)
    prog = LocalProgram(steps=sc.local_steps, lr=sc.lr, momentum=0.5)
    cfg = FLConfig(strategy="unweighted", rounds=rounds, eval_every=rounds)
    srv = Server(model, prog, cfg, cx, cy, cm, sched, tx, ty)
    # ground truth: a stale client is unique iff its dominant class is held
    # (mostly) by slow clients only
    dominant = hist.argmax(1)
    rows = []
    correct = total = 0
    for t in range(rounds):
        srv.round(t)
        if t < 4:
            continue
        fast_updates = []
        lu = srv._local_update
        for i in sched.fast_clients[:6]:
            x, y, m = srv._client_shard(i)
            w = lu(srv.global_params, x, y, m)[0]
            fast_updates.append(tree_sub(w, srv.global_params))
        for i in sched.slow_clients:
            x, y, m = srv._client_shard(i)
            w = lu(srv.global_params, x, y, m)[0]
            upd = tree_sub(w, srv.global_params)
            pred_unique, _ = is_unique(upd, fast_updates)
            truly_unique = dominant[i] not in dominant[sched.fast_clients]
            correct += int(pred_unique == truly_unique)
            total += 1
        rows.append({"round": t, "cum_accuracy": correct / max(total, 1)})
    return rows, correct / max(total, 1)


def switching_tables_2_3(sc: Scale, tau=6, rounds=24) -> Tuple[List[Dict], float]:
    """Tables 2/3 + Fig. 5: E1/E2 crossover and gamma-decay smoothing."""
    cx, cy, cm, hist, sched, tx, ty = _setting(sc, tau=tau)
    rows = []
    accs = {}
    for decay in (0.0, 0.05, 0.10, 0.20):
        model = lenet(n_classes=sc.n_classes, in_hw=sc.hw)
        prog = LocalProgram(steps=sc.local_steps, lr=sc.lr, momentum=0.5)
        cfg = FLConfig(strategy="ours", rounds=rounds,
                       gi=GIConfig(n_rec=sc.gi_nrec, iters=sc.gi_iters, lr=0.1),
                       switching=True, switch_check_every=2,
                       eval_every=rounds, seed=0)
        srv = Server(model, prog, cfg, cx, cy, cm, sched, tx, ty)
        srv.monitor.decay_fraction = decay
        srv.run()
        final = [m for m in srv.metrics if "acc" in m][-1]
        rows.append({"decay_fraction": decay, "acc": final["acc"],
                     "switched_at": srv.monitor.switched_at,
                     "n_observations": len(srv.monitor.history)})
        accs[decay] = final["acc"]
    return rows, accs[0.10] - accs[0.0]
