"""Golden trace-digest gate: engine determinism must not regress silently.

    PYTHONPATH=src python -m repro.sim --scenario fedbuff_k4 --seed 0 \
        --strategy unweighted --out /tmp/sim.json
    PYTHONPATH=src python -m benchmarks.check_digest --summary /tmp/sim.json \
        --golden benchmarks/golden/fedbuff_k4_seed0.digest

The trace digest fingerprints the event process (dispatch/upload/dropout/
rejoin/aggregate/eval ordering) and is strategy-independent by design
(``examples/simulate_async_fl.py`` asserts this), so CI runs the cheap
``unweighted`` strategy. A mismatch means the engine's determinism contract
changed — if intentional (new event kind, RNG draw order, policy change),
regenerate the golden file with ``--update`` and say so in the PR.
"""

from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.check_digest")
    ap.add_argument("--summary", required=True,
                    help="JSON written by python -m repro.sim --out")
    ap.add_argument("--golden", required=True,
                    help="committed digest file (one hex digest per line 1)")
    ap.add_argument("--update", action="store_true",
                    help="write the observed digest to --golden instead of "
                         "comparing")
    args = ap.parse_args(argv)
    try:
        with open(args.summary) as f:
            digest = json.load(f)["trace_digest"]
    except (OSError, ValueError, KeyError) as e:
        print(f"error reading summary: {e}", file=sys.stderr)
        return 2
    if args.update:
        with open(args.golden, "w") as f:
            f.write(digest + "\n")
        print(f"wrote {digest} to {args.golden}")
        return 0
    try:
        with open(args.golden) as f:
            golden = f.read().strip().splitlines()[0].strip()
    except (OSError, IndexError) as e:
        print(f"error reading golden file: {e}", file=sys.stderr)
        return 2
    if digest != golden:
        print(f"trace digest mismatch: observed {digest}, golden {golden}\n"
              f"the event engine's determinism contract changed — if "
              f"intentional, regenerate with --update and flag it in the PR",
              file=sys.stderr)
        return 1
    print(f"trace digest ok ({digest})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
