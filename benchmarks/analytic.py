"""Analytic FLOPs / bytes model for the roofline (v5e target).

``cost_analysis()`` does not multiply while-loop bodies by trip count, so the
roofline probe (benchmarks/roofline.py) lowers 1- and 2-layer *unrolled*
variants and extrapolates linearly in L. This module supplies the
independent first-principles cross-check:

* MODEL_FLOPS = 6 N D (train) / 2 N D (prefill) / 2 N_active per token
  (decode), with N_active for MoE counting shared + top-k experts only,
  plus the standard attention term.
* HBM bytes: weight reads + activation traffic + KV-cache reads (decode).

The MODEL_FLOPS / HLO_FLOPs ratio in EXPERIMENTS.md §Roofline uses these.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig


def _param_counts(cfg: ModelConfig) -> Dict[str, float]:
    """Total and active parameter counts (analytic, matches init_params)."""
    d, f, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    hd, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    per_layer_attn = d * H * hd + 2 * d * KV * hd + H * hd * d
    if cfg.block_type == "rwkv6":
        per_layer = 5 * d * d + d * cfg.d_ff * 2 + d * d  # time+channel mix
        per_layer_active = per_layer
    else:
        if cfg.moe is not None:
            fe = cfg.moe.d_expert or f
            routed = cfg.moe.n_experts * 3 * d * fe
            shared = (3 * d * fe * cfg.moe.n_shared) if cfg.moe.n_shared else 0
            active = (cfg.moe.top_k * 3 * d * fe) + shared
            ffn_total, ffn_active = routed + shared, active
        else:
            n_mats = 3 if cfg.act == "silu_glu" else 2
            ffn_total = ffn_active = n_mats * d * f
        mamba = 0
        if cfg.block_type == "hybrid":
            di, N = cfg.ssm_expand * d, cfg.ssm_state
            mamba = d * 2 * di + di * 2 * N + di * d + di * max(8, d // 16) * 2
        per_layer = per_layer_attn + ffn_total + mamba
        per_layer_active = per_layer_attn + ffn_active + mamba
    embed = V * d * (1 if cfg.tie_embeddings else 2)
    n_total = L * per_layer + embed
    n_active = L * per_layer_active + embed
    if cfg.is_encdec:
        enc = cfg.encoder.n_layers * (per_layer_attn + 2 * d * f)
        cross = L * (per_layer_attn)
        n_total += enc + cross
        n_active += enc + cross
    return {"total": float(n_total), "active": float(n_active)}


@dataclasses.dataclass
class RooflineEstimate:
    model_flops_global: float        # useful FLOPs for the whole step
    hbm_bytes_per_device: float      # analytic min HBM traffic per chip
    n_total: float
    n_active: float


def estimate(cfg: ModelConfig, shape: InputShape, chips: int = 256,
             remat_factor: float = 1.0) -> RooflineEstimate:
    """remat_factor deliberately defaults to 1.0: MODEL_FLOPS is the *pure*
    useful-compute count, so MODEL_FLOPS / HLO_FLOPs directly exposes remat
    recompute and redundancy in the compiled program."""
    counts = _param_counts(cfg)
    N, Na = counts["total"], counts["active"]
    B, S = shape.global_batch, shape.seq_len
    tokens = B * S
    d, L, hd, H = cfg.d_model, cfg.n_layers, cfg.head_dim, cfg.n_heads

    # attention matmul flops (qk + pv), causal halves it; windows cap it
    if cfg.block_type == "rwkv6":
        attn_fl_train = tokens * L * (cfg.d_model * cfg.rwkv_head_size * 4)
        attn_fl_tok = L * cfg.d_model * cfg.rwkv_head_size * 4
    else:
        ctx = S if cfg.sliding_window is None else min(S, cfg.sliding_window)
        attn_fl_train = 4 * L * H * hd * tokens * ctx / 2
        attn_fl_tok = 4 * L * H * hd * ctx        # decode: 1 query vs cache

    emb_bytes = 2.0  # bf16
    if shape.kind == "train":
        mf = 6.0 * Na * tokens * remat_factor + 3.0 * attn_fl_train * remat_factor
        # per device: weights(+grad+momentum traffic) + activations
        hbm = (N * emb_bytes * 3 / chips) + tokens / chips * d * L * 2 * emb_bytes
    elif shape.kind == "prefill":
        mf = 2.0 * Na * tokens + attn_fl_train
        hbm = N * emb_bytes / chips + tokens / chips * d * L * emb_bytes
    else:  # decode: one token per sequence
        mf = (2.0 * Na + attn_fl_tok) * B
        kv_bytes = (2 * L * cfg.n_kv_heads * hd * emb_bytes *
                    (S if cfg.sliding_window is None else
                     min(S, cfg.sliding_window)))
        if cfg.block_type == "rwkv6":
            kv_bytes = L * cfg.n_rwkv_heads * cfg.rwkv_head_size ** 2 * 4
        hbm = N * emb_bytes / chips + B * kv_bytes / chips
    return RooflineEstimate(mf, hbm, N, Na)


if __name__ == "__main__":
    from repro.configs import ARCH_IDS, get_config
    for a in ARCH_IDS:
        cfg = get_config(a)
        c = _param_counts(cfg)
        print(f"{a:24s} N={c['total']/1e9:7.2f}B  active={c['active']/1e9:7.2f}B")
